"""Model/shape configuration system.

Every assigned architecture is a ``ModelConfig`` (frozen dataclass). Layer
stacking is expressed as a repeating ``block`` pattern of sublayer kinds so
heterogeneous stacks (gemma2 local/global, jamba attn:mamba 1:7 with MoE on
odd layers) still scan over homogeneous parameter groups.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class LayerSpec:
    """One sublayer inside the repeating block."""

    kind: str              # "attn" | "mamba"
    ffn: str = "mlp"       # "mlp" | "moe" | "none"
    window: int = 0        # sliding-window size; 0 = full attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    block: tuple[LayerSpec, ...] = ()  # () -> homogeneous full-attn + mlp

    # attention flavour
    qk_norm: bool = False
    attn_softcap: float = 0.0
    # sequences longer than this use the chunked online-softmax path (the
    # pure-JAX twin of the flash Pallas kernel); hillclimb overrides lower it
    attn_dense_threshold: int = 8192
    # Megatron-style sequence parallelism: residual stream + norms sharded
    # over the model axis on the sequence dim; GSPMD turns the TP all-reduces
    # into reduce-scatter + all-gather pairs and elementwise traffic /= TP
    seq_parallel: bool = False
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) half-dims

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25   # per-expert buffer = T*topk/E * this
    # "ep": experts sharded over data, token all-to-all (paper-standard);
    # "tp": expert weights sharded over model d_ff, output psum — moves
    #       T x d instead of E x C x d per layer (§Perf hillclimb)
    moe_parallel: str = "ep"

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64

    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed-frame length (whisper: 1500)

    # VLM stub
    vis_tokens: int = 0              # precomputed patch embeddings prepended

    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False        # multiply embeddings by sqrt(d_model) (gemma)
    norm_eps: float = 1e-6
    max_seq: int = 32768
    dtype: str = "bfloat16"
    # post-attention / post-ffn extra norms (gemma2 style)
    post_norms: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if not self.block:
            object.__setattr__(self, "block", (LayerSpec(kind="attn", ffn="mlp"),))
        assert self.n_layers % len(self.block) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by block {len(self.block)}")

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.block)

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return any(l.kind == "attn" for l in self.block)

    @property
    def sub_quadratic(self) -> bool:
        """Whether the long_500k cell runs (SSM/hybrid/windowed-attention).

        Hybrids qualify: most layers are O(1)-state Mamba; the few full-
        attention layers cost O(ctx) per decoded token (linear, not
        quadratic) with a KV footprint that fits when sharded."""
        if self.family in ("ssm", "hybrid"):
            return True
        return all(l.kind == "mamba" or l.window > 0 for l in self.block)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d                     # embed
        if not self.tie_embeddings:
            total += d * self.vocab                # lm_head
        per_block = 0
        for spec in self.block:
            per_block += d                          # pre-norm
            if self.post_norms:
                per_block += d
            if spec.kind == "attn":
                per_block += d * self.n_heads * hd          # wq
                per_block += 2 * d * self.n_kv_heads * hd   # wk, wv
                per_block += self.n_heads * hd * d          # wo
                if self.qk_norm:
                    per_block += 2 * hd
            else:  # mamba2
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                per_block += d * (2 * di + 2 * N + H)   # in_proj (x,z,B,C,dt)
                per_block += self.ssm_conv * (di + 2 * N)
                per_block += 3 * H                       # A_log, D, dt_bias
                per_block += di                          # gated norm
                per_block += di * d                      # out_proj
            if spec.ffn == "mlp":
                per_block += d + 3 * d * self.d_ff
                if self.post_norms:
                    per_block += d
            elif spec.ffn == "moe":
                per_block += d + d * self.moe_experts    # norm + router
                per_block += self.moe_experts * 3 * d * self.moe_d_ff
                if self.post_norms:
                    per_block += d
        total += per_block * self.n_blocks
        total += d                                  # final norm
        if self.encoder_layers:
            enc = self.encoder_layers * (2 * d + d * self.n_heads * hd +
                                         2 * d * self.n_kv_heads * hd +
                                         self.n_heads * hd * d + 3 * d * self.d_ff + d)
            # cross-attention in every decoder layer
            cross = self.n_layers * (d + d * self.n_heads * hd +
                                     2 * d * self.n_kv_heads * hd + self.n_heads * hd * d)
            total += enc + cross + d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe = sum(1 for l in self.block if l.ffn == "moe") * self.n_blocks
        inactive = n_moe * (self.moe_experts - self.moe_topk) * 3 * self.d_model * self.moe_d_ff
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=len(cfg.block) * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        max_seq=128,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_layers else 0,
        vis_tokens=8 if cfg.vis_tokens else 0,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_topk=min(cfg.moe_topk, 2) if cfg.moe_topk else 0,
        moe_d_ff=32 if cfg.moe_experts else 0,
        # tiny smoke configs run drop-free so prefill+decode == forward exactly
        moe_capacity_factor=16.0 if cfg.moe_experts else 1.25,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else (),
        dtype="float32",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)

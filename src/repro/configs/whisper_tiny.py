"""whisper-tiny [audio] — enc-dec, conv frontend stubbed (precomputed frames).

4L decoder (+4L encoder) d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.
[arXiv:2212.04356; unverified]. Decode shapes use extended sinusoidal
positions (the real model caps targets at 448 tokens — noted in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    act="gelu",
    encoder_layers=4,
    encoder_seq=1500,
    rope_theta=0.0,          # sinusoidal absolute positions, not RoPE
    norm_eps=1e-5,
)

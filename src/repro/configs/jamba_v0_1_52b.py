"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536. [arXiv:2403.19887; hf]
Block of 8 layers: attention at index 4, Mamba elsewhere; MoE FFN on odd
indices (1::2), dense FFN on even — the published period-8 layout.
"""
from .base import LayerSpec, ModelConfig

_BLOCK = tuple(
    LayerSpec(kind="attn" if i == 4 else "mamba",
              ffn="moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    block=_BLOCK,
    moe_experts=16,
    moe_topk=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    moe_parallel="tp",  # §Perf: expert-TP beats EP all-to-all on the 16x16 mesh
)

"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060;
unverified]. expand=2 => d_inner=3072, head_dim=64 => 48 SSM heads.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    block=(LayerSpec(kind="mamba", ffn="none"),),
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    norm_eps=1e-5,
)

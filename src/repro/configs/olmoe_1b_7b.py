"""olmoe-1b-7b [moe] — 64 experts top-8. [arXiv:2409.02060; hf]

16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert) vocab=50304.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    block=(LayerSpec(kind="attn", ffn="moe"),),
    moe_experts=64,
    moe_topk=8,
    moe_d_ff=1024,
    qk_norm=True,
    moe_parallel="tp",  # §Perf: 11x fewer collective bytes than EP all-to-all on 16x16
)

"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000. [arXiv:2408.00118; hf]
Block of 2: sliding-window(4096) layer then full-attention layer; GeGLU;
attention softcap 50, final-logit softcap 30; pre+post norms.
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    block=(LayerSpec(kind="attn", ffn="mlp", window=4096),
           LayerSpec(kind="attn", ffn="mlp", window=0)),
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    post_norms=True,
    tie_embeddings=True,
    embed_scale=True,
)

"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    block=(LayerSpec(kind="attn", ffn="moe"),),
    moe_experts=32,
    moe_topk=8,
    moe_d_ff=512,
    tie_embeddings=True,
    moe_parallel="tp",  # §Perf: expert-TP beats EP all-to-all on the 16x16 mesh
)

"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. [arXiv:2409.12191; hf]
The vision tower is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (vis_tokens x d_model) prepended to the text sequence, plus the
(t, h, w) M-RoPE position ids. mrope_sections are half-dim section sizes
(16, 24, 24) summing to head_dim/2 = 64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    mrope_sections=(16, 24, 24),
    vis_tokens=1024,
    rope_theta=1.0e6,
)

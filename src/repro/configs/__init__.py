"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib

from .base import SHAPES, LayerSpec, ModelConfig, ShapeConfig, reduced

ARCH_IDS = [
    "whisper-tiny",
    "jamba-v0.1-52b",
    "tinyllama-1.1b",
    "qwen3-8b",
    "gemma2-27b",
    "h2o-danube-3-4b",
    "mamba2-780m",
    "granite-moe-1b-a400m",
    "olmoe-1b-7b",
    "qwen2-vl-72b",
]

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen3-8b": "qwen3_8b",
    "gemma2-27b": "gemma2_27b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mamba2-780m": "mamba2_780m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def get_config(arch: str) -> ModelConfig:
    key = arch.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[key]}", __package__)
    return mod.CONFIG


def cells(arch: str) -> list[str]:
    """Shape names applicable to this arch (long_500k only if sub-quadratic)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


__all__ = ["ARCH_IDS", "SHAPES", "LayerSpec", "ModelConfig", "ShapeConfig",
           "cells", "get_config", "reduced"]

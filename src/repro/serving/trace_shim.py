"""Trace-emitting shim: the serving stack's page IO becomes a replayable
workload (ISSUE 10 tentpole).

``ServingTraceRecorder`` instruments the two host-side producers of page
traffic — ``PagedKVPool`` (KV offload / fetch / stale-discard) and
``CheckpointManager.save_async`` (checkpoint chunk writes) — by swapping
their threaded ``IOExecutor`` for a deterministic, synchronously-pumped
``RecordingExecutor``. Every IO that actually reaches a device is recorded
as one page-granular ``(time, lba, op, tenant)`` row; stale flush requests
discarded at the queue head (core/io_queues.py dual-queue discipline) never
reach a device and are therefore counted but NOT emitted — exactly the op
stream an SSD array would have seen. Time comes from an explicit
``LogicalClock`` the caller advances (no wall clock, no threads), so the
same driver seed yields a byte-identical trace array on every run.

Device alignment: the pool places tag ``t`` on device ``t % n_targets``
and the recorder emits ``lba = tag`` verbatim, while ``ArraySim``'s JBOD
fast loop maps a (folded) LBA to device ``lba % n_ssds``. Replaying with
``n_ssds == n_targets`` therefore lands every recorded op on the device
that served it (``n_live`` is always a multiple of the member count, so
the fold preserves ``lba % n``). Checkpoint chunks use a stable 64-bit
key hash for both placement and LBA; the shim pins the manager's salted
``hash()``-based ``_target_of`` to the same stable hash so placement —
and with it the emitted trace — is reproducible across processes.

Worked emit -> replay round trip::

    rec = ServingTraceRecorder(n_targets=8, tenant_of=lambda tag: tag % 2)
    rec.attach_pool(pool)                  # swap in the recorder
    ... drive the pool; rec.advance(dt); rec.pump() ...
    save_trace("kv.npz", rec.to_array(), meta={"n_targets": 8})

    trace = load_trace("kv.npz")
    r = ShardedArraySim(8, ssd, 0.6, Workload(scenario="trace"),
                        trace=trace, qos=policy).run(50000)

Trace format (``.npz``, version ``workloads.TRACE_VERSION``): arrays
``trace`` (float64, shape (n, 4), columns ``workloads.TRACE_COLUMNS``),
``version``, ``columns``, and a ``meta`` JSON string for free-form
recording metadata. The byte-identity contract is defined on the trace
ARRAY (``trace_digest``), not the container file (zip timestamps are not
content).

This module must stay importable without jax (the perf-smoke CI tier and
the fork-based sharded pool depend on it) — anything touching
``checkpoint.async_ckpt`` therefore happens through duck typing on an
already-constructed manager object.
"""
from __future__ import annotations

import hashlib
import json
from typing import Callable, Optional

import numpy as np

from repro.core.io_queues import HIGH, DualQueue, IORequest
from repro.core.workloads import (TRACE_COLUMNS, TRACE_READ, TRACE_VERSION,
                                  TRACE_WRITE)

__all__ = ["LogicalClock", "RecordingExecutor", "ServingTraceRecorder",
           "stable_key_lba", "save_trace", "load_trace", "trace_digest",
           "CKPT_TENANT"]

# default tenant id for checkpoint chunk writes: distinct from KV tenants so
# per-tenant SLO accounting separates checkpoint background traffic
CKPT_TENANT = 2


def stable_key_lba(key: str) -> int:
    """Stable page address for a checkpoint chunk key. Python's ``hash(str)``
    is salted per process; this one is reproducible across processes and
    platforms (blake2b), which the emit-twice byte-identity contract
    requires. Clamped to 52 bits: the trace's lba column is float64, and a
    wider hash would lose its LOW bits — exactly the ones that pick the
    device (``lba % n_targets``)."""
    digest = hashlib.blake2b(str(key).encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big") & 0xFFFFFFFFFFFFF


class LogicalClock:
    """Caller-driven simulation clock for trace emission (no wall time)."""

    __slots__ = ("now",)

    def __init__(self) -> None:
        self.now = 0.0

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


class RecordingExecutor:
    """Deterministic drop-in for ``core.io_queues.IOExecutor``.

    Same surface the serving stack uses (``submit``/``drain``/``shutdown``/
    ``stats``/``set_refill``/``_queues``), but no worker threads:

    * HIGH-priority requests (KV fetches, checkpoint restores) execute
      synchronously inside ``submit`` — the callers block on a semaphore
      released by ``device_fn``, so a deferred HIGH would deadlock them.
    * LOW-priority requests queue on real per-device ``DualQueue``s and are
      served by explicit ``pump(per_device)`` calls from the driver, so a
      backlog can build up and stale flush requests are discarded at the
      head by the genuine dual-queue discipline (discards are counted,
      never recorded — they never reach a device).

    Each executed request is mapped to a trace row via the payload's
    ``op`` field (offload/write -> ``TRACE_WRITE``, fetch/read ->
    ``TRACE_READ``); unknown payloads execute but record nothing."""

    def __init__(self, n_devices: int, device_fn: Callable[[int, object], None],
                 clock: LogicalClock, rows: list,
                 tenant_of: Optional[Callable[[int], int]] = None,
                 ckpt_tenant: int = CKPT_TENANT,
                 max_inflight: int = 2, reserved: int = 1) -> None:
        self._device_fn = device_fn
        self._clock = clock
        self._rows = rows
        self._tenant_of = tenant_of or (lambda tag: 0)
        self._ckpt_tenant = ckpt_tenant
        self._queues = [DualQueue(max_inflight=max_inflight,
                                  reserved=reserved)
                        for _ in range(n_devices)]

    # -- IOExecutor surface -------------------------------------------------
    def submit(self, device: int, req: IORequest) -> bool:
        if req.priority == HIGH:
            self._record(req)
            self._device_fn(device, req.payload)
            if req.on_complete:
                req.on_complete(req.payload)
            return True
        return self._queues[device].submit(req)

    def set_refill(self, device: int, fn: Callable[[], None]) -> None:
        self._queues[device].refill = fn

    def stats(self, device: int):
        return self._queues[device].stats

    def drain(self, timeout: float = 60.0) -> bool:
        while self.pump() > 0:
            pass
        return True

    def shutdown(self) -> None:
        pass

    # -- deterministic service ---------------------------------------------
    def pump(self, per_device: int = 4) -> int:
        """Serve up to ``per_device`` queued LOW requests on every device
        (round-robin by device id — one fixed, documented order). Returns
        the number of requests actually executed."""
        served = 0
        for dev, q in enumerate(self._queues):
            for _ in range(per_device):
                req = q.pop_next()
                if req is None:
                    break
                self._record(req)
                self._device_fn(dev, req.payload)
                q.complete(req)
                served += 1
        return served

    def backlog(self) -> int:
        return sum(len(q.high) + len(q.low) for q in self._queues)

    def stale_discards(self) -> int:
        return sum(q.stats.discarded_stale for q in self._queues)

    def _record(self, req: IORequest) -> None:
        p = req.payload
        if not isinstance(p, dict):
            return
        op = p.get("op")
        if op == "offload":
            row = (float(p["tag"]), TRACE_WRITE, self._tenant_of(p["tag"]))
        elif op == "fetch":
            row = (float(p["tag"]), TRACE_READ, self._tenant_of(p["tag"]))
        elif op == "write":
            row = (float(stable_key_lba(p["key"])), TRACE_WRITE,
                   self._ckpt_tenant)
        elif op == "read":
            row = (float(stable_key_lba(p["key"])), TRACE_READ,
                   self._ckpt_tenant)
        else:
            return
        self._rows.append((self._clock.now,) + row)


class ServingTraceRecorder:
    """Facade tying the clock, the rows, and the attached executors together.

    ``attach_pool``/``attach_ckpt`` swap the target's threaded executor for
    a shared-clock ``RecordingExecutor`` (the displaced executor is shut
    down). The driver then interleaves workload steps with ``advance(dt)``
    and ``pump()`` calls; ``to_array()`` yields the (n, 4) float64 trace,
    time-ordered by construction."""

    def __init__(self, n_targets: int,
                 tenant_of: Optional[Callable[[int], int]] = None,
                 ckpt_tenant: int = CKPT_TENANT) -> None:
        self.n_targets = n_targets
        self.clock = LogicalClock()
        self.rows: list = []
        self._tenant_of = tenant_of
        self._ckpt_tenant = ckpt_tenant
        self._execs: list[RecordingExecutor] = []

    def _make_exec(self, n_devices: int, device_fn) -> RecordingExecutor:
        ex = RecordingExecutor(n_devices, device_fn, self.clock, self.rows,
                               tenant_of=self._tenant_of,
                               ckpt_tenant=self._ckpt_tenant)
        self._execs.append(ex)
        return ex

    def attach_pool(self, pool) -> "ServingTraceRecorder":
        """Instrument a ``PagedKVPool``: its offloads/fetches are recorded,
        its stale discards counted. Attach right after construction, before
        any IO is submitted."""
        old = pool.exec
        pool.exec = self._make_exec(len(old._queues), pool._do_io)
        old.shutdown()
        return self

    def attach_ckpt(self, mgr) -> "ServingTraceRecorder":
        """Instrument a ``CheckpointManager``: chunk writes/reads are
        recorded under the checkpoint tenant. Also pins the manager's
        process-salted ``hash()`` placement to the stable key hash so the
        emitted trace is reproducible across processes (placement and
        recorded LBA then agree: ``lba % n_targets == target``)."""
        old = mgr._exec
        mgr._exec = self._make_exec(mgr.n_targets, mgr._do_io)
        mgr._target_of = lambda key: stable_key_lba(key) % mgr.n_targets
        old.shutdown()
        return self

    # -- driver hooks -------------------------------------------------------
    def advance(self, dt: float) -> float:
        return self.clock.advance(dt)

    def record_direct(self, lba: int, op: int, tenant: int = 0) -> None:
        """Record an IO that bypasses the executor — the pool's synchronous
        spill paths (``offload_now``/``offload_now_evicted``: blocking
        dirty-eviction offloads) still hit the spill device and belong in
        the trace; the driver calls this right after invoking them."""
        self.rows.append((self.clock.now, float(lba), float(op),
                          float(tenant)))

    def pump(self, per_device: int = 4) -> int:
        return sum(ex.pump(per_device) for ex in self._execs)

    def drain(self) -> None:
        for ex in self._execs:
            ex.drain()

    # -- results ------------------------------------------------------------
    def to_array(self) -> np.ndarray:
        if not self.rows:
            return np.empty((0, 4), dtype=np.float64)
        return np.asarray(self.rows, dtype=np.float64)

    def stale_discards(self) -> int:
        return sum(ex.stale_discards() for ex in self._execs)

    def backlog(self) -> int:
        return sum(ex.backlog() for ex in self._execs)


# -- trace container --------------------------------------------------------

def trace_digest(trace: np.ndarray) -> str:
    """SHA-256 over shape + row bytes: the byte-identity contract is on
    this canonical array form (same seed => same digest)."""
    arr = np.ascontiguousarray(np.asarray(trace, dtype=np.float64))
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def save_trace(path, trace: np.ndarray, meta: dict | None = None) -> None:
    """Write the versioned ``.npz`` trace container."""
    arr = np.asarray(trace, dtype=np.float64)
    assert arr.ndim == 2 and arr.shape[1] in (3, 4), "bad trace shape"
    np.savez_compressed(
        path,
        version=np.int64(TRACE_VERSION),
        columns=np.array(TRACE_COLUMNS[:arr.shape[1]]),
        trace=arr,
        meta=np.array(json.dumps(meta or {})),
    )


def load_trace(path, with_meta: bool = False):
    """Load a trace container; returns the (n, 3|4) array (and the meta
    dict when ``with_meta``). Rejects unknown future versions."""
    with np.load(path, allow_pickle=False) as z:
        version = int(z["version"])
        if version > TRACE_VERSION:
            raise ValueError(f"trace version {version} is newer than "
                             f"supported ({TRACE_VERSION})")
        trace = z["trace"]
        meta = json.loads(str(z["meta"])) if "meta" in z else {}
    return (trace, meta) if with_meta else trace

from .kv_pool import PagedAllocator, PagedKVPool
from .engine import ServeEngine, Request

__all__ = ["PagedAllocator", "PagedKVPool", "ServeEngine", "Request"]

"""Paged KV pool with set-associative placement + the paper's policies.

HBM pool pages are grouped into page SETS (SA-cache, paper §3.1): a page for
tag = (seq, page_idx) may live only in set ``hash(tag) % num_sets``, so every
policy decision is a 12-wide vector op, never a global scan. On top of it:

  * pinned   — pages of ACTIVE sequences (attention needs residency);
  * dirty    — device-only content (no host-tier copy yet);
  * clean    — a host-tier copy exists (offloaded by the flusher).

The dirty-page flusher (core/flusher.py, unchanged) pre-cleans FULL pages of
active sequences in the background over per-target dual-priority queues, so
a preemption or eviction almost always hits a *clean* page and costs nothing
— the paper's thesis transplanted: convert blocking evictions into
background bandwidth. Queued offloads whose page was freed (sequence
finished) are discarded stale at the queue head (§3.3.2).

GClock hits are bumped every time a page is read by decode (recency), and
eviction inside a set is clean-first analytic GClock — identical math to
``core/policies.py`` (property-tested), with ``kernels/flush_score`` as the
TPU-resident twin for scoring at scale.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core import policies
from repro.core.flusher import DirtyPageFlusher, FlushRequest, StalenessChecker
from repro.core.gc_sim import _mix64
from repro.core.io_queues import HIGH, LOW, IOExecutor, IORequest


@dataclass
class PoolStats:
    allocs: int = 0
    clean_evictions: int = 0
    dirty_evictions: int = 0          # blocking offload on the alloc path
    alloc_failures: int = 0           # -> engine preempts a sequence
    offloads: int = 0
    fetches: int = 0
    stale_discards: int = 0


class PagedAllocator:
    """Host control plane for the HBM page pool (numpy, O(set_size) ops)."""

    def __init__(self, num_sets: int, set_size: int = policies.SET_SIZE):
        self.num_sets, self.set_size = num_sets, set_size
        n = num_sets * set_size
        self.tags = np.full(n, -1, dtype=np.int64)
        self.hits = np.zeros(n, dtype=np.int32)
        self.dirty = np.zeros(n, dtype=bool)
        self.pinned = np.zeros(n, dtype=bool)
        self.full = np.zeros(n, dtype=bool)      # page completely written
        self.clock = np.zeros(num_sets, dtype=np.int32)
        self.where: dict[int, int] = {}          # tag -> page_id
        self.stats = PoolStats()

    # -- helpers -------------------------------------------------------------
    def set_of(self, tag: int) -> int:
        return _mix64(tag * 2 + 1) % self.num_sets

    def set2_of(self, tag: int) -> int:
        """Second placement choice (d=2). Pure SA placement cannot guarantee
        CO-RESIDENCY of one sequence's pinned pages (3 pinned tags hashing to
        a 2-way set would deadlock an admission forever); two choices plus
        the bounded spill below make that probability negligible while the
        policy math stays per-set."""
        return _mix64(tag * 2 + 7) % self.num_sets

    def _slots(self, s: int) -> slice:
        return slice(s * self.set_size, (s + 1) * self.set_size)

    def page_id(self, tag: int) -> Optional[int]:
        return self.where.get(tag)

    def _try_set(self, s: int) -> Optional[int]:
        """Find a slot in set ``s``: empty, else clean-first GClock among
        UNPINNED (eligibility-masked analytic sweep). None if fully pinned."""
        sl = self._slots(s)
        tags = self.tags[sl]
        empty = np.flatnonzero(tags == -1)
        if empty.size:
            return s * self.set_size + int(empty[0])
        eligible = ~self.pinned[sl]
        if not eligible.any():
            return None
        clean = eligible & ~self.dirty[sl]
        cand = clean if clean.any() else eligible
        ss = self.set_size
        hits = self.hits[sl]
        dist = (np.arange(ss) - self.clock[s]) % ss
        score = np.where(cand, hits * ss + dist, np.iinfo(np.int64).max)
        slot = int(np.argmin(score))
        # sweep decrement bookkeeping (mirrors policies gclock semantics)
        h_v = int(hits[slot])
        visits = np.where(dist < dist[slot], h_v + 1, h_v)
        hits = np.maximum(hits - np.where(cand, visits, 0), 0)
        hits[slot] = 0
        self.hits[sl] = hits
        self.clock[s] = (slot + 1) % ss
        return s * self.set_size + slot

    # -- allocation (paper: clean-first GClock within the set) ---------------
    def alloc(self, tag: int) -> tuple[Optional[int], Optional[int], bool]:
        """Allocate a page for ``tag``.

        Returns (page_id, evicted_tag, evicted_dirty). page_id None => every
        candidate slot is pinned: the engine must preempt a sequence and
        retry. ``evicted_dirty`` True means the caller owes a blocking
        offload of the victim before reusing the slot (the stall the flusher
        makes rare)."""
        self.stats.allocs += 1
        page = None
        s1 = self.set_of(tag)
        s2 = self.set2_of(tag)
        for s in (s1,) if s1 == s2 else (s1, s2):
            page = self._try_set(s)
            if page is not None:
                break
        if page is None:
            # bounded spill: co-residency escape hatch (placement is a
            # heuristic — `where` maps tags to pages directly)
            free = np.flatnonzero((self.tags == -1))
            if free.size:
                page = int(free[0])
            else:
                evictable = ~self.pinned & (self.tags != -1)
                clean = evictable & ~self.dirty
                cand = clean if clean.any() else evictable
                if cand.any():
                    page = int(np.flatnonzero(cand)[0])
        if page is None:
            self.stats.alloc_failures += 1
            return None, None, False
        evicted_tag = int(self.tags[page]) if self.tags[page] != -1 else None
        evicted_dirty = bool(self.dirty[page]) if evicted_tag is not None else False
        if evicted_tag is not None:
            del self.where[evicted_tag]
            if evicted_dirty:
                self.stats.dirty_evictions += 1
            else:
                self.stats.clean_evictions += 1
        self.tags[page] = tag
        self.hits[page] = 0
        self.dirty[page] = True
        self.full[page] = False
        self.pinned[page] = True
        self.where[tag] = page
        return page, evicted_tag, evicted_dirty

    # -- state transitions ----------------------------------------------------
    def touch(self, tags: list[int]) -> None:
        for t in tags:
            p = self.where.get(t)
            if p is not None:
                self.hits[p] = min(self.hits[p] + 1, 15)

    def mark_full(self, tag: int) -> None:
        p = self.where.get(tag)
        if p is not None:
            self.full[p] = True

    def mark_clean(self, tag: int) -> None:
        p = self.where.get(tag)
        if p is not None:
            self.dirty[p] = False

    def set_pinned(self, tags: list[int], value: bool) -> None:
        for t in tags:
            p = self.where.get(t)
            if p is not None:
                self.pinned[p] = value

    def free(self, tags: list[int]) -> None:
        for t in tags:
            p = self.where.pop(t, None)
            if p is not None:
                self.tags[p] = -1
                self.dirty[p] = False
                self.pinned[p] = False
                self.full[p] = False
                self.hits[p] = 0

    # -- CacheView protocol for the flusher (full dirty pages only) ----------
    def dirty_count(self, set_idx: int) -> int:
        sl = self._slots(set_idx)
        return int((self.dirty[sl] & self.full[sl] & (self.tags[sl] != -1)).sum())

    def flush_candidates(self, set_idx: int):
        sl = self._slots(set_idx)
        tags = self.tags[sl]
        flushable = self.dirty[sl] & self.full[sl] & (tags != -1)
        if not flushable.any():
            return []
        fs = policies.flush_scores(self.hits[sl], int(self.clock[set_idx]),
                                   valid=(tags != -1))
        out = [(int(i), int(tags[i]), int(fs[i]))
               for i in np.flatnonzero(flushable)]
        out.sort(key=lambda t: -t[2])
        return out

    def device_of(self, tag: int) -> int:
        return tag % max(getattr(self, "n_targets", 1), 1)

    def flush_score_of(self, set_idx: int, slot: int) -> int:
        sl = self._slots(set_idx)
        fs = policies.flush_scores(self.hits[sl], int(self.clock[set_idx]),
                                   valid=(self.tags[sl] != -1))
        return int(fs[slot])


class PagedKVPool:
    """Device pool + host tier + flusher + offload executor.

    The device arrays live in ``engine`` (they are jitted-function operands);
    this class owns placement (allocator), the host tier (the "SSD"), and the
    background offload pipeline. ``copy_out(tag) -> np arrays`` and
    ``copy_in(tag, arrays)`` are provided by the engine.
    """

    def __init__(self, num_sets: int, set_size: int, *, n_targets: int = 2,
                 copy_out: Callable, copy_in: Callable,
                 flush_trigger: int = policies.FLUSH_TRIGGER,
                 max_pending_per_target: int = 64,
                 offload_delay: float = 0.0):
        self.alloc = PagedAllocator(num_sets, set_size)
        self.alloc.n_targets = n_targets
        self.host_tier: dict[int, tuple] = {}
        self._copy_out = copy_out
        self._copy_in = copy_in
        self._offload_delay = offload_delay
        self._lock = threading.Lock()
        self.flusher = DirtyPageFlusher(
            self.alloc, n_targets, trigger=flush_trigger,
            max_pending_per_dev=max_pending_per_target)
        self.checker = StalenessChecker(
            is_evicted=lambda r: self.alloc.where.get(r.tag) !=
            r.set_idx * self.alloc.set_size + r.slot,
            is_clean=lambda r: not self._is_dirty(r),
            current_score=lambda r: self.alloc.flush_score_of(r.set_idx, r.slot),
            score_threshold=0,
        )
        self.exec = IOExecutor(n_targets, self._do_io, max_inflight=2,
                               reserved=1)

    def _is_dirty(self, r: FlushRequest) -> bool:
        p = self.alloc.where.get(r.tag)
        return p is not None and bool(self.alloc.dirty[p])

    # -- io ---------------------------------------------------------------
    def _do_io(self, target: int, payload) -> None:
        import time
        if self._offload_delay:
            time.sleep(self._offload_delay)
        if payload["op"] == "offload":
            tag = payload["tag"]
            data = self._copy_out(tag)
            if data is not None:
                with self._lock:
                    self.host_tier[tag] = data
                    self.alloc.mark_clean(tag)
                    self.alloc.stats.offloads += 1
        else:                                     # fetch (HIGH)
            tag = payload["tag"]
            self._copy_in(tag, self.host_tier[tag])
            with self._lock:
                self.alloc.mark_clean(tag)        # content == host copy
                self.alloc.stats.fetches += 1
            payload["done"].release()

    # -- flusher pump (paper §3.3) -----------------------------------------
    def note_page_full(self, set_idx: int) -> None:
        self.flusher.note_write(set_idx)
        self.pump()

    def pump(self, budget: int = 8) -> None:
        for fr in self.flusher.make_requests(budget, max_visits=16):
            self.exec.submit(fr.device, IORequest(
                payload={"op": "offload", "tag": fr.tag, "fr": fr},
                priority=LOW,
                is_stale=lambda p, fr=fr: self.checker(fr),
                on_complete=lambda p, fr=fr: self.flusher.note_flush_done(fr),
                on_discard=lambda p, fr=fr: self._on_discard(fr)))

    def _on_discard(self, fr: FlushRequest) -> None:
        with self._lock:
            self.alloc.stats.stale_discards += 1
        self.flusher.note_flush_discarded(fr)

    # -- synchronous paths ---------------------------------------------------
    def offload_now(self, tag: int) -> None:
        """Blocking offload (dirty eviction / preemption of unflushed page)."""
        data = self._copy_out(tag)
        if data is not None:
            with self._lock:
                self.host_tier[tag] = data
                self.alloc.mark_clean(tag)
                self.alloc.stats.offloads += 1

    def offload_now_evicted(self, tag: int, page_id: int, copy_out) -> None:
        """Save a just-evicted dirty victim's content (slot metadata already
        reassigned, device content still intact until the first new write)."""
        data = copy_out(tag, page_id)
        if data is not None:
            with self._lock:
                self.host_tier[tag] = data
                self.alloc.stats.offloads += 1

    def mark_redirtied(self, tag: int) -> None:
        """New tokens written into a page that had a host copy: the copy is
        stale (paper §3.3.2 rule (ii) inverse) — drop it, re-dirty."""
        p = self.alloc.where.get(tag)
        if p is not None:
            self.alloc.dirty[p] = True
        self.host_tier.pop(tag, None)

    def fetch(self, tags: list[int]) -> None:
        """HIGH-priority parallel fetch host->device (resume path)."""
        import threading as _t
        sem = _t.Semaphore(0)
        todo = [t for t in tags if t in self.host_tier]
        for tag in todo:
            self.exec.submit(tag % self.exec._queues.__len__(), IORequest(
                payload={"op": "fetch", "tag": tag, "done": sem},
                priority=HIGH))
        for _ in todo:
            sem.acquire()

    def close(self):
        self.exec.shutdown()

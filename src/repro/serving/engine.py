"""Continuous-batching serve engine over the paged KV pool.

Scheduler loop (host) + jitted paged decode step (device):

  submit() -> waiting queue -> admit into free batch rows (prefill writes the
  prompt's KV pages) -> decode all active rows each step -> pages that fill
  trigger the dirty-page flusher (background offload, LOW priority) ->
  finished sequences free their pages (queued offloads become stale and are
  discarded) -> page-pool exhaustion preempts the youngest sequence
  (clean pages drop instantly thanks to pre-cleaning; dirty ones cost a
  blocking offload — counted) -> preempted sequences resume via HIGH-priority
  fetches.

This is the paper's cache+flusher+queues stack serving as a first-class
inference feature; stats expose exactly the quantities the paper reports
(extra writeback, stall counts, queue discards).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from .kv_pool import PagedKVPool
from .paged_model import init_pools, make_paged_decode_step

MAX_PAGES_PER_SEQ = 512


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    state: str = "waiting"         # waiting | active | preempted | done
    row: int = -1
    length: int = 0
    pages: list[int] = field(default_factory=list)     # tags, in order
    stall_steps: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 page_size: int = 16, num_sets: int = 32, set_size: int = 4,
                 max_pages: int = 64, use_flusher: bool = True,
                 use_kernel: bool = False, seed: int = 0):
        assert cfg.has_attention or cfg.family == "ssm"
        self.cfg = cfg
        self.params = params
        self.page = page_size
        self.max_batch = max_batch
        self.max_pages = max_pages
        self.use_flusher = use_flusher
        n_data_pages = num_sets * set_size
        self.scratch_page = n_data_pages                  # reserved, never allocated
        self.pools = init_pools(cfg, num_pages=n_data_pages + 1,
                                page_size=page_size, max_batch=max_batch)
        self.pool = PagedKVPool(num_sets, set_size, n_targets=2,
                                copy_out=self._copy_out, copy_in=self._copy_in,
                                # paper: trigger at half the set (6 of 12)
                                flush_trigger=max(0, set_size // 2 - 1))
        self.step_fn = make_paged_decode_step(cfg, page_size=page_size,
                                              use_kernel=use_kernel)
        self._attn_positions = [i for i, s in enumerate(cfg.block)
                                if s.kind == "attn"]
        self._reqs: dict[int, Request] = {}
        self._waiting: list[int] = []
        self._rows: list[Optional[int]] = [None] * max_batch
        self._rid = itertools.count()
        self._lengths = np.zeros(max_batch, np.int32)
        self._tables = np.full((max_batch, max_pages), self.scratch_page,
                               np.int32)
        self._last_tok = np.zeros(max_batch, np.int32)
        self._pools_lock = __import__("threading").Lock()
        self.preemptions = 0
        self.blocking_offloads = 0

    # ------------------------------------------------------------- tags
    def _tag(self, rid: int, page_idx: int) -> int:
        return rid * MAX_PAGES_PER_SEQ + page_idx

    # -------------------------------------------------- device<->host copies
    def _copy_out(self, tag: int, page_id: int | None = None):
        pid = self.pool.alloc.where.get(tag) if page_id is None else page_id
        if pid is None:
            return None
        ks, vs = [], []
        for pos in self._attn_positions:
            ks.append(np.asarray(self.pools[pos]["k"][:, pid]))
            vs.append(np.asarray(self.pools[pos]["v"][:, pid]))
        return (ks, vs)

    def _copy_in(self, tag: int, data) -> None:
        # serialized: concurrent fetch workers would lose each other's
        # read-modify-write of the pools pytree
        with self._pools_lock:
            pid = self.pool.alloc.where.get(tag)
            if pid is None:
                return
            ks, vs = data
            new_pools = list(self.pools)
            for j, pos in enumerate(self._attn_positions):
                new_pools[pos] = {
                    "k": self.pools[pos]["k"].at[:, pid].set(jnp.asarray(ks[j])),
                    "v": self.pools[pos]["v"].at[:, pid].set(jnp.asarray(vs[j])),
                }
            self.pools = tuple(new_pools)

    # ------------------------------------------------------------ public
    def submit(self, prompt: list[int], max_new: int = 16) -> int:
        rid = next(self._rid)
        self._reqs[rid] = Request(rid, list(prompt), max_new)
        self._waiting.append(rid)
        return rid

    def result(self, rid: int) -> Request:
        return self._reqs[rid]

    # -------------------------------------------------------- page control
    def _alloc_page(self, req: Request, page_idx: int,
                    allow_preempt: bool = True) -> bool:
        """Allocate (tag); on a fully-pinned set optionally preempt a victim.

        Admission passes allow_preempt=False (a waiting request never kicks
        out an active one — that's the thrash the paper's deep queues avoid);
        only an ACTIVE row growing into its next page may preempt.
        """
        tag = self._tag(req.rid, page_idx)
        while True:
            pid, ev_tag, ev_dirty = self.pool.alloc.alloc(tag)
            if pid is not None:
                if ev_tag is not None and ev_dirty:
                    # blocking offload of the victim's content (stall)
                    self.pool.offload_now_evicted(ev_tag, pid, self._copy_out)
                    self.blocking_offloads += 1
                req.pages.append(tag)
                return True
            if not allow_preempt:
                return False
            victim = self._pick_victim(exclude=req.rid)
            if victim is None:
                return False
            self._preempt(victim)

    def _pick_victim(self, exclude: int) -> Optional[Request]:
        active = [r for r in self._reqs.values()
                  if r.state == "active" and r.rid != exclude]
        if not active:
            return None
        return max(active, key=lambda r: r.rid)        # youngest first (LIFO)

    def _preempt(self, req: Request) -> None:
        self.preemptions += 1
        # partial (dirty, non-full) pages + any un-offloaded full pages must
        # reach the host tier before their slots can be reused
        for tag in req.pages:
            pid = self.pool.alloc.where.get(tag)
            if pid is not None and self.pool.alloc.dirty[pid]:
                if self.pool.alloc.full[pid] and self.use_flusher:
                    req.stall_steps += 1   # flusher hadn't gotten to it yet
                self.pool.offload_now(tag)
                self.blocking_offloads += 1
        self.pool.alloc.set_pinned(req.pages, False)
        self._rows[req.row] = None
        self._tables[req.row, :] = self.scratch_page
        req.state = "preempted"
        req.row = -1

    def _free(self, req: Request) -> None:
        self.pool.alloc.free(req.pages)
        # scan by rid: host-tier copies of pages evicted while preempted are
        # no longer listed in req.pages but must not leak
        for tag in [t for t in self.pool.host_tier
                    if t // MAX_PAGES_PER_SEQ == req.rid]:
            self.pool.host_tier.pop(tag, None)
        req.pages.clear()

    # ------------------------------------------------------------- admit
    def _admit(self, rid: int) -> bool:
        req = self._reqs[rid]
        row = next((i for i, r in enumerate(self._rows) if r is None), None)
        if row is None:
            return False
        resume = req.state == "preempted"
        tokens = req.prompt + req.out
        # consumed tokens occupy positions [0, c); the next decode writes
        # position c -> pages 0 .. c // page must exist.
        consumed = req.length if resume else len(req.prompt)
        n_pages = consumed // self.page + 1
        req.pages = [t for t in req.pages
                     if self.pool.alloc.where.get(t) is not None]
        # re-pin surviving pages FIRST: the alloc loop below must not evict
        # this request's own residents
        self.pool.alloc.set_pinned(req.pages, True)
        survivors = list(req.pages)
        newly: list[int] = []
        for i in range(n_pages):
            tag = self._tag(rid, i)
            if self.pool.alloc.where.get(tag) is None:
                if not self._alloc_page(req, i, allow_preempt=False):
                    # ROLL BACK this attempt's allocations: they hold garbage
                    # (content is only restored by the post-success fetch);
                    # leaving them dirty would later clobber the good host
                    # copies via eviction writeback
                    self.pool.alloc.free(newly)
                    req.pages = survivors
                    self.pool.alloc.set_pinned(survivors, False)
                    return False
                newly.append(tag)
        self.pool.alloc.set_pinned(req.pages, True)
        req.row, req.state = row, "active"
        self._rows[row] = rid
        if resume:
            # fetch by LOGICAL page index, not by the (lossy) tag list —
            # a page evicted while preempted lives only in the host tier
            fetchable = [self._tag(rid, i) for i in range(n_pages)
                         if self._tag(rid, i) in self.pool.host_tier]
            self.pool.fetch(fetchable)
            self._refill_row(req, tokens)
        else:
            self._prefill_row(req, tokens)
        return True

    def _prefill_row(self, req: Request, tokens: list[int]) -> None:
        cfg, row = self.cfg, req.row
        s = len(tokens)
        pad = len(req.pages) * self.page
        toks = jnp.asarray(tokens, jnp.int32)[None]
        logits, cache = T.prefill(self.params, toks, cfg, max_seq=pad)
        new_pools = list(self.pools)
        for i, spec in enumerate(cfg.block):
            lc = cache.layers[i]
            if spec.kind == "attn":
                k = lc["k"][:, 0]                          # (nb, pad, kvh, hd)
                v = lc["v"][:, 0]
                kp, vp = new_pools[i]["k"], new_pools[i]["v"]
                for tag in req.pages:
                    pi = tag % MAX_PAGES_PER_SEQ        # page index from tag
                    pid = self.pool.alloc.where[tag]
                    sl = slice(pi * self.page, (pi + 1) * self.page)
                    kp = kp.at[:, pid].set(k[:, sl])
                    vp = vp.at[:, pid].set(v[:, sl])
                new_pools[i] = {"k": kp, "v": vp}
            else:
                st = new_pools[i]
                new_pools[i] = jax.tree.map(
                    lambda pool, new: pool.at[:, row].set(new[:, 0]),
                    st, {k: lc[k] for k in st})
        # NOTE: prefill caches beyond ``s`` are zeros — masked by lengths.
        self.pools = tuple(new_pools)
        self._lengths[row] = s
        self._tables[row, :] = self.scratch_page
        for tag in req.pages:
            self._tables[row, tag % MAX_PAGES_PER_SEQ] = \
                self.pool.alloc.where[tag]
        # the prompt's last-position logits emit the FIRST generated token
        first = int(jnp.argmax(logits[0, -1]))
        req.out.append(first)
        self._last_tok[row] = first
        req.length = s
        # full prompt pages are immediately flushable
        if self.use_flusher:
            for tag in req.pages:
                pi = tag % MAX_PAGES_PER_SEQ
                if (pi + 1) * self.page <= s:
                    self.pool.alloc.mark_full(tag)
                    self.pool.note_page_full(self.pool.alloc.set_of(tag))

    def _refill_row(self, req: Request, tokens: list[int]) -> None:
        """Resume: pages were fetched back by tag; rebuild the table/row."""
        row = req.row
        self._lengths[row] = req.length          # consumed tokens
        self._tables[row, :] = self.scratch_page
        for pi_tag in req.pages:
            pi = pi_tag % MAX_PAGES_PER_SEQ
            self._tables[row, pi] = self.pool.alloc.where[pi_tag]
        self._last_tok[row] = tokens[-1]         # the one unconsumed token

    # --------------------------------------------------------------- loop
    def step(self) -> None:
        # admission
        for rid in list(self._waiting):
            if self._admit(rid):
                self._waiting.remove(rid)
        active_rows = [i for i, r in enumerate(self._rows) if r is not None]
        if not active_rows:
            return
        # ensure a page exists for the next position of every active row
        for i in active_rows:
            rid = self._rows[i]
            if rid is None:                      # preempted as a victim above
                continue
            req = self._reqs[rid]
            pi = int(self._lengths[i]) // self.page
            tag = self._tag(req.rid, pi)
            if self.pool.alloc.where.get(tag) is None:
                if not self._alloc_page(req, pi):
                    self._preempt(req)
                    continue
                self._tables[i, pi] = self.pool.alloc.where[tag]
        active_rows = [i for i, r in enumerate(self._rows) if r is not None]
        if not active_rows:
            return
        active = np.zeros(self.max_batch, bool)
        active[active_rows] = True
        logits, self.pools = self.step_fn(
            self.params, self.pools,
            jnp.asarray(self._last_tok[:, None]),
            jnp.asarray(self._lengths),
            jnp.asarray(self._tables),
            jnp.asarray(active))
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        # GClock touch: every resident page of every active row was read
        for i in active_rows:
            if self._rows[i] is None:
                continue
            self.pool.alloc.touch(self._reqs[self._rows[i]].pages)
        for i in active_rows:
            if self._rows[i] is None:
                continue
            req = self._reqs[self._rows[i]]
            # the page written this step diverged from any host copy
            cur_tag = self._tag(req.rid, int(self._lengths[i]) // self.page)
            self.pool.mark_redirtied(cur_tag)
            req.out.append(int(toks[i]))
            self._last_tok[i] = toks[i]
            self._lengths[i] += 1
            req.length += 1
            if self._lengths[i] % self.page == 0 and self.use_flusher:
                tag = self._tag(req.rid, int(self._lengths[i]) // self.page - 1)
                self.pool.alloc.mark_full(tag)
                self.pool.note_page_full(self.pool.alloc.set_of(tag))
            if len(req.out) >= req.max_new:
                req.state = "done"
                self._rows[i] = None
                self._tables[i, :] = self.scratch_page
                self._free(req)
        # resumption of preempted requests
        for req in list(self._reqs.values()):
            if req.state == "preempted":
                self._waiting.append(req.rid) if req.rid not in self._waiting else None

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if all(r.state == "done" for r in self._reqs.values()):
                break
            self.step()

    def stats(self) -> dict:
        s = self.pool.alloc.stats
        return {
            "offloads": s.offloads, "fetches": s.fetches,
            "stale_discards": s.stale_discards,
            "clean_evictions": s.clean_evictions,
            "dirty_evictions": s.dirty_evictions,
            "alloc_failures": s.alloc_failures,
            "preemptions": self.preemptions,
            "blocking_offloads": self.blocking_offloads,
        }

    def close(self):
        self.pool.close()

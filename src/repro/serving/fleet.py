"""Serving-fleet scenario generator: thousands of KV-spill sessions -> trace.

Drives a REAL ``PagedKVPool`` (no-op copy callbacks — the device arrays are
irrelevant to the IO pattern) through the ``trace_shim`` recorder with a
deterministic synthetic serving fleet:

* session arrivals follow a diurnal sinusoid (rate modulated by
  ``diurnal_amp`` over ``diurnal_periods`` periods across the run) sampled
  as a per-step Poisson count — bursty AND slowly varying, the two arrival
  regimes the GC-coordination results care about;
* two tenant classes: interactive (tenant 0 — short sessions, preempted
  and resumed, fetch-heavy) and batch (tenant 1 — long sessions,
  write-heavy). Checkpoint chunk writes, when a ``CheckpointManager`` is
  attached by the caller, ride as tenant ``trace_shim.CKPT_TENANT``;
* every full KV page goes through the pool's genuine flusher pipeline
  (``note_page_full`` -> dual-priority queues -> offload or stale discard),
  blocking dirty-eviction spills are recorded via ``record_direct``, and
  session resume fetches run HIGH priority — the paper's §3.3 machinery
  produces the trace, not a synthetic op mix.

Same ``FleetConfig`` + seed => byte-identical trace array (the RNG is a
single seeded ``default_rng`` consumed in one fixed order; the clock is
logical). Tags encode ``session * PAGES_PER_SESSION_CAP + page_idx`` so
``tag % n_targets`` spreads each session's pages across the array and the
recorder's ``tenant_of`` can map any tag back to its session's tenant.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving.kv_pool import PagedKVPool
from repro.serving.trace_shim import ServingTraceRecorder

from repro.core.workloads import TRACE_WRITE

__all__ = ["FleetConfig", "FleetResult", "run_fleet",
           "PAGES_PER_SESSION_CAP"]

# tag layout: tag = session_id * CAP + page_idx (page_idx < CAP). Prime,
# and so coprime to any realistic n_targets: device = tag % n_targets then
# mixes the session id in, instead of collapsing to page_idx % n_targets
# (a power-of-two CAP would pin page k of EVERY session to the same device).
PAGES_PER_SESSION_CAP = 67

TENANT_INTERACTIVE = 0
TENANT_BATCH = 1


@dataclass(frozen=True)
class FleetConfig:
    n_targets: int = 8             # spill devices == replay array members
    duration_s: float = 1.0        # logical trace span
    dt: float = 1e-3               # driver step
    arrival_rate: float = 600.0    # mean session arrivals / logical second
    diurnal_amp: float = 0.6       # arrival modulation depth (0..1)
    diurnal_periods: float = 2.0   # sinusoid periods over the run
    page_tokens: int = 64          # tokens per KV page
    interactive_frac: float = 0.6  # tenant 0 share of sessions
    pages_min: int = 2             # session length (pages), inclusive
    pages_max: int = 12            # session length (pages), inclusive
    tokens_per_step_interactive: int = 48
    tokens_per_step_batch: int = 160
    preempt_prob: float = 0.12     # per-step, interactive active sessions
    resume_prob: float = 0.4       # per-step, preempted sessions
    pool_sets: int = 10            # SA sets in the HBM pool
    set_size: int = 8              # slots per set
    flush_trigger: int = 1         # dirty-full pages per set before queueing
    pump_per_device: int = 1       # LOW offloads served per device per step


@dataclass
class FleetResult:
    trace: np.ndarray              # (n, 4) float64 time/lba/op/tenant
    tokens_total: int = 0
    sessions_started: int = 0
    sessions_completed: int = 0
    offloads: int = 0
    fetches: int = 0
    stale_discards: int = 0
    dirty_evictions: int = 0
    alloc_failures: int = 0
    meta: dict = field(default_factory=dict)


class _Session:
    __slots__ = ("sid", "tenant", "n_pages", "pages_done", "tokens_accum",
                 "tags", "state")

    def __init__(self, sid: int, tenant: int, n_pages: int) -> None:
        self.sid = sid
        self.tenant = tenant
        self.n_pages = n_pages
        self.pages_done = 0
        self.tokens_accum = 0
        self.tags: list[int] = []
        self.state = "active"          # active | preempted


def run_fleet(cfg: FleetConfig = FleetConfig(), seed: int = 0,
              recorder: ServingTraceRecorder | None = None) -> FleetResult:
    """Run the fleet against a fresh pool; returns the emitted trace plus
    driver/pool counters. Pass a ``recorder`` that already has a
    ``CheckpointManager`` attached to interleave checkpoint chunk writes
    with the KV traffic on the same clock."""
    rng = np.random.default_rng(seed)
    tenants: dict[int, int] = {}       # session -> tenant (for tenant_of)
    rec = recorder or ServingTraceRecorder(cfg.n_targets)
    rec._tenant_of = lambda tag: tenants.get(
        tag // PAGES_PER_SESSION_CAP, 0)
    pool = PagedKVPool(cfg.pool_sets, cfg.set_size,
                       n_targets=cfg.n_targets,
                       copy_out=lambda tag: (),
                       copy_in=lambda tag, data: None,
                       flush_trigger=cfg.flush_trigger)
    rec.attach_pool(pool)

    res = FleetResult(trace=np.empty((0, 4)))
    sessions: dict[int, _Session] = {}
    next_sid = 0
    pages_cap = min(cfg.pages_max, PAGES_PER_SESSION_CAP - 1)
    steps = int(round(cfg.duration_s / cfg.dt))
    two_pi = 2.0 * np.pi

    def alloc_page(tag: int):
        page, evicted_tag, evicted_dirty = pool.alloc.alloc(tag)
        if page is not None and evicted_tag is not None and evicted_dirty:
            # blocking spill of the dirty victim: a synchronous device
            # write the executor never sees — record it explicitly
            pool.offload_now_evicted(evicted_tag, page, lambda t, p: ())
            rec.record_direct(evicted_tag, TRACE_WRITE,
                              tenants.get(
                                  evicted_tag // PAGES_PER_SESSION_CAP, 0))
        return page

    def fill_page(s: _Session) -> None:
        tag = s.sid * PAGES_PER_SESSION_CAP + s.pages_done
        if alloc_page(tag) is None:
            res.alloc_failures += 1
            return
        s.tags.append(tag)
        s.pages_done += 1
        res.tokens_total += cfg.page_tokens
        pool.alloc.mark_full(tag)
        pool.note_page_full(pool.alloc.set_of(tag))

    def finish(s: _Session) -> None:
        pool.alloc.set_pinned(s.tags, False)
        pool.alloc.free(s.tags)        # queued offloads now discard stale
        for tag in s.tags:
            pool.host_tier.pop(tag, None)
        res.sessions_completed += 1

    for step in range(steps):
        t = step * cfg.dt
        # diurnal/bursty arrivals
        rate = cfg.arrival_rate * (1.0 + cfg.diurnal_amp * np.sin(
            two_pi * cfg.diurnal_periods * t / cfg.duration_s))
        for _ in range(int(rng.poisson(max(rate, 0.0) * cfg.dt))):
            tenant = (TENANT_INTERACTIVE
                      if rng.random() < cfg.interactive_frac
                      else TENANT_BATCH)
            n_pages = int(rng.integers(cfg.pages_min, pages_cap + 1))
            sessions[next_sid] = _Session(next_sid, tenant, n_pages)
            tenants[next_sid] = tenant
            next_sid += 1
            res.sessions_started += 1

        done: list[int] = []
        for sid, s in sessions.items():
            if s.state == "preempted":
                if rng.random() < cfg.resume_prob:
                    # pages evicted while preempted come back from the
                    # host tier: HIGH-priority fetches (recorded)
                    lost = [tag for tag in s.tags
                            if pool.alloc.where.get(tag) is None
                            and tag in pool.host_tier]
                    for tag in lost:
                        alloc_page(tag)
                    if lost:
                        pool.fetch(lost)
                    pool.alloc.set_pinned(s.tags, True)
                    s.state = "active"
                continue
            per_step = (cfg.tokens_per_step_interactive
                        if s.tenant == TENANT_INTERACTIVE
                        else cfg.tokens_per_step_batch)
            s.tokens_accum += per_step
            while s.tokens_accum >= cfg.page_tokens \
                    and s.pages_done < s.n_pages:
                s.tokens_accum -= cfg.page_tokens
                fill_page(s)
            if s.pages_done >= s.n_pages:
                done.append(sid)
            elif s.tenant == TENANT_INTERACTIVE \
                    and rng.random() < cfg.preempt_prob:
                pool.alloc.set_pinned(s.tags, False)
                s.state = "preempted"
        for sid in done:
            finish(sessions.pop(sid))

        rec.advance(cfg.dt)
        rec.pump(cfg.pump_per_device)

    # close out: abandon the stragglers (their queued offloads go stale),
    # then serve the remaining backlog on the still-advancing clock
    for sid in list(sessions):
        finish(sessions.pop(sid))
    guard = 0
    while rec.backlog() and guard < 100000:
        rec.advance(cfg.dt)
        rec.pump(max(cfg.pump_per_device, 2))
        guard += 1
    pool.close()

    stats = pool.alloc.stats
    res.trace = rec.to_array()
    res.offloads = stats.offloads
    res.fetches = stats.fetches
    res.stale_discards = stats.stale_discards   # == rec.stale_discards()
    res.dirty_evictions = stats.dirty_evictions
    res.meta = {
        "n_targets": cfg.n_targets,
        "page_tokens": cfg.page_tokens,
        "duration_s": cfg.duration_s,
        "seed": seed,
    }
    return res

"""JAX data plane for paged serving: one decode step over the page pool.

Mirrors ``models/transformer.decode_step`` but attention layers read/write
the shared HBM page pool through a per-sequence page table instead of dense
per-sequence ring buffers. Mamba/conv states stay per-row ("pinned pages",
DESIGN.md §5). The whole step jits; the pool arrays are donated so page
writes are in-place on device.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.moe import moe_ffn
from repro.models.ssm import init_mamba_state, mamba_decode_step
from repro.kernels import ops as kops
from repro.kernels.ref import paged_attention_ref


def init_pools(cfg: ModelConfig, *, num_pages: int, page_size: int,
               max_batch: int):
    """Device arrays: per block position, stacked over n_blocks."""
    dt = jnp.dtype(cfg.dtype)
    nb = cfg.n_blocks
    pools = []
    for spec in cfg.block:
        if spec.kind == "attn":
            shape = (nb, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
            pools.append({"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)})
        else:
            st = init_mamba_state(max_batch, cfg)
            pools.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (nb, *a.shape)).copy(), st))
    return tuple(pools)


def make_paged_decode_step(cfg: ModelConfig, *, page_size: int,
                           use_kernel: bool = False, mesh=None):
    """Returns jitted ``step(params, pools, tokens, lengths, page_table,
    active) -> (logits, new_pools)``.

    tokens: (B, 1); lengths: (B,); page_table: (B, max_pages) pool ids;
    active: (B,) bool — inactive rows compute but their state is masked out.
    """

    def attn_sublayer(x, p, layer_pool, lengths, page_table, active, positions):
        b = x.shape[0]
        h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (x @ p["wq"]).reshape(b, 1, h, hd)
        k = (x @ p["wk"]).reshape(b, 1, kvh, hd)
        v = (x @ p["wv"]).reshape(b, 1, kvh, hd)
        if cfg.qk_norm:
            q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
            k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
        if cfg.rope_theta:
            q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        pids = page_table[jnp.arange(b), lengths // page_size]     # (B,)
        offs = lengths % page_size
        # inactive rows park their write in the reserved scratch page 0 slot?
        # No: mask by writing their own current values (no-op via where).
        k_pool = layer_pool["k"].at[pids, offs].set(
            jnp.where(active[:, None, None], k[:, 0],
                      layer_pool["k"][pids, offs]))
        v_pool = layer_pool["v"].at[pids, offs].set(
            jnp.where(active[:, None, None], v[:, 0],
                      layer_pool["v"][pids, offs]))
        if use_kernel:
            out = kops.paged_attention(q[:, 0], k_pool, v_pool, page_table,
                                       lengths + 1, softcap=cfg.attn_softcap)
            out = out.reshape(b, 1, h * hd)
        else:
            out = paged_attention_ref(q[:, 0], k_pool, v_pool, page_table,
                                      lengths + 1,
                                      softcap=cfg.attn_softcap)
            out = out.reshape(b, 1, h * hd)
        return out @ p["wo"], {"k": k_pool, "v": v_pool}

    def step(params, pools, tokens, lengths, page_table, active):
        b = tokens.shape[0]
        positions = lengths[:, None]
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(lengths[:, None, None], (b, 3, 1))
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        def block_body(xc, scanned):
            block_params, layer_pools = scanned
            new_pools = []
            for i, spec in enumerate(cfg.block):
                p = block_params[i]
                h = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
                if spec.kind == "attn":
                    h, np_ = attn_sublayer(h, p["attn"], layer_pools[i],
                                           lengths, page_table, active,
                                           positions)
                else:
                    h, st = mamba_decode_step(h, layer_pools[i], p["attn"], cfg)
                    np_ = jax.tree.map(
                        lambda new, old: jnp.where(
                            active.reshape((-1,) + (1,) * (new.ndim - 1)),
                            new, old), st, layer_pools[i])
                if cfg.post_norms:
                    h = L.rmsnorm(h, p["post_norm"], cfg.norm_eps)
                xc = xc + h
                new_pools.append(np_)
                if spec.ffn == "mlp":
                    hh = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                    hh = L.mlp(hh, p["mlp"], cfg.act)
                    if cfg.post_norms:
                        hh = L.rmsnorm(hh, p["ffn_post_norm"], cfg.norm_eps)
                    xc = xc + hh
                elif spec.ffn == "moe":
                    hh = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                    hh, _ = moe_ffn(hh, p["moe"], cfg, mesh=mesh)
                    xc = xc + hh
            return xc, tuple(new_pools)

        x, new_pools = jax.lax.scan(block_body, x, (params["blocks"], pools))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = T.logits_fn(params, x, cfg)
        return logits, new_pools

    # NOTE: pools are NOT donated. The background flusher DMAs pages out of
    # the previous pool arrays concurrently with the next step; donation
    # would let XLA reuse those buffers mid-copy. On TPU the production fix
    # is a device-side staging copy of flush candidates + donation; here
    # (CPU, correctness-first) we keep the immutable-buffer guarantee.
    return jax.jit(step)

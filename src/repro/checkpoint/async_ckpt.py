"""Async sharded checkpointing built on the paper's machinery.

The mapping (DESIGN.md §2): checkpoint *chunks* are the dirty pages, storage
targets are the SSDs, and the training loop is the application whose writes
must never block.

  * every ``save_async(step, tree)`` marks all (changed) chunks dirty and
    enqueues LOW-priority writes on per-target dual queues (``core.io_queues``)
    — the train loop continues immediately (paper: flush requests fill the
    long queues);
  * a queued write is discarded at the queue head iff a NEWER save for the
    same chunk has been enqueued (paper §3.3.2 staleness: the page was
    re-dirtied and a fresher flush exists — writing the old version is
    wasted bandwidth);
  * ``restore`` reads run HIGH priority and overtake any backlog of writes
    (paper §3.2: reserved slots keep reads fast under write pressure);
  * a per-target budget (``max_inflight``) plus deep software queues absorb
    stragglers: one slow target (overloaded NFS shard, throttled disk) delays
    only its own chunks — exactly the unsynchronized-GC scenario.

A checkpoint step is COMMITTED by writing ``manifest-<step>.json`` after its
last chunk lands; superseded steps simply never commit (their chunks were
discarded), so restore always sees a consistent, complete step. Chunk files
are content-addressed by (key, step) so elastic restore to a different mesh
just re-shards the global arrays.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable

import numpy as np

import jax

from repro.core.io_queues import HIGH, LOW, IOExecutor, IORequest


def flatten_with_paths(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out[key] = leaf
    return out


class CheckpointManager:
    """Async checkpoint writer/reader over ``n_targets`` storage targets."""

    def __init__(self, directory: str | Path, *, n_targets: int = 4,
                 max_inflight: int = 2, reserved: int = 1, keep: int = 2,
                 write_delay: float = 0.0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.n_targets = n_targets
        self.keep = keep
        # reserved slots must leave room for LOW-priority writes to flow
        reserved = max(0, min(reserved, max_inflight - 1))
        self._write_delay = write_delay          # fault-injection for tests
        self._lock = threading.Lock()
        self._latest_enqueued: dict[str, int] = {}   # key -> newest step queued
        self._remaining: dict[int, int] = {}         # step -> chunks not landed
        self._treedef = None
        self._committed: list[int] = []
        self.stats = {"written": 0, "discarded_stale": 0, "bytes": 0,
                      "saves": 0, "restores": 0}
        self._exec = IOExecutor(n_targets, self._do_io,
                                max_inflight=max_inflight, reserved=reserved)

    # ------------------------------------------------------------------ io
    def _chunk_path(self, key: str, step: int) -> Path:
        safe = key.replace("/", "__")
        return self.dir / f"{safe}-{step}.npy"

    def _do_io(self, target: int, payload: dict) -> None:
        if payload["op"] == "write":
            if self._write_delay:
                time.sleep(self._write_delay)
            np.save(self._chunk_path(payload["key"], payload["step"]),
                    payload["data"], allow_pickle=False)
            with self._lock:
                self.stats["written"] += 1
                self.stats["bytes"] += payload["data"].nbytes
                step = payload["step"]
                if step in self._remaining:
                    self._remaining[step] -= 1
                    if self._remaining[step] == 0:
                        self._commit(step)
        else:                                     # read (HIGH priority)
            payload["out"][payload["key"]] = np.load(
                self._chunk_path(payload["key"], payload["step"]))
            payload["done"].release()

    def _commit(self, step: int) -> None:
        """Called with lock held: all chunks of ``step`` are durable."""
        manifest = {"step": step,
                    "keys": sorted(k for k, s in self._latest_enqueued.items())}
        tmp = self.dir / f".manifest-{step}.tmp"
        tmp.write_text(json.dumps(manifest))
        tmp.rename(self.dir / f"manifest-{step}.json")
        self._committed.append(step)
        del self._remaining[step]
        # retention: drop chunk files of old committed steps
        for old in self._committed[:-self.keep]:
            for f in self.dir.glob(f"*-{old}.npy"):
                f.unlink(missing_ok=True)
            (self.dir / f"manifest-{old}.json").unlink(missing_ok=True)
        self._committed = self._committed[-self.keep:]

    # --------------------------------------------------------------- save
    def save_async(self, step: int, tree: Any,
                   changed: set[str] | None = None) -> None:
        """Enqueue a checkpoint of ``tree`` at ``step``; returns immediately.

        ``changed`` optionally names the dirty chunks (default: all) — the
        dirty-chunk filter for e.g. frozen towers or unchanged EMA copies.
        """
        with self._lock:
            if step in self._remaining or step in self._committed:
                return                       # duplicate save for this step
        host = {k: np.asarray(v) for k, v in flatten_with_paths(tree).items()
                if changed is None or k in changed}
        with self._lock:
            self.stats["saves"] += 1
            self._remaining[step] = len(host)
            for k in host:
                self._latest_enqueued[k] = step

        def make_stale(key: str, s: int) -> Callable[[Any], bool]:
            def is_stale(_payload) -> bool:
                with self._lock:
                    return self._latest_enqueued.get(key, s) > s
            return is_stale

        def on_discard(payload) -> None:
            with self._lock:
                self.stats["discarded_stale"] += 1
                step_d = payload["step"]
                if step_d in self._remaining:
                    self._remaining[step_d] -= 1
                    # a superseded step never commits; forget it when drained
                    if self._remaining[step_d] <= 0:
                        del self._remaining[step_d]

        for i, (k, v) in enumerate(sorted(host.items())):
            self._exec.submit(
                self._target_of(k),
                IORequest(payload={"op": "write", "key": k, "step": step,
                                   "data": v},
                          priority=LOW,
                          is_stale=make_stale(k, step),
                          on_discard=on_discard))

    def _target_of(self, key: str) -> int:
        return hash(key) % self.n_targets

    # ------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(int(p.stem.split("-")[1])
                       for p in self.dir.glob("manifest-*.json"))
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Blocking restore into the structure of ``like`` (a pytree or tree
        of ShapeDtypeStructs). Reads are HIGH priority: they overtake any
        write backlog. ``shardings`` optionally re-shards onto a (different)
        mesh — elastic resume."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        manifest = json.loads((self.dir / f"manifest-{step}.json").read_text())
        out: dict[str, np.ndarray] = {}
        sem = threading.Semaphore(0)
        for k in manifest["keys"]:
            self._exec.submit(
                self._target_of(k),
                IORequest(payload={"op": "read", "key": k, "step": step,
                                   "out": out, "done": sem},
                          priority=HIGH))
        for _ in manifest["keys"]:
            sem.acquire()
        self.stats["restores"] += 1

        leaves_like = flatten_with_paths(like)
        ordered = [out[k] for k in leaves_like]
        treedef = jax.tree_util.tree_structure(like)
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(shardings)
            arrs = [jax.device_put(a, s) for a, s in zip(ordered, sh_leaves)]
        else:
            arrs = [jax.numpy.asarray(a) for a in ordered]
        return step, jax.tree_util.tree_unflatten(treedef, arrs)

    # ------------------------------------------------------------- control
    def barrier(self, timeout: float = 120.0) -> bool:
        """Write barrier (paper §3.4): returns once every enqueued write has
        either landed or been discarded stale — everything submitted before
        the barrier is durable (or superseded) before anything after it.
        The paper's caveat holds: frequent barriers forfeit the flusher's
        reordering freedom, so use them at consistency points only."""
        return self._exec.drain(timeout)

    def wait_for_commit(self, step: int, timeout: float = 60.0) -> bool:
        deadline = time.monotonic() + timeout
        path = self.dir / f"manifest-{step}.json"
        while time.monotonic() < deadline:
            if path.exists():
                return True
            time.sleep(0.01)
        return False

    def drain(self, timeout: float = 120.0) -> bool:
        return self._exec.drain(timeout)

    def close(self) -> None:
        self._exec.shutdown()

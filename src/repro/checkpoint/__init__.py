from .async_ckpt import CheckpointManager, flatten_with_paths

__all__ = ["CheckpointManager", "flatten_with_paths"]

"""Workload scenario layer shared by both simulators and the benchmarks.

Every workload is an ``OpSource``: a stateful stream of :class:`Op` records
(LBA, read/write, earliest-issue time, tenant). The simulators pull from a
source instead of sampling inline, so the same scenario definitions drive the
raw-array simulator (``gc_sim.ArraySim``), the full SAFS stack
(``safs_sim.SAFSSim``), and the benchmark sweeps.

Scenarios:

* ``uniform`` / ``zipf`` — the paper's 4 KB random workloads (§4).
* ``sequential`` — N evenly spaced sequential cursors round-robined, the
  classic multi-stream sequential writer.
* ``bursty`` — on/off arrival gating around any base source; during OFF
  windows ``Op.at`` jumps to the next ON window (open-loop lulls).
* ``mixed`` — two tenants: a Zipf-hot reader tenant and a random writer
  tenant, mixed by ``writer_frac``.
* ``delete_burst`` — trim-heavy file-delete bursts: the base op stream with
  a contiguous run of TRIMs (one unlinked file's extent) every N ops.
* ``trace`` — replay of a ``(time, lba, op)`` array, looping with a time
  offset when exhausted.

Closed-loop sources emit ``at=0.0`` (issue immediately); open-loop sources
(bursty, trace) emit a real earliest-issue time and the simulators honour it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

# trace op codes for TraceSource arrays
TRACE_READ = 0
TRACE_WRITE = 1

# op kinds (``Op.kind``). KIND_AUTO derives the kind from ``is_read`` so every
# pre-existing two-argument ``Op(lba, is_read)`` call site keeps working; only
# sources that emit the newer command types set an explicit kind.
KIND_AUTO = -1
OP_READ = 0
OP_WRITE = 1
OP_TRIM = 2      # ATA TRIM / NVMe deallocate: invalidates the LBA in the FTL
OP_REBUILD = 3   # RAID rebuild unit (one stripe row), planned by core/raid.py


def _mix64(x: int) -> int:
    """splitmix64 finalizer — cheap stateless permutation-ish hash."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class ZipfSampler:
    """Bounded Zipf(s) over ranks 1..N: exact CDF for the head, continuous
    generalized-harmonic inverse for the tail. O(1) memory in N."""

    HEAD = 4096

    def __init__(self, n: int, s: float, rng: np.random.Generator):
        self.n, self.s, self.rng = n, s, rng
        head = min(self.HEAD, n)
        ranks = np.arange(1, head + 1, dtype=np.float64)
        head_w = ranks ** (-s)
        self._head_cum = np.cumsum(head_w)
        h_head = float(self._head_cum[-1])
        if n > head:
            # integral_{head+.5}^{n+.5} x^-s dx
            if abs(s - 1.0) < 1e-9:
                tail = np.log((n + 0.5) / (head + 0.5))
            else:
                tail = ((n + 0.5) ** (1 - s) - (head + 0.5) ** (1 - s)) / (1 - s)
        else:
            tail = 0.0
        self._h_head, self._h_total = h_head, h_head + tail
        self._p_head = h_head / self._h_total

    def sample(self) -> int:
        u = self.rng.random()
        if u < self._p_head or self.n <= self.HEAD:
            t = u * self._h_total
            return int(np.searchsorted(self._head_cum, t) + 1)
        rem = u * self._h_total - self._h_head
        head, s = min(self.HEAD, self.n), self.s
        if abs(s - 1.0) < 1e-9:
            k = (head + 0.5) * np.exp(rem)
        else:
            k = ((head + 0.5) ** (1 - s) + rem * (1 - s)) ** (1.0 / (1 - s))
        return int(min(max(k, head + 1), self.n))


class Op(NamedTuple):
    """One application request. ``at`` is the earliest simulated time the op
    may issue (0.0 = immediately, the closed-loop case).

    A NamedTuple, not a frozen dataclass: one ``Op`` is built per simulated
    request, and frozen-dataclass ``__init__`` (``object.__setattr__`` per
    field) costs ~4x a tuple construction on the DES hot path.

    ``kind`` defaults to ``KIND_AUTO`` (derive read/write from ``is_read``),
    so existing callers and sources are untouched; TRIM and rebuild sources
    set it explicitly. Resolve with ``op_kind``."""

    lba: int
    is_read: bool
    at: float = 0.0
    tenant: int = 0
    kind: int = KIND_AUTO

    def op_kind(self) -> int:
        k = self.kind
        if k >= 0:
            return k
        return OP_READ if self.is_read else OP_WRITE


class OpSource:
    """Stateful stream of operations."""

    def next_op(self, now: float) -> Op:
        raise NotImplementedError


class UniformSource(OpSource):
    """Uniform random LBAs. ``trim_frac`` turns that fraction of the writes
    into TRIM commands; at the default 0.0 the extra RNG draw is skipped so
    the op stream (and every seeded golden) is bit-identical to the
    pre-TRIM source."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, trim_frac: float = 0.0):
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.trim_frac = trim_frac
        # bound methods: next_op runs once per simulated request
        self._randint = rng.integers
        self._random = rng.random

    def next_op(self, now: float) -> Op:
        lba = int(self._randint(self.n_live))
        is_read = self._random() < self.read_frac
        if not is_read and self.trim_frac and self._random() < self.trim_frac:
            return Op(lba, False, kind=OP_TRIM)
        return Op(lba, is_read)


class ZipfSource(OpSource):
    """Zipf ranks in a virtual LBA space ``virtual_scale`` times the live
    space, hashed onto physical LBAs (keeps the head below one SSD's fair
    share, as at real scale)."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, s: float = 0.99,
                 virtual_scale: int = 512, trim_frac: float = 0.0):
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.trim_frac = trim_frac
        self._zipf = ZipfSampler(n_live * virtual_scale, s, rng)
        self._random = rng.random

    def next_op(self, now: float) -> Op:
        lba = _mix64(self._zipf.sample()) % self.n_live
        is_read = self._random() < self.read_frac
        if not is_read and self.trim_frac and self._random() < self.trim_frac:
            return Op(lba, False, kind=OP_TRIM)
        return Op(lba, is_read)


class SequentialSource(OpSource):
    """``streams`` sequential cursors spaced evenly over the LBA space,
    advanced round-robin (multi-stream sequential I/O). Wraps at the end."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, streams: int = 4):
        streams = max(1, streams)
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.cursors = [(i * n_live) // streams for i in range(streams)]
        self._next = 0

    def next_op(self, now: float) -> Op:
        i = self._next
        self._next = (i + 1) % len(self.cursors)
        lba = self.cursors[i]
        self.cursors[i] = (lba + 1) % self.n_live
        return Op(lba, bool(self.rng.random() < self.read_frac), tenant=i)


class BurstySource(OpSource):
    """On/off arrival gating around a base source. Time is divided into
    ``on + off`` periods; ops requested during an OFF window are deferred
    (``at`` = start of the next ON window)."""

    def __init__(self, base: OpSource, on_time: float, off_time: float):
        assert on_time > 0.0 and off_time >= 0.0
        self.base = base
        self.on, self.off = on_time, off_time

    def next_op(self, now: float) -> Op:
        op = self.base.next_op(now)
        period = self.on + self.off
        phase = now % period
        if phase >= self.on:  # in an OFF window: defer to the next period
            op = op._replace(at=max(op.at, now + (period - phase)))
        return op


class DeleteBurstSource(OpSource):
    """Trim-heavy file-delete bursts around a base source.

    Models a filesystem unlinking files: the op stream is the base source's,
    but every ``every``-th op slot fires a burst — a contiguous run of
    ``pages`` TRIMs starting at a ``pages``-aligned random LBA (one deleted
    file's extent lowered to an LBA-range deallocate) — so consecutive
    bursts are separated by ``every - 1`` base ops. A run is truncated at
    the end of the LBA space (the tail extent may be short) rather than
    wrapped, so every run stays contiguous and aligned. The extra RNG draw
    (the extent start) happens only when a burst fires, and the scenario is
    opt-in (``scenario="delete_burst"``) — every other scenario's op stream
    (and every seeded golden) is untouched."""

    def __init__(self, base: OpSource, n_live: int, rng: np.random.Generator,
                 pages: int = 64, every: int = 256):
        assert n_live > 0
        self.base, self.n_live, self.rng = base, n_live, rng
        self.pages = max(1, min(pages, n_live))
        self.every = max(1, every)
        self._count = 0
        self._run_left = 0
        self._run_lba = 0

    def next_op(self, now: float) -> Op:
        if self._run_left:
            self._run_left -= 1
            lba = self._run_lba
            self._run_lba = lba + 1
            return Op(lba, False, kind=OP_TRIM)
        self._count += 1
        if self._count >= self.every:
            self._count = 0
            start = int(self.rng.integers(self.n_live))
            start -= start % self.pages          # file extents are aligned
            end = min(start + self.pages, self.n_live)   # short tail extent
            self._run_left = end - start - 1
            self._run_lba = start + 1
            return Op(start, False, kind=OP_TRIM)
        return self.base.next_op(now)


class MixedTenantSource(OpSource):
    """Multi-tenant mix: tenant 0 is a Zipf-hot reader, tenant 1 a random
    writer; each op is drawn from one tenant with probability
    ``writer_frac`` of being the writer."""

    def __init__(self, reader: OpSource, writer: OpSource,
                 rng: np.random.Generator, writer_frac: float = 0.5):
        self.reader, self.writer = reader, writer
        self.rng, self.writer_frac = rng, writer_frac

    def next_op(self, now: float) -> Op:
        if self.rng.random() < self.writer_frac:
            return self.writer.next_op(now)._replace(tenant=1)
        return self.reader.next_op(now)._replace(tenant=0)


class TraceSource(OpSource):
    """Replay a ``(time, lba, op)`` array (op: 0 = read, 1 = write).

    Rows must be time-sorted. LBAs are folded onto the live space with
    ``mod n_live``. When the trace is exhausted it loops, shifting times by
    the trace span so arrival times stay monotone."""

    def __init__(self, trace: np.ndarray, n_live: int, time_scale: float = 1.0):
        trace = np.asarray(trace)
        assert trace.ndim == 2 and trace.shape[1] == 3, \
            "trace must be (n, 3): time, lba, op"
        assert trace.shape[0] > 0, "empty trace"
        self.times = trace[:, 0].astype(np.float64) * time_scale
        self.lbas = trace[:, 1].astype(np.int64) % n_live
        self.ops = trace[:, 2].astype(np.int64)
        # loop period: span plus one mean inter-arrival gap
        span = float(self.times[-1] - self.times[0])
        self.period = span + max(span / max(len(self.times) - 1, 1), 1e-9)
        self._i = 0
        self._offset = 0.0

    def next_op(self, now: float) -> Op:
        if self._i >= len(self.times):
            self._i = 0
            self._offset += self.period
        i = self._i
        self._i += 1
        return Op(int(self.lbas[i]), self.ops[i] == TRACE_READ,
                  at=self._offset + float(self.times[i]))


def source_for(wl, n_live: int, rng: np.random.Generator,
               trace: Optional[np.ndarray] = None) -> OpSource:
    """Build the OpSource for a workload spec (``gc_sim.Workload`` or
    ``safs_sim.SAFSWorkload`` — anything with the scenario attributes)."""
    scenario = getattr(wl, "scenario", "random")
    read_frac = getattr(wl, "read_frac", 0.0)
    trim_frac = getattr(wl, "trim_frac", 0.0)

    def random_base():
        if getattr(wl, "dist", "uniform") == "zipf":
            return ZipfSource(n_live, rng, read_frac,
                              s=getattr(wl, "zipf_s", 0.99),
                              virtual_scale=getattr(wl, "virtual_scale", 512),
                              trim_frac=trim_frac)
        return UniformSource(n_live, rng, read_frac, trim_frac=trim_frac)

    if scenario == "random":
        return random_base()
    if scenario == "sequential":
        return SequentialSource(n_live, rng, read_frac,
                                streams=getattr(wl, "seq_streams", 4))
    if scenario == "bursty":
        return BurstySource(random_base(),
                            on_time=getattr(wl, "burst_on", 2e-3),
                            off_time=getattr(wl, "burst_off", 2e-3))
    if scenario == "mixed":
        reader = ZipfSource(n_live, rng, read_frac=1.0,
                            s=getattr(wl, "zipf_s", 0.99),
                            virtual_scale=getattr(wl, "virtual_scale", 512))
        writer = UniformSource(n_live, rng, read_frac=0.0)
        return MixedTenantSource(reader, writer, rng,
                                 writer_frac=getattr(wl, "writer_frac", 0.5))
    if scenario == "delete_burst":
        return DeleteBurstSource(random_base(), n_live, rng,
                                 pages=getattr(wl, "delete_pages", 64),
                                 every=getattr(wl, "delete_every", 256))
    if scenario == "trace":
        assert trace is not None, "scenario='trace' needs a trace array"
        return TraceSource(trace, n_live)
    raise ValueError(f"unknown workload scenario: {scenario!r}")

"""Workload scenario layer shared by both simulators and the benchmarks.

Every workload is an ``OpSource``: a stateful stream of :class:`Op` records
(LBA, read/write, earliest-issue time, tenant). The simulators pull from a
source instead of sampling inline, so the same scenario definitions drive the
raw-array simulator (``gc_sim.ArraySim``), the full SAFS stack
(``safs_sim.SAFSSim``), and the benchmark sweeps.

Scenarios (the **pattern suite** — every name is an entry in the
``PATTERNS`` registry, dispatched by :func:`source_for`):

* ``uniform`` / ``zipf`` — the paper's 4 KB random workloads (§4).
* ``sequential`` — N evenly spaced sequential cursors round-robined, the
  classic multi-stream sequential writer.
* ``strided`` — fixed-stride scan: lane-interleaved so the whole LBA space
  is covered even when ``gcd(stride, n_live) > 1``.
* ``snake`` — boustrophedon scan: ascending sweep, then descending, turning
  at the ends without repeating the endpoint.
* ``hot_cold`` — two-zone skew: a ``hot_frac`` slice of the space receives
  ``hot_ops`` of the operations (the skew split is configurable, unlike the
  fixed-head Zipf).
* ``write_then_read`` — write a span sequentially, read it back, advance to
  the next span (checkpoint-then-verify / producer-consumer footprints).
* ``bursty`` — on/off arrival gating around any base source; during OFF
  windows ``Op.at`` jumps to the next ON window (open-loop lulls).
* ``mixed`` — two tenants: a Zipf-hot reader tenant and a random writer
  tenant, mixed by ``writer_frac``.
* ``delete_burst`` — trim-heavy file-delete bursts: the base op stream with
  a contiguous run of TRIMs (one unlinked file's extent) every N ops.
* ``trace`` — replay of a ``(time, lba, op)`` array, looping with a time
  offset when exhausted.

Phased scenarios: :class:`PhasedScenario` chains :class:`Phase` records
(precondition → burst → drain → measure), each with its own op budget and
source; the simulators' ``run_phased`` drives one measurement window per
phase. This replaces ad-hoc prefill flags: preconditioning is just an
unmeasured leading phase.

Closed-loop sources emit ``at=0.0`` (issue immediately); open-loop sources
(bursty, trace) emit a real earliest-issue time and the simulators honour it.
"""
from __future__ import annotations

from dataclasses import dataclass
from math import gcd
from typing import Callable, NamedTuple, Optional, Sequence

import numpy as np

# trace op codes for TraceSource arrays
TRACE_READ = 0
TRACE_WRITE = 1

# Trace schema (see ``TraceSource``): a trace is a float64 array of shape
# (n, 3) — ``(time, lba, op)`` — or (n, 4) with a trailing integer tenant
# column. ``serving.trace_shim`` emits/loads the versioned ``.npz`` form.
TRACE_VERSION = 1
TRACE_COLUMNS = ("time", "lba", "op", "tenant")

# op kinds (``Op.kind``). KIND_AUTO derives the kind from ``is_read`` so every
# pre-existing two-argument ``Op(lba, is_read)`` call site keeps working; only
# sources that emit the newer command types set an explicit kind.
KIND_AUTO = -1
OP_READ = 0
OP_WRITE = 1
OP_TRIM = 2      # ATA TRIM / NVMe deallocate: invalidates the LBA in the FTL
OP_REBUILD = 3   # RAID rebuild unit (one stripe row), planned by core/raid.py


def _mix64(x: int) -> int:
    """splitmix64 finalizer — cheap stateless permutation-ish hash."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class ZipfSampler:
    """Bounded Zipf(s) over ranks 1..N: exact CDF for the head, continuous
    generalized-harmonic inverse for the tail. O(1) memory in N."""

    HEAD = 4096

    def __init__(self, n: int, s: float, rng: np.random.Generator):
        self.n, self.s, self.rng = n, s, rng
        head = min(self.HEAD, n)
        ranks = np.arange(1, head + 1, dtype=np.float64)
        head_w = ranks ** (-s)
        self._head_cum = np.cumsum(head_w)
        h_head = float(self._head_cum[-1])
        if n > head:
            # integral_{head+.5}^{n+.5} x^-s dx
            if abs(s - 1.0) < 1e-9:
                tail = np.log((n + 0.5) / (head + 0.5))
            else:
                tail = ((n + 0.5) ** (1 - s) - (head + 0.5) ** (1 - s)) / (1 - s)
        else:
            tail = 0.0
        self._h_head, self._h_total = h_head, h_head + tail
        self._p_head = h_head / self._h_total

    def sample(self) -> int:
        u = self.rng.random()
        if u < self._p_head or self.n <= self.HEAD:
            t = u * self._h_total
            return int(np.searchsorted(self._head_cum, t) + 1)
        rem = u * self._h_total - self._h_head
        head, s = min(self.HEAD, self.n), self.s
        if abs(s - 1.0) < 1e-9:
            k = (head + 0.5) * np.exp(rem)
        else:
            k = ((head + 0.5) ** (1 - s) + rem * (1 - s)) ** (1.0 / (1 - s))
        return int(min(max(k, head + 1), self.n))


class Op(NamedTuple):
    """One application request. ``at`` is the earliest simulated time the op
    may issue (0.0 = immediately, the closed-loop case).

    A NamedTuple, not a frozen dataclass: one ``Op`` is built per simulated
    request, and frozen-dataclass ``__init__`` (``object.__setattr__`` per
    field) costs ~4x a tuple construction on the DES hot path.

    ``kind`` defaults to ``KIND_AUTO`` (derive read/write from ``is_read``),
    so existing callers and sources are untouched; TRIM and rebuild sources
    set it explicitly. Resolve with ``op_kind``."""

    lba: int
    is_read: bool
    at: float = 0.0
    tenant: int = 0
    kind: int = KIND_AUTO

    def op_kind(self) -> int:
        k = self.kind
        if k >= 0:
            return k
        return OP_READ if self.is_read else OP_WRITE


class OpSource:
    """Stateful stream of operations."""

    def next_op(self, now: float) -> Op:
        raise NotImplementedError


class UniformSource(OpSource):
    """Uniform random LBAs. ``trim_frac`` turns that fraction of the writes
    into TRIM commands; at the default 0.0 the extra RNG draw is skipped so
    the op stream (and every seeded golden) is bit-identical to the
    pre-TRIM source."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, trim_frac: float = 0.0):
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.trim_frac = trim_frac
        # bound methods: next_op runs once per simulated request
        self._randint = rng.integers
        self._random = rng.random

    def next_op(self, now: float) -> Op:
        lba = int(self._randint(self.n_live))
        is_read = self._random() < self.read_frac
        if not is_read and self.trim_frac and self._random() < self.trim_frac:
            return Op(lba, False, kind=OP_TRIM)
        return Op(lba, is_read)


class ZipfSource(OpSource):
    """Zipf ranks in a virtual LBA space ``virtual_scale`` times the live
    space, hashed onto physical LBAs (keeps the head below one SSD's fair
    share, as at real scale)."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, s: float = 0.99,
                 virtual_scale: int = 512, trim_frac: float = 0.0):
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.trim_frac = trim_frac
        self._zipf = ZipfSampler(n_live * virtual_scale, s, rng)
        self._random = rng.random

    def next_op(self, now: float) -> Op:
        lba = _mix64(self._zipf.sample()) % self.n_live
        is_read = self._random() < self.read_frac
        if not is_read and self.trim_frac and self._random() < self.trim_frac:
            return Op(lba, False, kind=OP_TRIM)
        return Op(lba, is_read)


class SequentialSource(OpSource):
    """``streams`` sequential cursors spaced evenly over the LBA space,
    advanced round-robin (multi-stream sequential I/O). Wraps at the end."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, streams: int = 4):
        streams = max(1, streams)
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.cursors = [(i * n_live) // streams for i in range(streams)]
        self._next = 0

    def next_op(self, now: float) -> Op:
        i = self._next
        self._next = (i + 1) % len(self.cursors)
        lba = self.cursors[i]
        self.cursors[i] = (lba + 1) % self.n_live
        return Op(lba, bool(self.rng.random() < self.read_frac), tenant=i)


class BurstySource(OpSource):
    """On/off arrival gating around a base source. Time is divided into
    ``on + off`` periods; ops requested during an OFF window are deferred
    (``at`` = start of the next ON window)."""

    def __init__(self, base: OpSource, on_time: float, off_time: float):
        assert on_time > 0.0 and off_time >= 0.0
        self.base = base
        self.on, self.off = on_time, off_time

    def next_op(self, now: float) -> Op:
        op = self.base.next_op(now)
        period = self.on + self.off
        phase = now % period
        if phase >= self.on:  # in an OFF window: defer to the next period
            op = op._replace(at=max(op.at, now + (period - phase)))
        return op


class DeleteBurstSource(OpSource):
    """Trim-heavy file-delete bursts around a base source.

    Models a filesystem unlinking files: the op stream is the base source's,
    but every ``every``-th op slot fires a burst — a contiguous run of
    ``pages`` TRIMs starting at a ``pages``-aligned random LBA (one deleted
    file's extent lowered to an LBA-range deallocate) — so consecutive
    bursts are separated by ``every - 1`` base ops. A run is truncated at
    the end of the LBA space (the tail extent may be short) rather than
    wrapped, so every run stays contiguous and aligned. The extra RNG draw
    (the extent start) happens only when a burst fires, and the scenario is
    opt-in (``scenario="delete_burst"``) — every other scenario's op stream
    (and every seeded golden) is untouched."""

    def __init__(self, base: OpSource, n_live: int, rng: np.random.Generator,
                 pages: int = 64, every: int = 256):
        assert n_live > 0
        self.base, self.n_live, self.rng = base, n_live, rng
        self.pages = max(1, min(pages, n_live))
        self.every = max(1, every)
        self._count = 0
        self._run_left = 0
        self._run_lba = 0

    def next_op(self, now: float) -> Op:
        if self._run_left:
            self._run_left -= 1
            lba = self._run_lba
            self._run_lba = lba + 1
            return Op(lba, False, kind=OP_TRIM)
        self._count += 1
        if self._count >= self.every:
            self._count = 0
            start = int(self.rng.integers(self.n_live))
            start -= start % self.pages          # file extents are aligned
            end = min(start + self.pages, self.n_live)   # short tail extent
            self._run_left = end - start - 1
            self._run_lba = start + 1
            return Op(start, False, kind=OP_TRIM)
        return self.base.next_op(now)


class MixedTenantSource(OpSource):
    """Multi-tenant mix: tenant 0 is a Zipf-hot reader, tenant 1 a random
    writer; each op is drawn from one tenant with probability
    ``writer_frac`` of being the writer."""

    def __init__(self, reader: OpSource, writer: OpSource,
                 rng: np.random.Generator, writer_frac: float = 0.5):
        self.reader, self.writer = reader, writer
        self.rng, self.writer_frac = rng, writer_frac

    def next_op(self, now: float) -> Op:
        if self.rng.random() < self.writer_frac:
            return self.writer.next_op(now)._replace(tenant=1)
        return self.reader.next_op(now)._replace(tenant=0)


class TraceSource(OpSource):
    """Replay a ``(time, lba, op[, tenant])`` array (op: 0 = read, 1 = write).

    Schema (``TRACE_COLUMNS``, version ``TRACE_VERSION``): column 0 is the
    arrival time in seconds (scaled by ``time_scale``), column 1 the page
    LBA (folded onto the live space with ``mod n_live``), column 2 the op
    code (``TRACE_READ``/``TRACE_WRITE``), and the optional column 3 an
    integer tenant id carried onto ``Op.tenant`` (3-column traces replay
    bit-identically to before, tenant 0). Tenant ids map to ``QosPolicy``
    tenants positionally — tenant ``t`` in the trace is accounted against
    ``qos.tenants[t]``'s SLO/weight spec at replay time.

    Rows must be time-sorted. When the trace is exhausted it loops,
    shifting times by the trace span (plus one mean gap) so arrival times
    stay monotone. An empty trace is allowed at construction (a sharded
    replay may hand a shard zero records); drawing from one raises.

    Worked emit -> replay round trip::

        from repro.serving.trace_shim import ServingTraceRecorder, save_trace
        rec = ServingTraceRecorder(n_targets=4)
        pool = make_pool(...); rec.attach_pool(pool)   # swap in recorder
        ... drive the pool ...                         # offloads / fetches
        save_trace("kv.npz", rec.to_array())

        from repro.serving.trace_shim import load_trace
        wl = Workload(scenario="trace")
        r = ArraySim(4, ssd, 0.6, wl, seed=1,
                     trace=load_trace("kv.npz"), qos=policy).run(20000)
    """

    def __init__(self, trace: np.ndarray, n_live: int, time_scale: float = 1.0):
        trace = np.asarray(trace)
        assert trace.ndim == 2 and trace.shape[1] in (3, 4), \
            "trace must be (n, 3) time/lba/op or (n, 4) time/lba/op/tenant"
        self.has_tenants = trace.shape[1] == 4
        self.times = trace[:, 0].astype(np.float64) * time_scale
        self.lbas = trace[:, 1].astype(np.int64) % max(n_live, 1)
        self.ops = trace[:, 2].astype(np.int64)
        self.tenants = (trace[:, 3].astype(np.int64) if self.has_tenants
                        else np.zeros(len(self.times), dtype=np.int64))
        # loop period: span plus one mean inter-arrival gap
        if len(self.times):
            span = float(self.times[-1] - self.times[0])
            self.period = span + max(span / max(len(self.times) - 1, 1),
                                     1e-9)
        else:
            self.period = 1e-9
        self._i = 0
        self._offset = 0.0

    def next_op(self, now: float) -> Op:
        if self._i >= len(self.times):
            if not len(self.times):
                raise RuntimeError("next_op() on an empty trace — give "
                                   "empty shards a zero op budget")
            self._i = 0
            self._offset += self.period
        i = self._i
        self._i += 1
        return Op(int(self.lbas[i]), self.ops[i] == TRACE_READ,
                  at=self._offset + float(self.times[i]),
                  tenant=int(self.tenants[i]))


def shard_trace(trace: np.ndarray, n_ssds: int,
                sizes: Sequence[int]) -> list:
    """Partition trace records across shards by owning device.

    On a JBOD array of ``n_ssds`` members a folded LBA lands on device
    ``lba % n_ssds`` (``gc_sim`` fast loop / ``safs_sim`` tag mapping), so
    the shard covering devices ``[lo, lo + sz)`` owns exactly the records
    whose device falls in that range. Records keep their original relative
    order — a trace never reorders within a device group — and the LBA is
    remapped to the shard-local space as ``(lba // n_ssds) * sz +
    (device - lo)``, which preserves both the owning device (now ``device
    - lo``) and the per-device page index modulo the live space. The
    identity holds for any fold the shard applies later because
    ``n_live`` is always a multiple of the member count.

    Time/op/tenant columns pass through untouched; slices of a (n, 4)
    trace keep the tenant column. Returns one (possibly empty) array per
    shard."""
    arr = np.asarray(trace, dtype=np.float64)
    assert arr.ndim == 2 and arr.shape[1] in (3, 4), "bad trace shape"
    devs = arr[:, 1].astype(np.int64) % n_ssds
    out, lo = [], 0
    for sz in sizes:
        mask = (devs >= lo) & (devs < lo + sz)
        sub = arr[mask].copy()
        if len(sub):
            raw = sub[:, 1].astype(np.int64)
            sub[:, 1] = (raw // n_ssds) * sz + (devs[mask] - lo)
        out.append(sub)
        lo += sz
    return out


class StridedSource(OpSource):
    """Fixed-stride scan: successive LBAs are ``stride`` apart.

    When ``gcd(stride, n_live) > 1`` a naive ``(lba + stride) % n_live``
    cursor only ever visits ``n_live / gcd`` addresses. This source is
    lane-interleaved instead: it walks one residue class ("lane") of the
    stride to completion (``n_live // gcd`` steps), then advances to the
    next lane, so ``n_live`` consecutive ops cover every LBA exactly once
    regardless of the stride. Deterministic except for the read/write coin
    (one RNG draw per op, same as SequentialSource)."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, stride: int = 64):
        assert n_live > 0
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.stride = max(1, stride) % n_live or n_live
        self._g = gcd(self.stride, n_live)
        self._steps_per_lane = n_live // self._g
        self._lane = 0
        self._step = 0

    def next_op(self, now: float) -> Op:
        lba = (self._lane + self._step * self.stride) % self.n_live
        self._step += 1
        if self._step >= self._steps_per_lane:
            self._step = 0
            self._lane = (self._lane + 1) % self._g
        return Op(lba, bool(self.rng.random() < self.read_frac))

    def footprint(self, n_ops: int) -> int:
        """Distinct LBAs touched by the next ``n_ops`` ops (full coverage
        after ``n_live`` ops — the property the lane interleave buys)."""
        return min(n_ops, self.n_live)


class SnakeSource(OpSource):
    """Boustrophedon scan: ascend 0..n-1, then descend n-1..0, turning at
    the ends. The endpoint is *not* repeated at a turn (after emitting
    ``n-1`` ascending, the next op is ``n-2`` descending), so every window
    of ``n_live`` ops still covers all but one LBA and no LBA is issued
    twice in a row — the pattern elevators and disk schedulers produce."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0):
        assert n_live > 0
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self._pos = 0
        self._dir = 1

    def next_op(self, now: float) -> Op:
        lba = self._pos
        n = self.n_live
        if n > 1:
            nxt = lba + self._dir
            if nxt >= n or nxt < 0:          # turn without repeating the end
                self._dir = -self._dir
                nxt = lba + self._dir
            self._pos = nxt
        return Op(lba, bool(self.rng.random() < self.read_frac))


class HotColdSource(OpSource):
    """Two-zone skew with a configurable split: a ``hot_frac`` slice of the
    LBA space receives ``hot_ops`` of the operations; the cold remainder
    gets the rest. Unlike Zipf (fixed head shape, tunable only via ``s``),
    the skew *split* itself is a parameter — e.g. 10% of space / 90% of ops
    is the classic hot/cold GC stress configuration.

    Exactly three RNG draws per op (zone coin, offset, read/write coin), so
    the stream is seed-deterministic and cheap. The hot zone is the low end
    of the LBA space (``[0, hot_pages)``); physical placement skew is the
    point, so no hashing is applied."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 read_frac: float = 0.0, hot_frac: float = 0.1,
                 hot_ops: float = 0.9):
        assert n_live > 0
        assert 0.0 < hot_frac < 1.0, "hot_frac must split the space"
        assert 0.0 <= hot_ops <= 1.0
        self.n_live, self.rng, self.read_frac = n_live, rng, read_frac
        self.hot_frac, self.hot_ops = hot_frac, hot_ops
        self.hot_pages = min(max(1, int(n_live * hot_frac)), n_live - 1)
        self._random = rng.random
        self._randint = rng.integers

    def next_op(self, now: float) -> Op:
        if self._random() < self.hot_ops:
            lba = int(self._randint(self.hot_pages))
        else:
            lba = self.hot_pages + int(self._randint(self.n_live
                                                     - self.hot_pages))
        return Op(lba, bool(self._random() < self.read_frac))


class WriteThenReadSource(OpSource):
    """Write a ``span``-page extent sequentially, then read it back in the
    same order, then advance to the next extent (wrapping at the end of the
    LBA space). Models checkpoint-then-verify and producer-consumer
    pipelines: every read hits a page written exactly ``span`` ops earlier,
    the worst case for a write-back cache's dirty/clean churn. Fully
    deterministic — zero RNG draws (``read_frac`` is implied 0.5)."""

    def __init__(self, n_live: int, rng: np.random.Generator,
                 span: int = 4096):
        assert n_live > 0
        self.n_live = n_live
        self.span = max(1, min(span, n_live))
        self._base = 0
        self._i = 0
        self._reading = False

    def next_op(self, now: float) -> Op:
        lba = (self._base + self._i) % self.n_live
        op = Op(lba, self._reading)
        self._i += 1
        if self._i >= self.span:
            self._i = 0
            if self._reading:                 # extent verified: advance
                self._base = (self._base + self.span) % self.n_live
            self._reading = not self._reading
        return op


@dataclass(frozen=True)
class Phase:
    """One phase of a :class:`PhasedScenario`.

    ``ops`` is the measured op budget; ``warmup`` ops run first inside the
    phase without being measured (both counted against the phase's slice of
    the stream). ``measure=False`` marks a preconditioning / drain phase:
    the simulator runs it but reports no results row for it."""

    name: str
    source: OpSource
    ops: int
    warmup: int = 0
    measure: bool = True

    @property
    def total_ops(self) -> int:
        return self.ops + self.warmup


class PhasedScenario(OpSource):
    """Chain of :class:`Phase` records behaving as a single ``OpSource``.

    Op identity never leaks across a boundary: exactly ``phase.total_ops``
    ops are drawn from each phase's source before the next phase starts —
    except the *last* phase, which is open-ended (closed-loop simulators
    overshoot their op budget by the in-flight spawn count, and those tail
    ops must come from somewhere; they come from the final phase's source
    and are excluded from its measurement window by the simulator).

    The per-phase measurement windows come from the simulators'
    ``run_phased``, which drives one ``run(phase.ops, phase.warmup)`` call
    per phase and swaps measurement state at each boundary; this class only
    guarantees the op-stream side of that contract."""

    def __init__(self, phases: Sequence[Phase]):
        phases = list(phases)
        assert phases, "PhasedScenario needs at least one phase"
        for ph in phases[:-1]:
            assert ph.total_ops > 0, \
                f"non-final phase {ph.name!r} needs a positive op budget"
        self.phases = phases
        self._idx = 0
        self._left = phases[0].total_ops
        self._src = phases[0].source

    @property
    def current_phase(self) -> Phase:
        return self.phases[self._idx]

    def next_op(self, now: float) -> Op:
        if self._left <= 0 and self._idx < len(self.phases) - 1:
            self._idx += 1
            ph = self.phases[self._idx]
            self._left = ph.total_ops
            self._src = ph.source
        self._left -= 1
        return self._src.next_op(now)


# ---------------------------------------------------------------------------
# Scenario registry
#
# ``source_for`` dispatches through PATTERNS: scenario name -> builder taking
# ``(wl, n_live, rng, trace)``. Legacy scenarios are thin aliases over the
# suite — their builders construct exactly the sources the old if-chain did
# (no extra RNG draws at construction), so every seeded golden is
# bit-identical. Downstream code can add patterns with @register_pattern.
# ---------------------------------------------------------------------------

PATTERNS: dict = {}

Builder = Callable[..., OpSource]


def register_pattern(name: str) -> Callable[[Builder], Builder]:
    """Register ``builder(wl, n_live, rng, trace) -> OpSource`` under a
    scenario name. Re-registration replaces (lets tests stub patterns)."""

    def deco(builder: Builder) -> Builder:
        PATTERNS[name] = builder
        return builder

    return deco


def _random_base(wl, n_live: int, rng: np.random.Generator) -> OpSource:
    read_frac = getattr(wl, "read_frac", 0.0)
    trim_frac = getattr(wl, "trim_frac", 0.0)
    if getattr(wl, "dist", "uniform") == "zipf":
        return ZipfSource(n_live, rng, read_frac,
                          s=getattr(wl, "zipf_s", 0.99),
                          virtual_scale=getattr(wl, "virtual_scale", 512),
                          trim_frac=trim_frac)
    return UniformSource(n_live, rng, read_frac, trim_frac=trim_frac)


@register_pattern("random")
def _build_random(wl, n_live, rng, trace):
    return _random_base(wl, n_live, rng)


@register_pattern("sequential")
def _build_sequential(wl, n_live, rng, trace):
    return SequentialSource(n_live, rng, getattr(wl, "read_frac", 0.0),
                            streams=getattr(wl, "seq_streams", 4))


@register_pattern("strided")
def _build_strided(wl, n_live, rng, trace):
    return StridedSource(n_live, rng, getattr(wl, "read_frac", 0.0),
                         stride=getattr(wl, "stride", 64))


@register_pattern("snake")
def _build_snake(wl, n_live, rng, trace):
    return SnakeSource(n_live, rng, getattr(wl, "read_frac", 0.0))


@register_pattern("hot_cold")
def _build_hot_cold(wl, n_live, rng, trace):
    return HotColdSource(n_live, rng, getattr(wl, "read_frac", 0.0),
                         hot_frac=getattr(wl, "hot_frac", 0.1),
                         hot_ops=getattr(wl, "hot_ops", 0.9))


@register_pattern("write_then_read")
def _build_write_then_read(wl, n_live, rng, trace):
    return WriteThenReadSource(n_live, rng,
                               span=getattr(wl, "wtr_span", 4096))


@register_pattern("bursty")
def _build_bursty(wl, n_live, rng, trace):
    return BurstySource(_random_base(wl, n_live, rng),
                        on_time=getattr(wl, "burst_on", 2e-3),
                        off_time=getattr(wl, "burst_off", 2e-3))


@register_pattern("mixed")
def _build_mixed(wl, n_live, rng, trace):
    reader = ZipfSource(n_live, rng, read_frac=1.0,
                        s=getattr(wl, "zipf_s", 0.99),
                        virtual_scale=getattr(wl, "virtual_scale", 512))
    writer = UniformSource(n_live, rng, read_frac=0.0)
    return MixedTenantSource(reader, writer, rng,
                             writer_frac=getattr(wl, "writer_frac", 0.5))


@register_pattern("delete_burst")
def _build_delete_burst(wl, n_live, rng, trace):
    return DeleteBurstSource(_random_base(wl, n_live, rng), n_live, rng,
                             pages=getattr(wl, "delete_pages", 64),
                             every=getattr(wl, "delete_every", 256))


@register_pattern("trace")
def _build_trace(wl, n_live, rng, trace):
    assert trace is not None, "scenario='trace' needs a trace array"
    return TraceSource(trace, n_live,
                       time_scale=getattr(wl, "trace_time_scale", 1.0))


def source_for(wl, n_live: int, rng: np.random.Generator,
               trace: Optional[np.ndarray] = None) -> OpSource:
    """Build the OpSource for a workload spec (``gc_sim.Workload`` or
    ``safs_sim.SAFSWorkload`` — anything with the scenario attributes).
    Dispatches through the ``PATTERNS`` registry."""
    scenario = getattr(wl, "scenario", "random")
    builder = PATTERNS.get(scenario)
    if builder is None:
        raise ValueError(f"unknown workload scenario: {scenario!r}")
    return builder(wl, n_live, rng, trace)

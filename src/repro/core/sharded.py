"""Process-sharded SSD-array simulation: 100+ SSD sweeps on multicore hosts.

``ArraySim``'s per-device state (FTL, NCQ, GC) is fully independent across
SSDs; the only cross-SSD coupling is the host window W (and the submission
streams that carry it). ``ShardedArraySim`` exploits that: it partitions the
array's SSDs across worker processes, giving each shard

* a proportional slice of the host window ``w_total`` (and of ``n_streams``),
* a proportional slice of the measure/warmup budget, and
* its own decorrelated RNG seed (``_mix64`` of the base seed and shard id),

then merges the per-shard ``ArrayResults``: throughput counters add, per-SSD
arrays concatenate in shard order, and latency percentiles are computed
EXACTLY over the concatenation of every shard's raw samples (no percentile
averaging).

Modeling note: sharding replaces ONE global window W by ``n_shards``
independent windows of W/n_shards. Per-SSD queue bounds, NCQ service, and GC
dynamics are untouched, but W-level coupling across shards (a GC-paused SSD
in shard 0 starving streams that also feed shard 1) is not modeled — use one
stream-partitioned workload (``n_streams >= n_shards``), where the
approximation is exact in distribution, for paper-style sweeps. Results are
deterministic for a fixed ``(seed, n_shards)`` but differ numerically from
the unsharded ``ArraySim`` (different RNG streams).

Array layouts (``core/raid.py``): a striped layout couples the SSDs of one
stripe group, so the partition is **stripe-group-aware** — shard sizes are
multiples of ``layout.shard_unit`` (the group size) and a stripe group never
spans shards. Each shard then simulates whole, independent RAID groups, which
keeps serial == sharded bit-identical exactly as for JBOD. A grouped layout
is required to shard at all (``group=None`` couples the whole array into one
stripe set, forcing a single shard).

The worker pool persists across ``run()`` calls (module-level), so the
per-worker prefill snapshot cache (``gc_sim._PREFILL_CACHE``) keeps paying
off across the points of a sweep.
"""
from __future__ import annotations

import atexit
import multiprocessing as mp
import os
import sys
import time
from dataclasses import replace

import numpy as np

from .engine import LatencySummary
from .gc_sim import ArrayResults, ArraySim, SSDParams, Workload
from .monitor import merge_monitor
from .safs_sim import SAFSResults, SAFSSim, SAFSWorkload
from .telemetry import merge_telemetry
from .workloads import _mix64, shard_trace

__all__ = ["ShardedArraySim", "ShardedSAFSSim", "shard_sizes",
           "merge_results", "merge_safs_results", "pool_samples",
           "shard_seed"]


def shard_sizes(n_ssds: int, n_shards: int) -> list[int]:
    """Balanced partition: sizes differ by at most one, larger shards first."""
    n_shards = max(1, min(n_shards, n_ssds))
    base, rem = divmod(n_ssds, n_shards)
    return [base + 1] * rem + [base] * (n_shards - rem)


def shard_seed(seed: int, shard: int) -> int:
    """Decorrelated per-shard seed (stable across runs and platforms). The
    base seed is mixed before XORing the shard id so nearby (seed, shard)
    pairs cannot collide through low-bit cancellation."""
    return _mix64(_mix64(seed & 0xFFFFFFFFFFFFFFFF) ^ (shard + 1))


def _split_budget(total: int, sizes: list[int], n_ssds: int) -> list[int]:
    """Proportional integer split of an op budget (each shard gets >= 1,
    except for a zero budget, which stays zero everywhere — run(0) must be
    a no-op exactly like ``ArraySim.run(0)``)."""
    if total <= 0:
        return [0] * len(sizes)
    return [max(1, (total * sz) // n_ssds) for sz in sizes]


def _split_budget_by(total: int, weights: list[int]) -> list[int]:
    """Proportional split by arbitrary weights — used by the trace scenario,
    where a shard's fair budget share follows its RECORD count, not its
    device count. A zero-weight shard gets a hard 0 (its trace slice is
    empty and must never be pulled from); every positive-weight shard gets
    at least 1."""
    if total <= 0 or sum(weights) <= 0:
        return [0] * len(weights)
    wsum = sum(weights)
    return [max(1, (total * w) // wsum) if w else 0 for w in weights]


def _shard_workload(wl: Workload, sz: int, n_ssds: int) -> Workload:
    """Scale the host-side window and stream count to the shard's share."""
    return replace(
        wl,
        w_total=max(1, (wl.w_total * sz) // n_ssds),
        n_streams=max(1, (wl.n_streams * sz) // n_ssds),
    )


def _shard_qos(qos, sz: int, n_ssds: int):
    """Scale per-tenant token-bucket rate caps to the shard's share of the
    array. Weights and SLOs are ratios/targets and stay shard-local, but a
    ``rate_iops`` cap is an ARRAY-WIDE budget: shipping it verbatim would
    have every shard enforce the full cap and admit up to
    ``n_shards * rate_iops`` array-wide."""
    if qos is None or all(s.rate_iops is None for s in qos.tenants):
        return qos
    tenants = tuple(
        replace(s, rate_iops=s.rate_iops * sz / n_ssds)
        if s.rate_iops is not None else s
        for s in qos.tenants)
    return replace(qos, tenants=tenants)


def _check_telemetry(telemetry, faults) -> None:
    """Fail fast in the parent on a bad telemetry spec (the per-shard
    ``ArraySim``/``SAFSSim`` constructors re-validate in the workers, but a
    worker traceback is a worse error surface)."""
    if telemetry is None:
        return
    from .telemetry import TelemetrySpec
    if not isinstance(telemetry, TelemetrySpec):
        raise TypeError(f"telemetry must be a core.telemetry.TelemetrySpec, "
                        f"got {type(telemetry).__name__}")


def _check_monitor(monitor) -> None:
    """Same fail-fast-in-the-parent rationale as ``_check_telemetry``."""
    if monitor is None:
        return
    from .monitor import MonitorSpec
    if not isinstance(monitor, MonitorSpec):
        raise TypeError(f"monitor must be a core.monitor.MonitorSpec, "
                        f"got {type(monitor).__name__}")


def _run_shard(args):
    (sz, ssd, occupancy, wl, seed, measure_ops, warmup_ops,
     prefill_cache, layout, qos, gc, faults, telemetry, monitor,
     trace) = args
    sim = ArraySim(sz, ssd, occupancy, wl, seed=seed,
                   prefill_cache=prefill_cache, layout=layout, qos=qos, gc=gc,
                   faults=faults, telemetry=telemetry, monitor=monitor,
                   trace=trace)
    res = sim.run(measure_ops, warmup_ops)
    return (res, sim.last_latency, sim.last_stall, sim.last_tenant_latency,
            sim.last_gc_wait)


def pool_samples(samples: list[np.ndarray | None]) -> np.ndarray:
    """Concatenate the shards' latency samples (skipping empty shards)."""
    live = [s for s in samples if s is not None and s.size]
    return np.concatenate(live) if live else np.empty(0)


def merge_results(parts: list[ArrayResults], pooled: np.ndarray,
                  stall_pooled: np.ndarray | None = None,
                  tenant_pooled: "dict[int, np.ndarray] | None" = None,
                  qos=None,
                  gc_wait_pooled: np.ndarray | None = None) -> ArrayResults:
    """Merge per-shard results: rates and layout counters add, per-SSD
    arrays concatenate, write-amplification ratios are recomputed from the
    pooled counters (never averaged), and latency / stripe-stall percentiles
    are exact over the pooled raw samples (``pool_samples``). With a
    ``qos`` policy, the per-tenant block merges the same way: tenant ops and
    throughput add, tenant percentiles are exact over ``tenant_pooled``
    (``qos.pool_tenant_samples``), shares/share_error are recomputed from
    the pooled op counts, and ``throttle_time`` reports the worst shard.

    Telemetry block (``core/telemetry.py``): per-shard series concatenate
    along the device axis on the common tick-grid prefix, spans merge by
    ``(time, seq, shard)`` with device ids re-based, and budget sums add
    exactly (``telemetry.merge_telemetry``) — deterministic, so
    ``parallel=False`` == ``parallel=True`` bit-identical.

    GC-coordination block (``core/gc_coord.py``): each shard runs its own
    coordinator (stripe groups never span shards, so neither do leases);
    ``stagger_wait`` percentiles are exact over ``gc_wait_pooled``,
    ``gc_overlap_frac`` merges span-weighted, ``idle_gc_frac`` merges
    weighted by each shard's GC seconds, counters add, and ``util_min`` is
    the min over the concatenated per-SSD utilizations.

    Faults block (``core/faults.py``): fault domains never span shards
    (``slice_policy``), so the per-shard blocks merge by plain counter
    addition / sentinel adoption (``merge_fault_stats``).

    Monitor block (``core/monitor.py``): per-shard alert streams merge by
    ``(time, seq, shard)`` with device ids (and ``:devN`` root-cause
    suffixes) re-based to array-wide ids, then seq renumbered over the
    merged order; rule counts add (``monitor.merge_monitor``) —
    deterministic, so ``parallel=False`` == ``parallel=True`` bit-identical
    alert for alert."""
    if pooled.size:
        p50, p95, p99 = np.percentile(pooled, [50.0, 95.0, 99.0])
        summ = LatencySummary(mean=float(pooled.mean()), p50=float(p50),
                              p95=float(p95), p99=float(p99), n=pooled.size)
    else:
        summ = LatencySummary.empty()
    if stall_pooled is not None and stall_pooled.size:
        stall_mean = float(stall_pooled.mean())
        stall_p99 = float(np.percentile(stall_pooled, 99.0))
    else:
        stall_mean = stall_p99 = 0.0
    util = np.concatenate([p.util for p in parts])
    logical_writes = sum(p.logical_writes for p in parts)
    child_writes = sum(p.child_writes for p in parts)
    ftl_writes = sum(p.ftl_writes for p in parts)
    ftl_gc_copies = sum(p.ftl_gc_copies for p in parts)
    parity_wa = child_writes / logical_writes if logical_writes else 1.0
    gc_wa = (ftl_writes + ftl_gc_copies) / ftl_writes if ftl_writes else 1.0
    tstats, share_error = None, 0.0
    if qos is not None:
        from .qos import merge_tenant_stats
        tstats, share_error = merge_tenant_stats(
            qos, [p.tenant_stats for p in parts if p.tenant_stats],
            tenant_pooled or {})
    if gc_wait_pooled is not None and gc_wait_pooled.size:
        wait_mean = float(gc_wait_pooled.mean())
        wait_p99 = float(np.percentile(gc_wait_pooled, 99.0))
    else:
        wait_mean = wait_p99 = 0.0
    span_total = sum(p.sim_time for p in parts)
    overlap = sum(p.gc_overlap_frac * p.sim_time for p in parts) \
        / span_total if span_total > 0 else 0.0
    # per-shard GC seconds (window accounting) weight the idle fraction
    gc_secs = [float(p.gc_pause_frac.sum()) * p.sim_time for p in parts]
    gc_sec_total = sum(gc_secs)
    idle_frac = sum(p.idle_gc_frac * w for p, w in zip(parts, gc_secs)) \
        / gc_sec_total if gc_sec_total > 0 else 0.0
    return ArrayResults(
        iops=float(sum(p.iops for p in parts)),
        per_ssd_iops=np.concatenate([p.per_ssd_iops for p in parts]),
        read_iops=float(sum(p.read_iops for p in parts)),
        write_iops=float(sum(p.write_iops for p in parts)),
        util=util,
        sim_time=max(p.sim_time for p in parts),
        gc_pause_frac=np.concatenate([p.gc_pause_frac for p in parts]),
        mean_latency=summ.mean,
        p50_latency=summ.p50,
        p95_latency=summ.p95,
        p99_latency=summ.p99,
        events=sum(p.events for p in parts),
        wall_s=max(p.wall_s for p in parts),
        layout=parts[0].layout if parts else "jbod",
        parity_wa=parity_wa,
        gc_wa=gc_wa,
        array_wa=parity_wa * gc_wa,
        stripe_stall_mean=stall_mean,
        stripe_stall_p99=stall_p99,
        util_spread=float(util.max() - util.min()) if util.size else 0.0,
        logical_writes=logical_writes,
        child_writes=child_writes,
        child_reads=sum(p.child_reads for p in parts),
        parity_writes=sum(p.parity_writes for p in parts),
        full_stripe_rows=sum(p.full_stripe_rows for p in parts),
        rmw_ops=sum(p.rmw_ops for p in parts),
        degraded_reads=sum(p.degraded_reads for p in parts),
        rebuild_rows=sum(p.rebuild_rows for p in parts),
        trims=sum(p.trims for p in parts),
        trim_parity_skipped=sum(p.trim_parity_skipped for p in parts),
        steered_reads=sum(p.steered_reads for p in parts),
        ftl_writes=ftl_writes,
        ftl_gc_copies=ftl_gc_copies,
        tenant_stats=tstats,
        share_error=share_error,
        gc_policy=parts[0].gc_policy if parts else "reactive",
        gc_overlap_frac=overlap,
        stagger_wait_mean=wait_mean,
        stagger_wait_p99=wait_p99,
        util_min=float(util.min()) if util.size else 0.0,
        gc_starts=sum(p.gc_starts for p in parts),
        gc_forced=sum(p.gc_forced for p in parts),
        idle_gc_frac=idle_frac,
        faults=_merge_faults(parts),
        telemetry=merge_telemetry([p.telemetry for p in parts]),
        gc_lease_skipped=sum(p.gc_lease_skipped for p in parts),
        monitor=merge_monitor([p.monitor for p in parts]),
    )


def _merge_faults(parts) -> "dict | None":
    from .faults import merge_fault_stats
    return merge_fault_stats([p.faults for p in parts])


# one persistent worker pool, shared by every ShardedArraySim in the process
_POOL: tuple[int, "mp.pool.Pool"] | None = None


def _start_method() -> str:
    """'fork' is the fast path, but forking a parent whose JAX runtime is
    already initialized (multithreaded) can deadlock the workers — fall back
    to 'spawn' once jax has been imported. Spawned workers re-import this
    package, so the repo's ``src`` must be on PYTHONPATH (as the tier-1
    command sets it)."""
    methods = mp.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return "fork"
    return "spawn"


def _get_pool(n_procs: int) -> "mp.pool.Pool":
    global _POOL
    if _POOL is not None and _POOL[0] == n_procs:
        return _POOL[1]
    if _POOL is not None:
        _POOL[1].terminate()
    pool = mp.get_context(_start_method()).Pool(processes=n_procs)
    _POOL = (n_procs, pool)
    return pool


def _shutdown_pool() -> None:
    global _POOL
    if _POOL is not None:
        _POOL[1].terminate()
        _POOL = None


atexit.register(_shutdown_pool)


class ShardedArraySim:
    """Partition an ``ArraySim`` array across worker processes and merge the
    results. Drop-in for sweep drivers: same constructor shape as
    ``ArraySim`` plus sharding knobs, same ``run() -> ArrayResults``.

    ``n_shards=None`` uses ``min(cpu_count, shard units)``. ``parallel=False``
    runs the same shard decomposition serially in-process (identical
    results — used to test the merge path and as the fallback where
    multiprocessing is unavailable).

    With a striped ``layout`` the partition is stripe-group-aware: shard
    sizes are multiples of the layout's group size, so a stripe group never
    spans shards and each shard simulates whole independent RAID groups."""

    def __init__(self, n_ssds: int, ssd: SSDParams = SSDParams(),
                 occupancy: float = 0.6, workload: Workload = Workload(),
                 seed: int = 0, n_shards: int | None = None,
                 parallel: bool = True, prefill_cache: bool = True,
                 layout=None, qos=None, gc=None, faults=None,
                 telemetry=None, monitor=None, trace=None):
        from .raid import JBODLayout
        self.layout = layout if layout is not None else JBODLayout()
        self.trace = trace           # (n, 3|4) array for scenario="trace" —
                                     # sliced per shard by owning device
                                     # (workloads.shard_trace)
        if workload.scenario == "trace":
            if trace is None:
                raise ValueError("scenario='trace' needs a trace array")
            if not self.layout.trivial:
                raise ValueError("sharded trace replay supports only "
                                 "trivial (JBOD) layouts: the device-"
                                 "partitioning rule lba % n assumes no "
                                 "striping")
        elif trace is not None:
            raise ValueError("trace= requires workload.scenario='trace'")
        self.qos = qos               # QosPolicy | None (frozen — ships to
                                     # workers; each shard runs its own
                                     # scheduler over its slice)
        self.gc = gc                 # GcPolicy | None (frozen — ships to
                                     # workers; each shard runs its own
                                     # coordinator: stripe groups never span
                                     # shards, so neither do GC leases)
        self.faults = faults         # FaultPolicy | None (frozen — validated
                                     # against the FULL array here, then
                                     # sliced per shard: a fault domain is one
                                     # device, so it never spans shards)
        if faults is not None:
            from .faults import validate_fault_policy
            validate_fault_policy(faults, n_ssds, layout=self.layout)
        self.telemetry = telemetry   # TelemetrySpec | None (frozen — ships
                                     # to workers; per-shard results merge
                                     # via telemetry.merge_telemetry)
        _check_telemetry(telemetry, faults)
        self.monitor = monitor       # MonitorSpec | None (frozen — ships to
                                     # workers; each shard runs its own
                                     # HealthMonitor over its slice, alert
                                     # streams merge via monitor.merge_monitor)
        _check_monitor(monitor)
        unit = self.layout.shard_unit(n_ssds)   # SSDs per stripe group
        if n_ssds % unit:
            raise ValueError(f"n_ssds={n_ssds} not a multiple of the "
                             f"layout's stripe group ({unit})")
        units = n_ssds // unit
        if n_shards is None:
            n_shards = min(os.cpu_count() or 1, units)
        self.n = n_ssds
        self.p = ssd
        self.wl = workload
        self.occupancy = occupancy
        self.seed = seed
        self.parallel = parallel
        self.prefill_cache = prefill_cache
        # partition whole stripe groups, then scale back to SSD counts
        self.sizes = [u * unit for u in shard_sizes(units, n_shards)]
        if gc is not None and len(self.sizes) > 1:
            from .gc_coord import StaggeredGc
            if isinstance(gc, StaggeredGc) and gc.scope == "array":
                # coordinators are per-shard, so an "array"-wide lease would
                # silently become per-shard (n_shards x max_concurrent
                # concurrent collectors) — refuse instead of mislabeling
                raise ValueError(
                    "StaggeredGc(scope='array') couples every SSD through "
                    "one lease pool and cannot be sharded; use "
                    "scope='group' (lease per stripe group) or n_shards=1")
        self.last_latency: np.ndarray | None = None
        self.last_stall: np.ndarray | None = None
        self.last_tenant_latency: dict[int, np.ndarray] | None = None
        self.last_gc_wait: np.ndarray | None = None
        self.last_telemetry = None   # merged TelemetryResult of the last run
        self.last_monitor = None     # merged MonitorResult of the last run
        self.last_wall_s = 0.0       # observed wall clock of the last run()

    def _shard_args(self, measure_ops: int, warmup_ops: int | None):
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        traces = [None] * len(self.sizes)
        if self.trace is not None:
            # budgets follow each shard's record count: a shard owning few
            # (or no) trace records must not be asked to replay more ops
            # than its slice offers at the recorded rate
            traces = shard_trace(self.trace, self.n, self.sizes)
            counts = [len(t) for t in traces]
            measures = _split_budget_by(measure_ops, counts)
            warmups = _split_budget_by(warmup_ops, counts) \
                if warmup_ops else [0] * len(self.sizes)
        else:
            measures = _split_budget(measure_ops, self.sizes, self.n)
            warmups = _split_budget(warmup_ops, self.sizes, self.n) \
                if warmup_ops else [0] * len(self.sizes)
        faults = [None] * len(self.sizes)
        if self.faults is not None:
            from .faults import slice_policy
            lo = 0
            for k, sz in enumerate(self.sizes):
                faults[k] = slice_policy(self.faults, lo, lo + sz)
                lo += sz
        return [
            (sz, self.p, self.occupancy,
             _shard_workload(self.wl, sz, self.n),
             shard_seed(self.seed, k), measures[k], warmups[k],
             self.prefill_cache, self.layout,
             _shard_qos(self.qos, sz, self.n), self.gc, faults[k],
             self.telemetry, self.monitor, traces[k])
            for k, sz in enumerate(self.sizes)
        ]

    def run(self, measure_ops: int, warmup_ops: int | None = None) -> ArrayResults:
        args = self._shard_args(measure_ops, warmup_ops)
        t0 = time.perf_counter()
        if self.parallel and len(args) > 1:
            pool = _get_pool(min(len(args), os.cpu_count() or 1))
            out = pool.map(_run_shard, args, chunksize=1)
        else:
            out = [_run_shard(a) for a in args]
        self.last_wall_s = time.perf_counter() - t0
        parts = [r for r, _, _, _, _ in out]
        pooled = pool_samples([s for _, s, _, _, _ in out])
        stall_pooled = pool_samples([s for _, _, s, _, _ in out])
        gc_wait_pooled = pool_samples([s for _, _, _, _, s in out])
        tenant_pooled = None
        if self.qos is not None:
            from .qos import pool_tenant_samples
            tenant_pooled = pool_tenant_samples([tl for _, _, _, tl, _ in out])
        merged = merge_results(parts, pooled, stall_pooled, tenant_pooled,
                               self.qos, gc_wait_pooled)
        self.last_latency = pooled if pooled.size else None
        self.last_stall = stall_pooled if stall_pooled.size else None
        self.last_tenant_latency = tenant_pooled
        self.last_gc_wait = gc_wait_pooled if gc_wait_pooled.size else None
        self.last_telemetry = merged.telemetry
        self.last_monitor = merged.monitor
        return merged


# ---------------------------------------------------------------------------
# Sharded SAFS
#
# The SA-cache's only cross-device coupling is the set hash: one cache set
# may hold tags of several devices, but a tag's SET never depends on another
# device's state, and the flusher's per-device pending queues are already
# independent. Partitioning the array by device group therefore partitions
# the cache and the flusher cleanly: each shard owns a full SAFSSim (its own
# NumpySACache over its own device group's LBA space, its own
# DirtyPageFlusher and dual queues), so no cache set and no flush queue ever
# spans device groups. Concurrency (the closed-loop in-flight population)
# and cache capacity both split proportionally, so the merged system has the
# same aggregate cache-to-data ratio and offered load as the serial config.
# ---------------------------------------------------------------------------


def _shard_safs_workload(wl: SAFSWorkload, sz: int, n_ssds: int) -> SAFSWorkload:
    """Scale the closed-loop concurrency to the shard's share."""
    return replace(wl, concurrency=max(1, (wl.concurrency * sz) // n_ssds))


def _run_safs_shard(args):
    (sz, ssd, occupancy, wl, cache_frac, use_flusher, clean_first,
     score_threshold, seed, measure_ops, warmup_ops, faults,
     telemetry, monitor, trace) = args
    sim = SAFSSim(sz, ssd, occupancy, wl, cache_frac=cache_frac,
                  use_flusher=use_flusher, clean_first=clean_first,
                  score_threshold=score_threshold, seed=seed, faults=faults,
                  telemetry=telemetry, monitor=monitor, trace=trace)
    res = sim.run(measure_ops, warmup_ops)
    return (res, sim.last_latency)


def merge_safs_results(parts: list[SAFSResults],
                       pooled: np.ndarray) -> SAFSResults:
    """Merge per-shard ``SAFSResults``: throughput and writeback counters
    add, per-device utilizations concatenate in shard order, the hit rate is
    recomputed from the pooled raw cache counters (``cache_hits`` /
    ``cache_lookups`` — never an average of per-shard ratios), and latency
    percentiles are exact over the pooled raw samples."""
    if pooled.size:
        p50, p95, p99 = np.percentile(pooled, [50.0, 95.0, 99.0])
        summ = LatencySummary(mean=float(pooled.mean()), p50=float(p50),
                              p95=float(p95), p99=float(p99), n=pooled.size)
    else:
        summ = LatencySummary.empty()
    hits = sum(p.cache_hits for p in parts)
    lookups = sum(p.cache_lookups for p in parts)
    return SAFSResults(
        app_iops=float(sum(p.app_iops for p in parts)),
        hit_rate=hits / max(lookups, 1),
        ssd_page_writes=sum(p.ssd_page_writes for p in parts),
        flush_writes=sum(p.flush_writes for p in parts),
        demand_writes=sum(p.demand_writes for p in parts),
        ssd_reads=sum(p.ssd_reads for p in parts),
        stale_discards=sum(p.stale_discards for p in parts),
        app_ops=sum(p.app_ops for p in parts),
        mean_latency=summ.mean,
        sim_time=max(p.sim_time for p in parts),
        util=np.concatenate([p.util for p in parts]),
        p50_latency=summ.p50,
        p95_latency=summ.p95,
        p99_latency=summ.p99,
        events=sum(p.events for p in parts),
        wall_s=max(p.wall_s for p in parts),
        cache_hits=hits,
        cache_lookups=lookups,
        faults=_merge_faults(parts),
        telemetry=merge_telemetry([p.telemetry for p in parts]),
        monitor=merge_monitor([p.monitor for p in parts]),
    )


class ShardedSAFSSim:
    """Partition a ``SAFSSim`` array (cache + flusher + devices) across
    worker processes and merge the results. Same constructor shape as
    ``SAFSSim`` plus the sharding knobs, same ``run() -> SAFSResults``.

    Each shard is a complete SAFS instance over its device group: its own
    SA-cache (sets never span groups), its own flusher dual queues, its own
    decorrelated RNG. ``n_shards=None`` uses ``min(cpu_count, n_ssds)``;
    ``parallel=False`` runs the same decomposition serially in-process —
    bit-identical results, used to verify the merge path. As with
    ``ShardedArraySim``, results are deterministic for a fixed
    ``(seed, n_shards)`` but differ numerically from the unsharded
    ``SAFSSim`` (different RNG streams and set hashes). Per-tenant QoS is
    not sharded (``qos`` raises)."""

    def __init__(self, n_ssds: int, ssd=None, occupancy: float = 0.8,
                 workload: SAFSWorkload = SAFSWorkload(),
                 cache_frac: float = 0.1, use_flusher: bool = True,
                 clean_first: bool = True, score_threshold: int = 2,
                 seed: int = 0, n_shards: int | None = None,
                 parallel: bool = True, qos=None, faults=None,
                 telemetry=None, monitor=None, trace=None):
        if qos is not None:
            raise NotImplementedError(
                "per-tenant QoS couples every device through one scheduler "
                "and cannot be sharded; use SAFSSim(qos=...) unsharded")
        self.trace = trace           # (n, 3|4) array for scenario="trace" —
                                     # sliced per shard by owning device
                                     # (workloads.shard_trace); records never
                                     # reorder within a device group
        if workload.scenario == "trace":
            if trace is None:
                raise ValueError("scenario='trace' needs a trace array")
        elif trace is not None:
            raise ValueError("trace= requires workload.scenario='trace'")
        self.n = n_ssds
        self.p = ssd if ssd is not None else SSDParams()
        self.wl = workload
        self.occupancy = occupancy
        self.cache_frac = cache_frac
        self.use_flusher = use_flusher
        self.clean_first = clean_first
        self.score_threshold = score_threshold
        self.seed = seed
        self.parallel = parallel
        self.faults = faults
        if faults is not None:
            from .faults import validate_fault_policy
            validate_fault_policy(faults, n_ssds, layout=None)
        self.telemetry = telemetry
        _check_telemetry(telemetry, faults)
        self.monitor = monitor
        _check_monitor(monitor)
        if n_shards is None:
            n_shards = min(os.cpu_count() or 1, n_ssds)
        self.sizes = shard_sizes(n_ssds, n_shards)
        self.last_latency: np.ndarray | None = None
        self.last_telemetry = None   # merged TelemetryResult of the last run
        self.last_monitor = None     # merged MonitorResult of the last run
        self.last_wall_s = 0.0       # observed wall clock of the last run()

    def _shard_args(self, measure_ops: int, warmup_ops: int | None):
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        traces = [None] * len(self.sizes)
        if self.trace is not None:
            traces = shard_trace(self.trace, self.n, self.sizes)
            counts = [len(t) for t in traces]
            measures = _split_budget_by(measure_ops, counts)
            warmups = _split_budget_by(warmup_ops, counts) \
                if warmup_ops else [0] * len(self.sizes)
        else:
            measures = _split_budget(measure_ops, self.sizes, self.n)
            warmups = _split_budget(warmup_ops, self.sizes, self.n) \
                if warmup_ops else [0] * len(self.sizes)
        faults = [None] * len(self.sizes)
        if self.faults is not None:
            from .faults import slice_policy
            lo = 0
            for k, sz in enumerate(self.sizes):
                faults[k] = slice_policy(self.faults, lo, lo + sz)
                lo += sz
        return [
            (sz, self.p, self.occupancy,
             _shard_safs_workload(self.wl, sz, self.n),
             self.cache_frac, self.use_flusher, self.clean_first,
             self.score_threshold, shard_seed(self.seed, k),
             measures[k], warmups[k], faults[k], self.telemetry,
             self.monitor, traces[k])
            for k, sz in enumerate(self.sizes)
        ]

    def run(self, measure_ops: int, warmup_ops: int | None = None) -> SAFSResults:
        args = self._shard_args(measure_ops, warmup_ops)
        t0 = time.perf_counter()
        if self.parallel and len(args) > 1:
            pool = _get_pool(min(len(args), os.cpu_count() or 1))
            out = pool.map(_run_safs_shard, args, chunksize=1)
        else:
            out = [_run_safs_shard(a) for a in args]
        self.last_wall_s = time.perf_counter() - t0
        parts = [r for r, _ in out]
        pooled = pool_samples([s for _, s in out])
        merged = merge_safs_results(parts, pooled)
        self.last_latency = pooled if pooled.size else None
        self.last_telemetry = merged.telemetry
        self.last_monitor = merged.monitor
        return merged

"""Per-tenant QoS subsystem: weighted fair scheduling, SLO throttling, and
per-tenant telemetry at the host admission point of both simulators.

The paper's headline result (62% more throughput under mixed reads and
writes while SSDs run active GC) is a multi-tenant story: a latency-
sensitive reader shares an array with a random writer whose traffic drives
the GC that hurts the reader's tail. The simulators reproduced the *sharing*
(``Op.tenant``, the ``DualQueue`` HIGH/LOW split) but not the *isolation* —
nothing enforced shares or protected a tenant's p99 when a neighbor's writes
tripped the free-block watermark. This module adds that enforcement:

* :class:`TenantSpec` / :class:`QosPolicy` — frozen, hashable, picklable
  specs (safe for sharded worker processes): per-tenant weights, optional
  token-bucket rate caps, optional p99 latency SLOs, and a small closed-loop
  workload description (``ArraySim`` builds one op source per tenant from
  it).
* :class:`QosScheduler` — the admission arbiter: deficit-round-robin over
  tenant classes (unit op cost, quantum ``policy.quantum * weight *
  throttle``), gated by per-tenant token buckets, with an embedded
  :class:`SloController` that measures per-tenant p99 over sliding windows
  and multiplicatively throttles *unprotected* tenants while any protected
  tenant's SLO is violated (GC-driven interference is the scenario that
  trips it).
* :class:`TenantDualQueue` — the SAFS-side admission point: a drop-in for
  ``io_queues.DualQueue`` where the HIGH class becomes per-tenant queues
  arbitrated by the shared scheduler; the flusher's background LOW queue and
  the reserved-slot rule are unchanged.
* :class:`TenantStats` + :func:`build_tenant_stats` /
  :func:`merge_tenant_stats` — the per-tenant results block
  (``tenant_throughput``, ``tenant_p50/p95/p99``, ``share_error``,
  ``throttle_time``) built on per-tenant ``LatencyRecorder`` samples;
  ``ShardedArraySim`` merges them EXACTLY from pooled raw samples (never
  averaged percentiles).

Everything here is deterministic: the scheduler consumes no RNG, so a fixed
seed still produces byte-identical runs, and ``qos=None`` leaves every
existing simulator path untouched (goldens pinned in
``tests/test_golden_determinism.py`` / ``tests/test_qos.py``).

Composition with GC coordination (``core/gc_coord.py``): QoS arbitrates
WHICH tenant's op takes the next host window slot; a ``GcPolicy`` decides
WHEN each member collects (and, with ``steer=True``, caps admission to
GC-busy members). The two compose orthogonally in ``ArraySim(qos=...,
gc=...)`` — the scheduler's pick happens at window admission, the
coordinator's gate at device service — and the composition is pinned by
``tests/test_gc_coord.py::test_qos_raid5_staggered_composition``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .engine import LatencyRecorder
from .io_queues import HIGH, IOStats
from .metrics import SlidingWindow
from .workloads import (OpSource, SequentialSource, UniformSource, ZipfSource,
                        _mix64)

__all__ = [
    "QosPolicy", "QosScheduler", "SloController", "TenantDualQueue",
    "TenantSpec", "TenantStats", "build_tenant_stats", "merge_tenant_stats",
    "tenant_source",
]

# deep-throttle floor for the effective DRR quantum: keeps every pick() call
# terminating in a bounded number of rotations (deficit grows by at least
# this much per visit)
_MIN_QUANTUM = 1.0 / 64.0


# ---------------------------------------------------------------------------
# Specs (frozen, hashable, picklable)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TenantSpec:
    """One tenant's QoS contract plus its closed-loop workload description.

    ``weight`` sets the deficit-round-robin share; ``rate_iops`` (optional)
    adds a hard token-bucket cap with ``burst`` ops of depth; ``slo_p99``
    (optional) marks the tenant *protected* — when its sliding-window p99
    exceeds the SLO, every unprotected tenant is throttled until it
    recovers. The workload fields mirror the ``Workload`` knobs and are used
    by ``ArraySim`` to build a per-tenant greedy closed-loop ``OpSource``
    (``tenant_source``); ``SAFSSim`` tags tenants from its own op stream and
    ignores them."""

    tenant: int
    weight: float = 1.0
    rate_iops: Optional[float] = None
    burst: float = 32.0
    slo_p99: Optional[float] = None
    # -- closed-loop workload of this tenant (ArraySim) ----------------------
    read_frac: float = 0.0
    dist: str = "uniform"            # "uniform" | "zipf" | "sequential"
    zipf_s: float = 0.99
    virtual_scale: int = 512
    trim_frac: float = 0.0

    @property
    def protected(self) -> bool:
        return self.slo_p99 is not None


@dataclass(frozen=True)
class QosPolicy:
    """Array-wide QoS policy: the tenant set plus scheduler calibration.

    ``quantum`` is the DRR quantum in op-cost units per unit weight (op cost
    is 1, so any quantum >= 1 gives exact weighted shares at saturation).
    The SLO controller evaluates every ``slo_check_ops`` completions over a
    sliding window of the last ``slo_window_ops`` samples per protected
    tenant (warmup included, so throttling reaches steady state before the
    measurement window opens); violations halve the unprotected tenants'
    throttle factor down to ``throttle_min``, and the factor doubles back
    toward 1.0 only once every protected p99 is below ``throttle_recover *
    slo_p99``."""

    tenants: tuple[TenantSpec, ...]
    quantum: float = 16.0
    slo_window_ops: int = 256
    slo_check_ops: int = 64
    slo_min_samples: int = 64
    throttle_min: float = 1.0 / 16.0
    throttle_recover: float = 0.7

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("QosPolicy needs at least one TenantSpec")
        ids = [s.tenant for s in self.tenants]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate tenant ids: {ids}")
        for s in self.tenants:
            if s.weight <= 0.0:
                raise ValueError(f"tenant {s.tenant}: weight must be > 0")
            if s.rate_iops is not None and s.rate_iops <= 0.0:
                raise ValueError(f"tenant {s.tenant}: rate_iops must be > 0")

    @property
    def ids(self) -> tuple[int, ...]:
        return tuple(s.tenant for s in self.tenants)

    def spec(self, tenant: int) -> TenantSpec:
        for s in self.tenants:
            if s.tenant == tenant:
                return s
        raise KeyError(tenant)

    def weight_share(self, tenant: int) -> float:
        total = sum(s.weight for s in self.tenants)
        return self.spec(tenant).weight / total


def tenant_source(spec: TenantSpec, n_live: int,
                  rng: np.random.Generator) -> OpSource:
    """Greedy closed-loop op source for one tenant (``ArraySim`` QoS mode)."""
    if spec.dist == "zipf":
        return ZipfSource(n_live, rng, spec.read_frac, s=spec.zipf_s,
                          virtual_scale=spec.virtual_scale,
                          trim_frac=spec.trim_frac)
    if spec.dist == "sequential":
        return SequentialSource(n_live, rng, spec.read_frac)
    if spec.dist == "uniform":
        return UniformSource(n_live, rng, spec.read_frac,
                             trim_frac=spec.trim_frac)
    raise ValueError(f"tenant {spec.tenant}: unknown dist {spec.dist!r}")


def tenant_rng_seed(seed: int, tenant: int) -> int:
    """Decorrelated per-tenant RNG seed (same recipe as shard seeds: mix the
    base before XORing the id so nearby pairs cannot collide)."""
    return _mix64(_mix64((seed ^ 0x51EED) & 0xFFFFFFFFFFFFFFFF)
                  ^ (tenant + 0x71))


# ---------------------------------------------------------------------------
# Scheduler building blocks
# ---------------------------------------------------------------------------

class TokenBucket:
    """Classic token bucket with lazy refill (``rate`` ops/s, ``burst`` op
    depth, one token per admitted op)."""

    __slots__ = ("rate", "burst", "tokens", "t")

    def __init__(self, rate: float, burst: float, now: float = 0.0):
        self.rate = rate
        self.burst = max(1.0, burst)
        self.tokens = self.burst
        self.t = now

    def _refill(self, now: float) -> None:
        if now > self.t:
            self.tokens = min(self.burst, self.tokens + (now - self.t) * self.rate)
            self.t = now

    def eligible(self, now: float) -> bool:
        self._refill(now)
        return self.tokens >= 1.0 - 1e-12

    def take(self, now: float) -> None:
        self._refill(now)
        self.tokens -= 1.0

    def next_release(self, now: float) -> float:
        """Earliest time a full token is available (== ``now`` if already)."""
        self._refill(now)
        short = 1.0 - self.tokens
        return now if short <= 0.0 else now + short / self.rate


class DeficitRoundRobin:
    """Incremental deficit round robin over tenant classes, unit op cost.

    ``pick(eligible)`` returns the next tenant to admit one op (its deficit
    already charged) or None when no tenant is eligible. A tenant's deficit
    tops up by ``quantum_of(tenant)`` once per rotation visit; the pointer
    stays on a tenant while it has deficit and work, so at saturation the
    admitted-op shares converge to the (throttle-scaled) weight shares.
    Blocked tenants (parked on a full device queue, rate-capped) are skipped
    WITHOUT resetting their deficit — they resume with what they had. The
    deficit is capped at two quanta so a long-blocked tenant cannot bank an
    unbounded catch-up burst."""

    __slots__ = ("_order", "_idx", "_fresh", "deficit", "_quantum_of")

    def __init__(self, tenants, quantum_of: Callable[[int], float]):
        self._order = list(tenants)
        self._idx = 0
        self._fresh = True
        self.deficit = {t: 0.0 for t in self._order}
        self._quantum_of = quantum_of

    def pick(self, eligible: Callable[[int], bool]) -> Optional[int]:
        order = self._order
        n = len(order)
        deficit = self.deficit
        barren = 0                       # consecutive ineligible visits
        while True:
            t = order[self._idx]
            if eligible(t):
                barren = 0
                if self._fresh:
                    q = self._quantum_of(t)
                    if q < _MIN_QUANTUM:
                        q = _MIN_QUANTUM
                    d = deficit[t] + q
                    cap = 2.0 * q
                    if cap < 2.0:
                        cap = 2.0
                    deficit[t] = d if d < cap else cap
                    self._fresh = False
                if deficit[t] >= 1.0:
                    deficit[t] -= 1.0
                    return t
            else:
                barren += 1
                if barren >= n:          # full rotation, nobody eligible
                    return None
            self._idx = (self._idx + 1) % n
            self._fresh = True


class SloController:
    """Sliding-window p99 measurement + multiplicative throttle.

    Each protected tenant keeps a window of its last ``slo_window_ops``
    completion latencies (warmup included). Every ``slo_check_ops``
    completions the controller evaluates: if any protected tenant with
    enough samples exceeds its SLO, every unprotected tenant's throttle
    factor is halved (floored at ``throttle_min``); once every protected
    tenant is back under ``throttle_recover * slo_p99`` the factors double
    back toward 1.0. The factor scales the tenant's effective DRR quantum,
    shifting admission share away from the over-share tenants while the
    protected tenant's tail is hurting. ``throttle_time(t, now)`` integrates
    the simulated seconds tenant ``t`` spent at a factor < 1."""

    __slots__ = ("policy", "throttle", "_win", "_unprot", "_prot", "_n",
                 "_since", "_acc", "checks", "violations")

    def __init__(self, policy: QosPolicy):
        self.policy = policy
        self._prot = [s for s in policy.tenants if s.protected]
        self._unprot = [s.tenant for s in policy.tenants if not s.protected]
        self._win = {s.tenant: SlidingWindow(policy.slo_window_ops)
                     for s in self._prot}
        self.throttle = {s.tenant: 1.0 for s in policy.tenants}
        self._n = 0
        self._since: dict[int, float] = {}   # throttle episode start per tenant
        self._acc = {s.tenant: 0.0 for s in policy.tenants}
        self.checks = 0
        self.violations = 0

    def note(self, tenant: int, latency: float, now: float) -> None:
        w = self._win.get(tenant)
        if w is not None:
            w.push(latency)
        self._n += 1
        if self._prot and self._n % self.policy.slo_check_ops == 0:
            self._evaluate(now)

    def _evaluate(self, now: float) -> None:
        self.checks += 1
        p = self.policy
        violated = False
        all_clear = True
        for s in self._prot:
            w = self._win[s.tenant]
            if len(w) < p.slo_min_samples:
                all_clear = False
                continue
            q99 = w.quantile(0.99)
            if q99 > s.slo_p99:
                violated = True
            if q99 > s.slo_p99 * p.throttle_recover:
                all_clear = False
        if violated:
            self.violations += 1
            for t in self._unprot:
                self._set(t, max(p.throttle_min, self.throttle[t] * 0.5), now)
        elif all_clear:
            # asymmetric AIMD-style release: halve on violation, +25% on a
            # clear check — a fast release re-admits the writer before the
            # protected tail has actually cleared (GC episodes return and
            # the controller oscillates at ~50% duty cycle)
            for t in self._unprot:
                f = self.throttle[t]
                if f < 1.0:
                    self._set(t, min(1.0, f * 1.25), now)

    def _set(self, t: int, f: float, now: float) -> None:
        old = self.throttle[t]
        if f == old:
            return
        if old >= 1.0 > f:
            self._since[t] = now
        elif f >= 1.0 > old:
            self._acc[t] += now - self._since.pop(t)
        self.throttle[t] = f

    def throttle_time(self, tenant: int, now: float) -> float:
        acc = self._acc.get(tenant, 0.0)
        since = self._since.get(tenant)
        return acc if since is None else acc + (now - since)


class QosScheduler:
    """The admission arbiter both simulators plug in at their host admission
    point: DRR over tenant classes, gated by per-tenant token buckets,
    throttled by the embedded :class:`SloController`.

    ``pick(now, ready)`` — ``ready(t)`` says tenant ``t`` could submit one op
    right now (has work, not parked) — returns the admitted tenant with its
    deficit charged and rate token consumed, or None. When None is returned
    because every ready tenant is rate-blocked, ``next_release(now, ready)``
    gives the earliest wakeup time to re-try (the run loops schedule a kick
    there, so a rate-capped tenant never stalls forever). Feed every
    completion to ``note_completion`` so the SLO controller sees the full
    latency stream (including warmup)."""

    __slots__ = ("policy", "ids", "slo", "drr", "_buckets", "_base_q",
                 "admitted")

    def __init__(self, policy: QosPolicy, now: float = 0.0):
        self.policy = policy
        self.ids = list(policy.ids)
        self.slo = SloController(policy)
        self._buckets = {s.tenant: TokenBucket(s.rate_iops, s.burst, now)
                         for s in policy.tenants if s.rate_iops is not None}
        self._base_q = {s.tenant: policy.quantum * s.weight
                       for s in policy.tenants}
        self.drr = DeficitRoundRobin(self.ids, self._quantum_of)
        self.admitted = {t: 0 for t in self.ids}

    def _quantum_of(self, t: int) -> float:
        return self._base_q[t] * self.slo.throttle[t]

    def rate_ok(self, t: int, now: float) -> bool:
        b = self._buckets.get(t)
        return b is None or b.eligible(now)

    def pick(self, now: float, ready: Callable[[int], bool]) -> Optional[int]:
        t = self.drr.pick(lambda x: ready(x) and self.rate_ok(x, now))
        if t is not None:
            b = self._buckets.get(t)
            if b is not None:
                b.take(now)
            self.admitted[t] += 1
        return t

    def next_release(self, now: float,
                     ready: Callable[[int], bool]) -> Optional[float]:
        """Earliest future time a ready-but-rate-blocked tenant regains a
        token (None when no ready tenant is rate-blocked)."""
        out = None
        for t, b in self._buckets.items():
            if ready(t) and not b.eligible(now):
                r = b.next_release(now)
                if out is None or r < out:
                    out = r
        return out

    def note_completion(self, tenant: int, latency: float, now: float) -> None:
        self.slo.note(tenant, latency, now)

    def throttle_time(self, tenant: int, now: float) -> float:
        return self.slo.throttle_time(tenant, now)

    def throttle_of(self, tenant: int) -> float:
        return self.slo.throttle[tenant]


# ---------------------------------------------------------------------------
# SAFS admission point: per-tenant HIGH classes over the DualQueue discipline
# ---------------------------------------------------------------------------

class TenantDualQueue:
    """Drop-in for ``io_queues.DualQueue`` when a :class:`QosPolicy` is
    active: the HIGH class becomes per-tenant queues arbitrated by the shared
    :class:`QosScheduler` (demand reads/writebacks are classed by the app
    tenant that triggered them); the flusher's background LOW queue keeps its
    single class, its stale-discard-at-dequeue, and the reserved-slot rule.

    Discipline change vs the paper's §3.2 queue: LOW may also issue when
    every *waiting* HIGH class is rate-blocked (the device is not idled by a
    tenant's token bucket — background writebacks are exactly the work to do
    with the spare capacity); ``on_rate_blocked(t_release)`` fires so the
    simulator can schedule a device kick at the earliest token release."""

    __slots__ = ("loop", "sched", "max_inflight", "reserved", "high", "low",
                 "inflight_high", "inflight_low", "stats", "refill",
                 "on_rate_blocked", "_n_high")

    def __init__(self, loop, sched: QosScheduler, max_inflight: int,
                 reserved: int,
                 on_rate_blocked: Optional[Callable[[float], None]] = None):
        self.loop = loop
        self.sched = sched
        self.max_inflight = max_inflight
        self.reserved = reserved
        self.high: dict[int, deque] = {t: deque() for t in sched.ids}
        self.low: deque = deque()
        self.inflight_high = 0
        self.inflight_low = 0
        self.stats = IOStats()
        self.refill: Optional[Callable[[], None]] = None
        self.on_rate_blocked = on_rate_blocked
        self._n_high = 0

    def submit(self, req) -> bool:
        if req.priority == HIGH:
            q = self.high.get(req.tenant)
            if q is None:               # tenant outside the policy: class 0
                q = self.high[self.sched.ids[0]]
            q.append(req)
            self._n_high += 1
        else:
            self.low.append(req)
        return True

    def _ready(self, t: int) -> bool:
        q = self.high.get(t)
        return bool(q)

    def pop_next(self):
        """Apply the policy; drops stale low-priority heads (counts them)."""
        discarded = False
        sched = self.sched
        while True:
            inflight = self.inflight_high + self.inflight_low
            req = None
            if self._n_high and inflight < self.max_inflight:
                now = self.loop.now
                t = sched.pick(now, self._ready)
                if t is not None:
                    req = self.high[t].popleft()
                    self._n_high -= 1
                    self.inflight_high += 1
                    self.stats.issued_high += 1
                elif self.on_rate_blocked is not None:
                    tr = sched.next_release(now, self._ready)
                    if tr is not None:
                        self.on_rate_blocked(tr)
            if req is None and self.low \
                    and inflight < self.max_inflight - self.reserved:
                r = self.low.popleft()
                if r.is_stale is not None and r.is_stale(r.payload):
                    self.stats.discarded_stale += 1
                    discarded = True
                    if r.on_discard:
                        r.on_discard(r.payload)
                    continue
                req = r
                self.inflight_low += 1
                self.stats.issued_low += 1
            if discarded and self.refill:
                self.refill()
            return req

    def complete(self, req) -> None:
        if req.priority == HIGH:
            self.inflight_high -= 1
        else:
            self.inflight_low -= 1
        self.stats.completed += 1
        if req.on_complete:
            req.on_complete(req.payload)


# ---------------------------------------------------------------------------
# Per-tenant results block
# ---------------------------------------------------------------------------

@dataclass
class TenantStats:
    """One tenant's measured-window telemetry (the results block the ISSUE's
    acceptance sweeps gate on). ``share`` is the achieved fraction of all
    measured completions; ``weight_share`` the configured fraction —
    ``share_error`` on the parent results is ``max |share - weight_share|``
    over the tenants (meaningful when weights are the only active control:
    rate caps and SLO throttling shift shares by design)."""

    tenant: int
    weight: float
    ops: int
    throughput: float                # measured completions / s
    mean_latency: float
    p50_latency: float
    p95_latency: float
    p99_latency: float
    share: float
    weight_share: float
    throttle_time: float             # sim-seconds spent SLO-throttled
    slo_p99: Optional[float] = None
    rate_iops: Optional[float] = None


def build_tenant_stats(policy: QosPolicy,
                       recorders: dict[int, LatencyRecorder], span: float,
                       throttle_times: dict[int, float],
                       ) -> tuple[dict[int, TenantStats], float]:
    """Per-tenant stats from the measurement window's recorders; returns
    ``(stats_by_tenant, share_error)``."""
    total = sum(len(r) for r in recorders.values())
    out: dict[int, TenantStats] = {}
    share_error = 0.0
    for s in policy.tenants:
        rec = recorders[s.tenant]
        summ = rec.summary()
        share = summ.n / total if total else 0.0
        wshare = policy.weight_share(s.tenant)
        share_error = max(share_error, abs(share - wshare))
        out[s.tenant] = TenantStats(
            tenant=s.tenant, weight=s.weight, ops=summ.n,
            throughput=summ.n / span,
            mean_latency=summ.mean, p50_latency=summ.p50,
            p95_latency=summ.p95, p99_latency=summ.p99,
            share=share, weight_share=wshare,
            throttle_time=throttle_times.get(s.tenant, 0.0),
            slo_p99=s.slo_p99, rate_iops=s.rate_iops,
        )
    return out, share_error


def merge_tenant_stats(policy: QosPolicy,
                       parts: list[dict[int, TenantStats]],
                       pooled: dict[int, np.ndarray],
                       ) -> tuple[dict[int, TenantStats], float]:
    """Merge per-shard tenant stats: ops and throughput add, percentiles are
    EXACT over the pooled raw samples, shares are recomputed from the pooled
    op counts, and ``throttle_time`` takes the worst (max) shard — each shard
    runs its own SLO controller over its slice of the array."""
    total = sum(sum(p[t].ops for t in p) for p in parts)
    out: dict[int, TenantStats] = {}
    share_error = 0.0
    for s in policy.tenants:
        t = s.tenant
        samples = pooled.get(t)
        if samples is not None and samples.size:
            p50, p95, p99 = np.percentile(samples, [50.0, 95.0, 99.0])
            mean = float(samples.mean())
        else:
            p50 = p95 = p99 = mean = 0.0
        ops = sum(p[t].ops for p in parts if t in p)
        share = ops / total if total else 0.0
        wshare = policy.weight_share(t)
        share_error = max(share_error, abs(share - wshare))
        out[t] = TenantStats(
            tenant=t, weight=s.weight, ops=ops,
            throughput=sum(p[t].throughput for p in parts if t in p),
            mean_latency=mean, p50_latency=float(p50), p95_latency=float(p95),
            p99_latency=float(p99), share=share, weight_share=wshare,
            throttle_time=max((p[t].throttle_time for p in parts if t in p),
                              default=0.0),
            slo_p99=s.slo_p99, rate_iops=s.rate_iops,
        )
    return out, share_error


def pool_tenant_samples(parts: list[Optional[dict[int, np.ndarray]]],
                        ) -> dict[int, np.ndarray]:
    """Concatenate per-shard per-tenant latency samples in shard order."""
    out: dict[int, list[np.ndarray]] = {}
    for p in parts:
        if not p:
            continue
        for t, a in p.items():
            if a is not None and a.size:
                out.setdefault(t, []).append(a)
    return {t: np.concatenate(chunks) for t, chunks in out.items()}

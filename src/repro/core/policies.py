"""Paper §3.3.1/§3.3.2 policies: GClock scoring, flush scores, clean-first eviction.

Pure-numpy reference implementations. These are the oracle for the JAX twin in
``sa_cache.py`` and the policy engine of the discrete-event simulator
(``gc_sim.py`` / ``safs_sim.py``).

Terminology (paper §3.3.1):
    distance_score = hits * set_size + distance
where ``distance`` is the forward distance from the GClock hand to the page's
slot. Pages are ranked ascending by distance score; the rank (0 = smallest
distance score = closest to eviction) maps to the *highest* flush score:
    flush_score = set_size - 1 - rank.
"""
from __future__ import annotations

import numpy as np

# Paper defaults (§3.2, §3.3).
SET_SIZE = 12            # pages per set                      [paper: 12]
FLUSH_TRIGGER = 6        # dirty pages in a set that trigger the flusher
FLUSHES_PER_VISIT = 2    # "one or two" pages flushed per set visit
RESERVED_SLOTS = 7       # device slots reserved for high-priority I/O
DEVICE_SLOTS = 32        # parallel requests an SSD wants for max performance
MAX_PENDING_FLUSH_PER_DEV = 2048  # global flush cap = 2048 x n_devices


def gclock_distance(positions: np.ndarray, clock_hand: int, set_size: int) -> np.ndarray:
    """Forward distance from the clock hand to each slot position."""
    return (positions - clock_hand) % set_size


def distance_scores(hits: np.ndarray, clock_hand: int, set_size: int | None = None) -> np.ndarray:
    """Paper: distance_score = hits * set_size + distance (per slot)."""
    if set_size is None:
        set_size = int(hits.shape[-1])
    pos = np.arange(set_size)
    return hits.astype(np.int64) * set_size + gclock_distance(pos, clock_hand, set_size)


def flush_scores(hits: np.ndarray, clock_hand: int, valid: np.ndarray | None = None) -> np.ndarray:
    """Rank-based flush score: lower distance score -> higher flush score.

    ``valid`` masks slots that hold pages; invalid slots get flush score -1.
    Ties broken by slot index (stable argsort) to match the JAX twin exactly.
    """
    set_size = int(hits.shape[-1])
    d = distance_scores(hits, clock_hand, set_size)
    if valid is not None:
        d = np.where(valid, d, np.iinfo(np.int64).max)
    order = np.argsort(d, kind="stable")          # ascending distance score
    rank = np.empty(set_size, dtype=np.int64)
    rank[order] = np.arange(set_size)
    fs = set_size - 1 - rank
    if valid is not None:
        fs = np.where(valid, fs, -1)
    return fs


def gclock_evict(
    hits: np.ndarray,
    clock_hand: int,
    valid: np.ndarray,
    dirty: np.ndarray | None = None,
    clean_first: bool = True,
) -> tuple[int, np.ndarray, int]:
    """GClock victim selection with optional clean-first preference (§3.3).

    Sweeps from the clock hand decrementing hit counts; the first page with
    hits == 0 is the victim. With ``clean_first`` the sweep considers only
    clean pages on the first lap over candidates; if every candidate is dirty
    the sweep falls back to all pages (the application write must then wait on
    the dirty writeback — the case the flusher makes rare).

    Returns (victim_slot, new_hits, new_clock_hand). Invalid (empty) slots are
    claimed immediately without a sweep.
    """
    set_size = int(hits.shape[-1])
    empty = np.flatnonzero(~valid)
    if empty.size:
        return int(empty[0]), hits.copy(), clock_hand

    def sweep(eligible: np.ndarray):
        h = hits.copy()
        hand = clock_hand
        # Each full lap decrements every eligible page once; max hits bounds laps.
        for _ in range(set_size * (int(h.max(initial=0)) + 2)):
            if eligible[hand]:
                if h[hand] == 0:
                    return hand, h, (hand + 1) % set_size
                h[hand] -= 1
            hand = (hand + 1) % set_size
        return None  # pragma: no cover - unreachable: some page reaches 0

    if clean_first and dirty is not None:
        clean = valid & ~dirty
        if clean.any():
            res = sweep(clean)
            if res is not None:
                return res
    res = sweep(valid)
    assert res is not None
    return res


def is_stale(
    *,
    evicted: bool,
    cleaned: bool,
    current_flush_score: int,
    score_threshold: int,
) -> bool:
    """Paper §3.3.2: discard a queued flush request iff the page was evicted,
    was re-cleaned, or its *current* flush score dropped below the threshold."""
    return evicted or cleaned or current_flush_score < score_threshold

"""Discrete-event simulator of an SSD array with unsynchronized garbage collection.

This reproduces the *evaluation substrate* of the paper (§4.1): OCZ Vertex-4
class SSDs behind HBAs, raw 4 KB random I/O. Three coupled models:

1. ``FTL`` — page-mapped flash translation layer with greedy (min-valid) GC
   and free-block watermark hysteresis. Hysteresis is what makes GC *bursty*:
   an SSD reclaims several blocks back-to-back, pausing user I/O for
   milliseconds. Across an array these pauses are unsynchronized — the
   phenomenon the paper attacks.
2. ``SSDServer`` — FTL + service-time parameters. Service itself is modeled
   by ``engine.DeviceModel``: up to ``device_slots`` admitted (NCQ) requests,
   up to ``channels`` serviced concurrently, each occupying one channel for
   its full ``t_op``; GC episodes preempt every channel. Saturation
   throughput is ``channels / t_op`` (the Table-1 calibration), but reaching
   it requires real queue depth — the paper's central lever.
3. ``ArraySim`` — host with a bounded total outstanding window W and bounded
   per-SSD queues. Tokens regenerate only on completion, so a GC-paused SSD
   accumulates an ever larger share of W while fast SSDs starve — exactly the
   Table-2/Figure-2 dynamic.

Calibration: ``t_prog`` is set so a fresh single SSD sustains 60 928 IOPS of
4 KB random writes (paper Table 1 "maximal"); occupancy-dependent degradation
then *emerges* from the FTL (write amplification), it is not programmed in.

Performance note: the FTL's mapping state (``page_lba``/``lba_loc``/
``valid_count``/``sealed``) is stored in plain Python lists, not numpy
arrays. The DES hot path programs ONE page per user write, and a numpy
scalar index costs ~10x a list index; chunks are at most one block
(``pages_per_block``) wide, where tight Python loops beat numpy's per-call
overhead too. The numpy-array views are still exposed as read-only
properties for analysis/tests. Semantics (and therefore seeded results) are
identical to the previous numpy implementation.
"""
from __future__ import annotations

import copy
import time
from collections import OrderedDict, deque
from dataclasses import dataclass

import numpy as np

from .engine import DeviceModel, EventLoop, LatencyRecorder, MeasurementWindow
from .workloads import (OP_READ, OP_REBUILD, OP_TRIM, OP_WRITE, OpSource,
                        ZipfSampler, _mix64, source_for)

__all__ = [
    "ArrayResults", "ArraySim", "FTL", "SSDParams", "SSDServer", "SealFifo",
    "Workload", "ZipfSampler", "_mix64", "clear_prefill_cache",
    "fresh_ssd_write_iops", "single_ssd_write_iops",
]

# Paper Table 1 calibration target.
FRESH_WRITE_IOPS = 60928.0
READ_IOPS = 90000.0


@dataclass(frozen=True)
class SSDParams:
    capacity_pages: int = 65536          # scaled-down drive (4 KB pages)
    pages_per_block: int = 64
    op_frac: float = 0.55                # effective spare factor. Calibrated to
                                         # paper Table 1; large because the
                                         # Vertex 4 reorganizes below half fill
                                         # ("performance mode") and so behaves
                                         # as if heavily over-provisioned.
    channels: int = 32                   # internal parallelism
    t_prog: float = 32.0 / FRESH_WRITE_IOPS
    t_read: float = 32.0 / READ_IOPS
    t_erase: float = 2.0e-3
    t_coalesce: float = 10.0e-6          # DRAM write-buffer hit: a write whose
                                         # LBA already has a pending write is
                                         # absorbed at bus speed, no program
    t_trim: float = 20.0e-6              # TRIM/deallocate: mapping-table-only
                                         # command, no flash program
    gc_low_blocks: int = 12              # enter GC episode at <= low free blocks
    gc_high_blocks: int = 16             # leave episode at >= high free blocks
                                         # (width => ~5 ms pauses; calibrated so
                                         # the Table-2 array decline matches)
    device_slots: int = 32               # NCQ-style concurrent admissions
    gc_window: int = 0                   # 0 = pure greedy; else greedy over the
                                         # oldest-sealed window (wear-leveling-
                                         # constrained controllers; raises WA)
    gc_sample: int = 2                   # 0 = full scan; else min-valid over a
                                         # distinct random sample of sealed
                                         # blocks (d-choices, as firmware
                                         # actually does). Calibrated (with
                                         # op_frac) to Table 1.

    @property
    def phys_pages(self) -> int:
        blocks = int(round(self.capacity_pages * (1 + self.op_frac))) // self.pages_per_block
        return blocks * self.pages_per_block

    @property
    def n_blocks(self) -> int:
        return self.phys_pages // self.pages_per_block


class SealFifo:
    """Seal-ordered block FIFO with O(1) removal and O(d) distinct sampling.

    Replaces a plain list whose ``.remove()`` was O(n) on the GC hot path.
    Tombstoned backing array, compacted when more than half dead, so the
    live fraction is always >= 1/2 (bounding rejection sampling)."""

    __slots__ = ("_items", "_pos", "_dead")

    def __init__(self) -> None:
        self._items: list[int] = []   # seal order; -1 = tombstone
        self._pos: dict[int, int] = {}
        self._dead = 0

    def __len__(self) -> int:
        return len(self._items) - self._dead

    def __contains__(self, block: int) -> bool:
        return block in self._pos

    def __iter__(self):
        return (b for b in self._items if b >= 0)

    def append(self, block: int) -> None:
        self._pos[block] = len(self._items)
        self._items.append(block)

    def remove(self, block: int) -> None:
        i = self._pos.pop(block)
        self._items[i] = -1
        self._dead += 1
        if self._dead * 2 > len(self._items):
            self._compact()

    def _compact(self) -> None:
        self._items = [b for b in self._items if b >= 0]
        self._pos = {b: i for i, b in enumerate(self._items)}
        self._dead = 0

    def head_window(self, k: int) -> list[int]:
        """First ``k`` live blocks in seal order."""
        out: list[int] = []
        if k <= 0:
            return out
        for b in self._items:
            if b >= 0:
                out.append(b)
                if len(out) == k:
                    break
        return out

    def sample_distinct(self, rng: np.random.Generator, k: int) -> list[int]:
        """``k`` distinct live blocks, uniform without replacement — proper
        d-choices (sampling the same index twice degenerated to 1-choice)."""
        n_live = len(self)
        if k >= n_live:
            return list(self)
        out: list[int] = []
        seen: set[int] = set()
        m = len(self._items)
        while len(out) < k:
            for i in rng.integers(0, m, size=4 * k):
                b = self._items[int(i)]
                if b >= 0 and b not in seen:
                    seen.add(b)
                    out.append(b)
                    if len(out) == k:
                        break
        return out


class FTL:
    """Page-mapped FTL with greedy GC. Mapping state in plain Python lists
    (scalar indexing dominates the DES hot path — see module docstring); the
    prefill path still bulk-initializes with slice assignment."""

    def __init__(self, params: SSDParams, rng: np.random.Generator):
        self.p = params
        self.rng = rng
        n_blocks = params.n_blocks
        self._page_lba: list[int] = [-1] * params.phys_pages
        self._lba_loc: list[int] = [-1] * params.capacity_pages
        self._valid_count: list[int] = [0] * n_blocks
        self._sealed: list[bool] = [False] * n_blocks
        self._gc_low = params.gc_low_blocks
        self._gc_high = params.gc_high_blocks
        self.seal_fifo = SealFifo()   # blocks in seal order (gc_window policy)
        # FIFO free list: allocate from the left, return reclaimed blocks on
        # the right (a freed block is not reused before the active moves on).
        self.free_blocks: deque[int] = deque(range(1, n_blocks))
        self.active = 0
        self.active_off = 0
        self.writes = 0          # user page programs
        self.gc_copies = 0       # GC page programs
        self.erases = 0
        self.trims = 0           # TRIM invalidations applied

    def clone(self, rng: np.random.Generator) -> "FTL":
        """Fast state copy (prefill snapshot cache) — ~10x cheaper than
        ``copy.deepcopy`` on the int-list state."""
        c = object.__new__(FTL)
        c.p = self.p
        c.rng = rng
        c._page_lba = self._page_lba.copy()
        c._lba_loc = self._lba_loc.copy()
        c._valid_count = self._valid_count.copy()
        c._sealed = self._sealed.copy()
        c._gc_low = self._gc_low
        c._gc_high = self._gc_high
        sf = SealFifo()
        sf._items = self.seal_fifo._items.copy()
        sf._pos = dict(self.seal_fifo._pos)
        sf._dead = self.seal_fifo._dead
        c.seal_fifo = sf
        c.free_blocks = deque(self.free_blocks)
        c.active = self.active
        c.active_off = self.active_off
        c.writes = self.writes
        c.gc_copies = self.gc_copies
        c.erases = self.erases
        c.trims = self.trims
        if hasattr(self, "live_lbas"):
            c.live_lbas = self.live_lbas
        return c

    # -- numpy views (analysis/tests; NOT the hot path) ----------------------
    @property
    def page_lba(self) -> np.ndarray:
        return np.asarray(self._page_lba, dtype=np.int64)

    @property
    def lba_loc(self) -> np.ndarray:
        return np.asarray(self._lba_loc, dtype=np.int64)

    @property
    def valid_count(self) -> np.ndarray:
        return np.asarray(self._valid_count, dtype=np.int32)

    @property
    def sealed(self) -> np.ndarray:
        return np.asarray(self._sealed, dtype=bool)

    # -- helpers -------------------------------------------------------------
    @property
    def n_free_blocks(self) -> int:
        return len(self.free_blocks)

    def _advance_active(self) -> None:
        if self.active_off == self.p.pages_per_block:
            self._sealed[self.active] = True
            self.seal_fifo.append(self.active)
            self.active = self.free_blocks.popleft()
            self.active_off = 0

    def _program(self, lba: int) -> None:
        """Append ``lba`` to the active block (mapping update only)."""
        ppb = self.p.pages_per_block
        if self.active_off == ppb:
            self._sealed[self.active] = True
            self.seal_fifo.append(self.active)
            self.active = self.free_blocks.popleft()
            self.active_off = 0
        active = self.active
        phys = active * ppb + self.active_off
        self.active_off += 1
        lba_loc = self._lba_loc
        page_lba = self._page_lba
        old = lba_loc[lba]
        if old >= 0:
            page_lba[old] = -1
            self._valid_count[old // ppb] -= 1
        page_lba[phys] = lba
        lba_loc[lba] = phys
        self._valid_count[active] += 1

    def _program_chunk(self, lbas) -> None:
        """Program a batch of (possibly duplicate) LBAs into the active block.
        Caller guarantees the batch fits: len(lbas) <= pages_per_block -
        active_off. Sequential scalar semantics: the last occurrence of a
        duplicated LBA wins, earlier occurrences land dead-on-arrival."""
        k = len(lbas)
        if k == 0:
            return
        ppb = self.p.pages_per_block
        active = self.active
        phys = active * ppb + self.active_off
        page_lba = self._page_lba
        lba_loc = self._lba_loc
        valid = self._valid_count
        for lba in lbas:
            old = lba_loc[lba]
            if old >= 0:
                page_lba[old] = -1
                valid[old // ppb] -= 1
            page_lba[phys] = lba
            lba_loc[lba] = phys
            phys += 1
        valid[active] += k
        self.active_off += k

    def _program_batch(self, lbas) -> None:
        """Program a batch spanning block boundaries (chunks per active block)."""
        i, n = 0, len(lbas)
        while i < n:
            self._advance_active()
            room = self.p.pages_per_block - self.active_off
            take = room if room < n - i else n - i
            self._program_chunk(lbas[i:i + take])
            i += take

    # -- public ----------------------------------------------------------------
    def prefill(self, occupancy: float, churn: bool = True) -> None:
        """Sequentially write ``occupancy`` of the LBA space (paper's pre-
        conditioning), then churn random overwrites (with GC interleaved,
        charging no simulated time) until the drive reaches GC steady state."""
        live = int(self.p.capacity_pages * occupancy)
        self.live_lbas = live
        if live:
            # Bulk sequential fill: blocks are allocated in index order from
            # a fresh drive, so LBA i lands on physical page i.
            ppb = self.p.pages_per_block
            q, r = divmod(live, ppb)
            seq = range(live)
            self._page_lba[:live] = seq
            self._lba_loc[:live] = seq
            self._valid_count[:q] = [ppb] * q
            if r:
                self._valid_count[q] = r
            # a block seals only when the *next* program arrives, so an
            # exactly-full trailing block stays active (matches _program)
            n_sealed = q if r else q - 1
            self._sealed[:n_sealed] = [True] * n_sealed
            for b in range(n_sealed):
                self.seal_fifo.append(b)
            self.active = n_sealed
            self.active_off = r if r else ppb
            self.free_blocks = deque(range(n_sealed + 1, self.p.n_blocks))
        if churn:
            spare = self.p.phys_pages - live
            lbas = self.rng.integers(0, live, size=3 * spare).tolist()
            i, n = 0, len(lbas)
            while i < n:
                # free-block count only changes at block boundaries, so GC
                # trigger points are preserved under block-sized chunking
                self._advance_active()
                room = self.p.pages_per_block - self.active_off
                take = room if room < n - i else n - i
                self._program_chunk(lbas[i:i + take])
                i += take
                while self.need_gc() and not self.gc_satisfied():
                    self.gc_reclaim_one()
            # reset counters so WA statistics reflect steady state only
            self.writes = 0
            self.gc_copies = 0
            self.erases = 0
            self.trims = 0

    def user_write(self, lba: int) -> None:
        self._program(lba)
        self.writes += 1

    def trim(self, lba: int) -> None:
        """TRIM/deallocate ``lba``: drop the mapping and mark its physical
        page invalid, so GC never copies it (trim-aware GC lowers WA). A
        later write to the LBA simply re-maps it."""
        loc = self._lba_loc[lba]
        if loc >= 0:
            self._page_lba[loc] = -1
            self._valid_count[loc // self.p.pages_per_block] -= 1
            self._lba_loc[lba] = -1
            self.trims += 1

    def need_gc(self) -> bool:
        return len(self.free_blocks) <= self._gc_low

    def gc_satisfied(self) -> bool:
        return len(self.free_blocks) >= self._gc_high

    def gc_reclaim_one(self) -> int:
        """Reclaim the min-valid sealed block (within the seal-order window if
        ``gc_window`` > 0). Returns the number of page copies performed
        (caller charges time)."""
        valid = self._valid_count
        if self.p.gc_window > 0:
            window = self.seal_fifo.head_window(self.p.gc_window)
            victim = min(window, key=valid.__getitem__)
        elif self.p.gc_sample > 0 and len(self.seal_fifo) > self.p.gc_sample:
            cand = self.seal_fifo.sample_distinct(self.rng, self.p.gc_sample)
            victim = min(cand, key=valid.__getitem__)
        else:
            cand = [b for b, s in enumerate(self._sealed) if s]
            victim = min(cand, key=valid.__getitem__)
        self.seal_fifo.remove(victim)
        base = victim * self.p.pages_per_block
        page = self._page_lba[base:base + self.p.pages_per_block]
        live = [l for l in page if l >= 0]
        self._program_batch(live)
        moved = len(live)
        self._sealed[victim] = False
        valid[victim] = 0
        self.free_blocks.append(victim)  # tail: not reused before active moves on
        self.gc_copies += moved
        self.erases += 1
        return moved


@dataclass(frozen=True)
class Workload:
    read_frac: float = 0.0
    trim_frac: float = 0.0           # fraction of writes issued as TRIM
                                     # (uniform/zipf sources; trim-aware GC)
    dist: str = "uniform"            # "uniform" | "zipf"
    zipf_s: float = 0.99
    w_total: int = 128               # total outstanding window (app tokens)
    qd_per_ssd: int = 128            # host-side per-SSD queue bound
    n_streams: int = 1               # submission sequencers: a stream BLOCKS
                                     # (head-of-line) when its next request
                                     # targets a full device queue, as an AIO
                                     # submit loop does. SAFS's long in-memory
                                     # queues exist to break exactly this.
    virtual_scale: int = 512         # Zipf ranks live in a virtual LBA space
                                     # this many times larger than the scaled
                                     # drives (≈ real 128 GB drives), then hash
                                     # onto physical LBAs. Keeps the Zipf head
                                     # below one SSD's fair share, as at real
                                     # scale, instead of a scale-artifact
                                     # hotspot.
    # -- scenario layer / pattern suite (core/workloads.py) -----------------
    scenario: str = "random"         # any PATTERNS name: "random" |
                                     # "sequential" | "strided" | "snake" |
                                     # "hot_cold" | "write_then_read" |
                                     # "bursty" | "mixed" | "trace" |
                                     # "delete_burst"
    seq_streams: int = 4             # sequential cursors for "sequential"
    burst_on: float = 2e-3           # ON window seconds for "bursty"
    burst_off: float = 2e-3          # OFF window seconds for "bursty"
    writer_frac: float = 0.5         # writer-tenant share for "mixed"
    delete_pages: int = 64           # TRIM run length for "delete_burst"
    delete_every: int = 256          # a burst fires on every delete_every-th
                                     # op slot ("delete_burst")
    stride: int = 64                 # LBA step for "strided"
    hot_frac: float = 0.1            # hot-zone share of the LBA space
    hot_ops: float = 0.9             # op share hitting the hot zone
    wtr_span: int = 4096             # extent pages for "write_then_read"
    trace_time_scale: float = 1.0    # seconds-per-trace-second for "trace"


@dataclass
class ArrayResults:
    iops: float
    per_ssd_iops: np.ndarray
    read_iops: float
    write_iops: float
    util: np.ndarray                 # mean busy channel fraction per SSD
    sim_time: float
    gc_pause_frac: np.ndarray        # fraction of time in GC episodes
    mean_latency: float
    p50_latency: float = 0.0
    p95_latency: float = 0.0
    p99_latency: float = 0.0
    events: int = 0                  # engine events dispatched during run()
    wall_s: float = 0.0              # host wall-clock seconds of run()
    # -- array-layout results (core/raid.py; defaults = the JBOD story) ------
    layout: str = "jbod"
    parity_wa: float = 1.0           # member page writes / logical page writes
    gc_wa: float = 1.0               # (user + GC programs) / user programs
    array_wa: float = 1.0            # total = parity_wa * gc_wa
    stripe_stall_mean: float = 0.0   # per striped write: last child done -
    stripe_stall_p99: float = 0.0    #   first child done (the sync penalty)
    util_spread: float = 0.0         # max - min per-SSD utilization
    logical_writes: int = 0          # measured logical data pages written
    child_writes: int = 0            # measured member page writes (data+parity)
    child_reads: int = 0             # measured member page reads (RMW/degraded)
    parity_writes: int = 0
    full_stripe_rows: int = 0        # rows closed by the coalesced path
    rmw_ops: int = 0                 # logical writes that paid the RMW
    degraded_reads: int = 0          # reads served by reconstruction
    rebuild_rows: int = 0            # rebuild rows completed during run()
    trims: int = 0                   # TRIM invalidations applied (measured)
    trim_parity_skipped: int = 0     # RAID-5 parity updates skipped on TRIM
                                     # (modeling gap — see benchmarks/README)
    ftl_writes: int = 0              # measured user page programs (all SSDs)
    ftl_gc_copies: int = 0           # measured GC page copies (all SSDs)
    # -- per-tenant QoS results (core/qos.py; None when qos is off) ----------
    tenant_stats: "dict | None" = None   # tenant id -> qos.TenantStats
    share_error: float = 0.0         # max |achieved - weight| share over
                                     # tenants (weights-only runs)
    # -- GC coordination results (core/gc_coord.py; defaults = reactive) -----
    gc_policy: str = "reactive"      # active policy name ("reactive" too
                                     # when gc=None: same behavior)
    gc_overlap_frac: float = 0.0     # fraction of the window with >= 2
                                     # members simultaneously in GC
    stagger_wait_mean: float = 0.0   # lease wait (trip -> GC start) under
    stagger_wait_p99: float = 0.0    #   StaggeredGc deferral
    util_min: float = 0.0            # min per-SSD utilization (the member
                                     # coordination is meant to lift)
    gc_starts: int = 0               # GC episodes started in-window
    gc_forced: int = 0               # hard-floor lease overrides
    idle_gc_frac: float = 0.0        # fraction of GC time from idle steps
    steered_reads: int = 0           # RAID-5 reads redirected around a
                                     # GC-busy member (steer=True)
    gc_lease_skipped: int = 0        # leases withheld from quarantined
                                     # members (faults + gc coordination)
    # -- fault injection results (core/faults.py; None when faults is off) ---
    faults: "dict | None" = None     # whole-run fault/defense counters
                                     # (see faults._new_fault_stats)
    # -- telemetry (core/telemetry.py; None when telemetry is off) -----------
    telemetry: "TelemetryResult | None" = None   # series/spans/budget snapshot
    # -- health monitoring (core/monitor.py; None when monitor is off) -------
    monitor: "MonitorResult | None" = None       # structured alert log


class SSDServer:
    """One SSD: FTL + service-time parameters + accounting. Actual service
    scheduling (NCQ slots, concurrent channels, GC preemption) lives in
    ``engine.DeviceModel``."""

    def __init__(self, params: SSDParams, occupancy: float, rng: np.random.Generator):
        self.p = params
        self.ftl = FTL(params, rng)
        self.ftl.prefill(occupancy)
        self.in_gc = False
        self.pending_writes: dict[int, int] = {}  # lba -> pending write count
        self.gc_time = 0.0
        self.busy_time = 0.0         # channel-seconds (see DeviceModel)
        self.served_reads = 0
        self.served_writes = 0
        self.served_trims = 0

    def clone(self, rng: np.random.Generator) -> "SSDServer":
        """Fast state copy (prefill snapshot cache)."""
        c = object.__new__(SSDServer)
        c.p = self.p
        c.ftl = self.ftl.clone(rng)
        c.in_gc = self.in_gc
        c.pending_writes = dict(self.pending_writes)
        c.gc_time = self.gc_time
        c.busy_time = self.busy_time
        c.served_reads = self.served_reads
        c.served_writes = self.served_writes
        c.served_trims = self.served_trims
        return c

    def service_time(self, is_read: bool) -> float:
        """Full per-op time on ONE channel; concurrency across channels is
        modeled explicitly by DeviceModel, not divided out fluidly."""
        return self.p.t_read if is_read else self.p.t_prog

    def gc_episode_time(self) -> float:
        """Reclaim blocks until the high watermark; return wall time of the
        episode (copies/erases spread across all channels)."""
        t = 0.0
        ftl = self.ftl
        p = self.p
        t_rw = p.t_read + p.t_prog
        channels = p.channels
        t_erase = p.t_erase / channels
        while not ftl.gc_satisfied():
            copies = ftl.gc_reclaim_one()
            t += copies * t_rw / channels
            t += t_erase
        return t

    def gc_idle_time(self, max_blocks: int) -> float:
        """Bounded idle-GC step (``gc_coord.IdleGc``): reclaim up to
        ``max_blocks`` blocks regardless of the watermarks (the coordinator
        has already decided collection is worthwhile) and return the wall
        time, same per-block cost model as a regular episode."""
        t = 0.0
        ftl = self.ftl
        p = self.p
        t_rw = p.t_read + p.t_prog
        channels = p.channels
        t_erase = p.t_erase / channels
        for _ in range(max_blocks):
            if not len(ftl.seal_fifo):
                break
            copies = ftl.gc_reclaim_one()
            t += copies * t_rw / channels
            t += t_erase
        return t


# Prefill snapshot cache: benchmark sweeps construct the *same* array (same
# params/occupancy/seed) once per sweep point; prefill+churn dominates that
# construction. With ``prefill_cache=True`` the post-construction state
# (every FTL, and the RNG state) is deep-copied once and restored bit-for-bit
# on subsequent constructions — results are identical to a fresh build.
# LRU-bounded: sharded worker processes persist across sweeps, and a full
# mapping snapshot is several MB per SSD — without eviction a long benchmark
# session would grow worker memory without bound.
_PREFILL_CACHE: OrderedDict = OrderedDict()
_PREFILL_CACHE_MAX = 8


def clear_prefill_cache() -> None:
    _PREFILL_CACHE.clear()


def _plan_devs(plan) -> tuple:
    """Sorted device set a plan touches across all phases (the span's GC
    exposure set). Only called when span tracing is on."""
    devs = set()
    for ph in plan.phases:
        for ch in ph:
            devs.add(ch[0])
    return tuple(sorted(devs))


def _ftl_window_stats(ssds, ftl_snap, span, channels):
    """Measurement-window accounting shared by both run loops: per-SSD
    utilization plus the FTL (writes, gc_copies, trims) deltas against the
    warmup snapshot and the GC write amplification they imply. Pure
    arithmetic after ``loop.run()`` — cannot perturb event ordering."""
    util = np.array([s.busy_time / (span * channels) for s in ssds])
    ftl_w = sum(s.ftl.writes for s in ssds) - sum(w for w, _, _ in ftl_snap)
    ftl_c = sum(s.ftl.gc_copies for s in ssds) \
        - sum(c for _, c, _ in ftl_snap)
    trims = sum(s.ftl.trims for s in ssds) - sum(t for _, _, t in ftl_snap)
    gc_wa = (ftl_w + ftl_c) / ftl_w if ftl_w else 1.0
    return util, ftl_w, ftl_c, trims, gc_wa


class ArraySim:
    """Host + n SSDs on the shared event engine; each SSD is a multi-slot
    NCQ device. Data placement is governed by ``layout`` (``core/raid.py``):
    the default ``JBODLayout`` round-robins independent 1-page LBAs across
    SSDs on a byte-identical fast path, while ``Raid0Layout``/``Raid5Layout``
    fan each logical op out to striped per-SSD children (completing at the
    max of them) through :meth:`_run_layout`."""

    def __init__(self, n_ssds: int, ssd: SSDParams = SSDParams(),
                 occupancy: float = 0.6, workload: Workload = Workload(),
                 seed: int = 0, source: OpSource | None = None,
                 trace: np.ndarray | None = None,
                 prefill_cache: bool = False,
                 layout: "Layout | None" = None,
                 qos: "QosPolicy | None" = None,
                 gc: "GcPolicy | None" = None,
                 faults: "FaultPolicy | None" = None,
                 telemetry: "TelemetrySpec | None" = None,
                 monitor: "MonitorSpec | None" = None):
        from .gc_coord import GcPolicy
        from .raid import JBODLayout, Layout   # local: raid imports workloads
        self.n = n_ssds
        self.p = ssd
        self.wl = workload
        self.layout = layout if layout is not None else JBODLayout()
        if not isinstance(self.layout, Layout):
            raise TypeError(f"layout must be a core.raid.Layout, "
                            f"got {type(self.layout).__name__}")
        self.gc = gc
        if gc is not None and not isinstance(gc, GcPolicy):
            raise TypeError(f"gc must be a core.gc_coord.GcPolicy, "
                            f"got {type(gc).__name__}")
        self.qos = qos
        if qos is not None:
            if workload.scenario == "trace":
                # trace replay honours the recorded admission order; qos=
                # supplies per-tenant SLO targets/weights for ACCOUNTING
                # (tenant_stats/share_error) — the scheduler's throttling
                # is not re-applied to a fixed open-loop arrival stream.
                if source is None and trace is None:
                    raise ValueError("qos + scenario='trace' needs the "
                                     "trace (trace= or source=)")
                if layout is not None and not layout.trivial:
                    raise ValueError("qos + trace replay supports only "
                                     "trivial (JBOD) layouts")
                if faults is not None:
                    raise ValueError("qos + trace replay does not compose "
                                     "with faults= yet")
                if telemetry is not None and getattr(telemetry, "spans",
                                                     False):
                    raise ValueError("qos + trace replay does not compose "
                                     "with telemetry spans yet")
            else:
                # under QoS each tenant runs its own closed-loop source
                # built from its TenantSpec; a caller-supplied source/
                # trace/scenario would be silently ignored — refuse
                # instead of lying
                if source is not None or trace is not None:
                    raise ValueError("qos= builds per-tenant sources from "
                                     "the TenantSpecs; source=/trace= "
                                     "would be ignored — drop them or "
                                     "drop qos")
                if workload.scenario != "random":
                    raise ValueError(f"qos= ignores workload.scenario="
                                     f"{workload.scenario!r}; describe "
                                     f"each tenant's workload in its "
                                     f"TenantSpec")
        self.faults = faults
        if faults is not None:
            from .faults import validate_fault_policy
            validate_fault_policy(faults, n_ssds, layout=self.layout)
        self.telemetry = telemetry
        if telemetry is not None:
            from .telemetry import TelemetrySpec
            if not isinstance(telemetry, TelemetrySpec):
                raise TypeError(f"telemetry must be a core.telemetry."
                                f"TelemetrySpec, got "
                                f"{type(telemetry).__name__}")
        self.monitor = monitor
        if monitor is not None:
            from .monitor import MonitorSpec
            if not isinstance(monitor, MonitorSpec):
                raise TypeError(f"monitor must be a core.monitor."
                                f"MonitorSpec, got "
                                f"{type(monitor).__name__}")
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        key = (n_ssds, ssd, occupancy, seed) if prefill_cache else None
        snap = _PREFILL_CACHE.get(key) if key is not None else None
        if snap is None:
            self.ssds = [SSDServer(ssd, occupancy, self.rng) for _ in range(n_ssds)]
            if key is not None:
                _PREFILL_CACHE[key] = ([s.clone(None) for s in self.ssds],
                                       copy.deepcopy(self.rng.bit_generator.state))
                while len(_PREFILL_CACHE) > _PREFILL_CACHE_MAX:
                    _PREFILL_CACHE.popitem(last=False)
        else:
            _PREFILL_CACHE.move_to_end(key)
            servers, rng_state = snap
            self.ssds = [s.clone(self.rng) for s in servers]
            self.rng.bit_generator.state = copy.deepcopy(rng_state)
        self.live_per_ssd = self.ssds[0].ftl.live_lbas
        # the logical page space excludes parity capacity (RAID-5); for JBOD
        # data_members(n) == n, so this is the historical n_live
        self.n_live = self.live_per_ssd * self.layout.data_members(n_ssds)
        self.source = source or source_for(workload, self.n_live, self.rng,
                                           trace=trace)
        self.last_latency: np.ndarray | None = None   # samples of last run()
        self.last_stall: np.ndarray | None = None     # stripe-stall samples
        self.last_tenant_latency: dict[int, np.ndarray] | None = None
        self.last_gc_wait: np.ndarray | None = None   # stagger-wait samples
        self.last_telemetry = None                    # TelemetryResult
        self.last_monitor = None                      # MonitorResult

    def _make_injector(self):
        """Fresh per-run FaultInjector, or None when faults are off. Each
        run() builds its own so repeated runs on one sim stay independent
        and deterministic (the injector's RNG is derived from the seed)."""
        if self.faults is None:
            return None
        from .faults import FaultInjector
        return FaultInjector(self.faults, self.n, self.seed)

    def _make_telemetry(self, loop):
        """Fresh per-run Telemetry collector attached to ``loop``, or None
        when telemetry is off. Per-run construction keeps repeated runs
        (``run_phased``) from mixing series."""
        if self.telemetry is None:
            return None
        from .telemetry import Telemetry
        return Telemetry(self.telemetry, self.n).attach(loop)

    def _make_monitor(self, loop, tel):
        """Fresh per-run HealthMonitor, or None when monitoring is off.
        Chains off ``tel``'s tick grid when telemetry is on; otherwise it
        installs its own identical loop hook."""
        if self.monitor is None:
            return None
        from .monitor import HealthMonitor
        return HealthMonitor(self.monitor, self.n).attach(loop, tel)

    # -- main loop -------------------------------------------------------------
    def run(self, measure_ops: int, warmup_ops: int | None = None) -> ArrayResults:
        if self.qos is not None and self.wl.scenario != "trace":
            return self._run_qos(measure_ops, warmup_ops)
        if not self.layout.trivial:
            return self._run_layout(measure_ops, warmup_ops)
        n, wl = self.n, self.wl
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        total_ops = warmup_ops + measure_ops
        loop = EventLoop()
        tel = self._make_telemetry(loop)
        mon = self._make_monitor(loop, tel)
        tel_spans = tel is not None and tel.spans_on
        qd = wl.qd_per_ssd
        coord = self.gc.make_coordinator(n, loop, self.layout.shard_unit(n)) \
            if self.gc is not None else None
        steer_on = coord is not None and coord.steer
        steer_qd = min(qd, coord.steer_qd) if steer_on else qd
        gc_busy = coord.gc_busy if coord is not None else None

        # fault injection (core/faults.py): None keeps every closure below
        # byte-identical to the pre-fault path. The JBOD fast loop supports
        # FailSlow, MediaError + retries, and the quarantine detector;
        # Crash/hedging need parity and are rejected/ignored for JBOD.
        inj = self._make_injector()
        if coord is not None and inj is not None and inj.detect:
            coord.quarantined = inj.quarantined
        media_on = inj is not None and inj.any_media
        qcap: "list[int] | None" = None
        if inj is not None and inj.detect:
            qcap = [qd] * n
            q_lo = min(qd, inj.policy.quarantine_qd)

            def _apply_q(i: int) -> None:
                qcap[i] = q_lo

            def _lift_q(i: int) -> None:
                qcap[i] = qd
                unpark(i)
            inj.on_quarantine = _apply_q
            inj.on_release = _lift_q

        # Submitter streams: each has a window of w_total/n_streams tokens and
        # a single submission sequence. A full target queue parks the whole
        # stream (AIO io_submit head-of-line behaviour); an open-loop lull
        # (Op.at in the future) puts it to sleep until that time.
        n_streams = max(1, wl.n_streams)
        window = max(1, wl.w_total // n_streams)
        outstanding = [0] * n_streams
        parked: list[tuple[int, int, bool, int] | None] = [None] * n_streams
        sleeping = [False] * n_streams
        waiters: list[deque] = [deque() for _ in range(n)]  # streams parked per SSD
        host_queues: list[deque] = [deque() for _ in range(n)]
        ssds = self.ssds

        measured = [0] * n
        mr = [0, 0]                  # measured [reads, writes]
        ftl_snap = [(0, 0, 0)] * n   # (writes, gc_copies, trims) at warmup

        # qos + trace replay: per-tenant latency accounting. ten_on gates
        # every tenant touch so the qos=None fast path stays byte-identical.
        qos = self.qos
        ten_on = qos is not None
        trec = {t: LatencyRecorder() for t in qos.ids} if ten_on else None
        cur_tenant = [0] * n_streams if ten_on else None

        def begin_measure():
            measured[:] = [0] * n
            mr[0] = mr[1] = 0
            if ten_on:
                for r in trec.values():
                    r.reset()
            for ss in ssds:
                ss.busy_time = 0.0
                ss.gc_time = 0.0
            ftl_snap[:] = [(s.ftl.writes, s.ftl.gc_copies, s.ftl.trims)
                           for s in ssds]
            if coord is not None:
                coord.begin_measure(loop.now)
            if mon is not None:
                mon.begin_measure(loop.now)

        mw = MeasurementWindow(loop, warmup_ops, begin_measure,
                               target=total_ops)
        note_completion = mw.note_completion
        next_op = self.source.next_op

        # requests are (stream, lba, is_read, coal, t_issue, kind)
        def make_pull(i: int):
            hq = host_queues[i]
            return lambda: hq.popleft() if hq else None

        def make_service_time(i: int):
            t_read, t_prog = self.p.t_read, self.p.t_prog
            t_coal, t_trim = self.p.t_coalesce, self.p.t_trim

            def service_time(req):
                if req[3]:
                    return t_coal
                if req[2]:
                    return t_read
                return t_trim if req[5] == OP_TRIM else t_prog
            if inj is not None and (inj.detect or inj.has_slow(i)):
                return inj.wrap_service_time(i, service_time, loop)
            return service_time

        def reissue(args):
            # media-error retry landing after its backoff: re-enter the host
            # queue exactly like enqueue()'s tail (the attempt counter and
            # the original t_issue ride inside the request tuple).
            i, req = args
            hq = host_queues[i]
            dev = devices[i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def make_on_done(i: int):
            s = ssds[i]
            ftl = s.ftl
            program = ftl._program
            pw = s.pending_writes
            w = waiters[i]

            if tel_spans and media_on:
                # combined variant: the span AND the media-retry attempt
                # counter ride at the tuple tail (indices 6/7); mutations
                # match the media_on branch in identical order, with the
                # span's retry note / close layered on passively
                t_read, t_prog = self.p.t_read, self.p.t_prog
                t_coal, t_trim = self.p.t_coalesce, self.p.t_trim

                def on_done(req):
                    stream, lba, is_read, coal, t_issue, kind, sp, att = req
                    if is_read:
                        if inj.read_fails(i):
                            retry, delay = inj.retry_decision(
                                att, t_issue, loop.now)
                            if retry:
                                tel.note_retry(sp, loop.now)
                                loop.call_at(
                                    loop.now + delay, reissue,
                                    (i, (stream, lba, True, coal, t_issue,
                                         kind, sp, att + 1)))
                                if w:
                                    unpark(i)
                                return
                            # exhausted/timed out: surface as a failed read —
                            # the op completes (token returns) without data
                        s.served_reads += 1
                        outstanding[stream] -= 1
                    else:
                        outstanding[stream] -= 1
                        if kind == OP_TRIM:
                            ftl.trim(lba)
                            s.served_trims += 1
                        else:
                            s.served_writes += 1
                            c = pw[lba] - 1
                            if c:
                                pw[lba] = c
                            else:
                                del pw[lba]
                            if not coal:      # inlined ftl.user_write
                                program(lba)
                                ftl.writes += 1
                    m = note_completion(t_issue)
                    if m:
                        measured[i] += 1
                        if is_read:
                            mr[0] += 1
                        else:
                            mr[1] += 1
                    svc = t_coal if coal else (
                        t_read if is_read else
                        (t_trim if kind == OP_TRIM else t_prog))
                    tel.close_fast_span(sp, loop.now, svc, m)
                    if w:
                        unpark(i)
                    stream_fill(stream)
                return on_done

            if tel_spans:
                # span variant: identical mutations in identical order; the
                # span record rides as the request tuple's 7th element
                t_read, t_prog = self.p.t_read, self.p.t_prog
                t_coal, t_trim = self.p.t_coalesce, self.p.t_trim

                def on_done(req):
                    stream, lba, is_read, coal, t_issue, kind, sp = req
                    outstanding[stream] -= 1
                    if is_read:
                        s.served_reads += 1
                    elif kind == OP_TRIM:
                        ftl.trim(lba)
                        s.served_trims += 1
                    else:
                        s.served_writes += 1
                        c = pw[lba] - 1
                        if c:
                            pw[lba] = c
                        else:
                            del pw[lba]
                        if not coal:      # inlined ftl.user_write
                            program(lba)
                            ftl.writes += 1
                    m = note_completion(t_issue)
                    if m:
                        measured[i] += 1
                        if is_read:
                            mr[0] += 1
                        else:
                            mr[1] += 1
                    svc = t_coal if coal else (
                        t_read if is_read else
                        (t_trim if kind == OP_TRIM else t_prog))
                    tel.close_fast_span(sp, loop.now, svc, m)
                    if w:
                        unpark(i)
                    stream_fill(stream)
                return on_done

            if media_on:
                def on_done(req):
                    stream, lba, is_read, coal, t_issue, kind, att = req
                    if is_read:
                        if inj.read_fails(i):
                            retry, delay = inj.retry_decision(
                                att, t_issue, loop.now)
                            if retry:
                                loop.call_at(
                                    loop.now + delay, reissue,
                                    (i, (stream, lba, True, coal, t_issue,
                                         kind, att + 1)))
                                if w:
                                    unpark(i)
                                return
                            # exhausted/timed out: surface as a failed read —
                            # the op completes (token returns) without data
                        s.served_reads += 1
                        outstanding[stream] -= 1
                    else:
                        outstanding[stream] -= 1
                        if kind == OP_TRIM:
                            ftl.trim(lba)
                            s.served_trims += 1
                        else:
                            s.served_writes += 1
                            c = pw[lba] - 1
                            if c:
                                pw[lba] = c
                            else:
                                del pw[lba]
                            if not coal:      # inlined ftl.user_write
                                program(lba)
                                ftl.writes += 1
                    if note_completion(t_issue):
                        measured[i] += 1
                        if is_read:
                            mr[0] += 1
                        else:
                            mr[1] += 1
                    if w:
                        unpark(i)
                    stream_fill(stream)
                return on_done

            if ten_on:
                # tenant variant: identical mutations in identical order;
                # the tenant id rides as the request tuple's 7th element
                # and feeds the per-tenant recorder on measured completions
                def on_done(req):
                    stream, lba, is_read, coal, t_issue, kind, tenant = req
                    outstanding[stream] -= 1
                    if is_read:
                        s.served_reads += 1
                    elif kind == OP_TRIM:
                        ftl.trim(lba)
                        s.served_trims += 1
                    else:
                        s.served_writes += 1
                        c = pw[lba] - 1
                        if c:
                            pw[lba] = c
                        else:
                            del pw[lba]
                        if not coal:      # inlined ftl.user_write
                            program(lba)
                            ftl.writes += 1
                    if note_completion(t_issue):
                        measured[i] += 1
                        if is_read:
                            mr[0] += 1
                        else:
                            mr[1] += 1
                        r = trec.get(tenant)
                        if r is not None:
                            r.record(loop.now - t_issue)
                    if w:
                        unpark(i)
                    stream_fill(stream)
                return on_done

            def on_done(req):
                stream, lba, is_read, coal, t_issue, kind = req
                outstanding[stream] -= 1
                if is_read:
                    s.served_reads += 1
                elif kind == OP_TRIM:
                    ftl.trim(lba)
                    s.served_trims += 1
                else:
                    s.served_writes += 1
                    c = pw[lba] - 1
                    if c:
                        pw[lba] = c
                    else:
                        del pw[lba]
                    if not coal:      # inlined ftl.user_write
                        program(lba)
                        ftl.writes += 1
                if note_completion(t_issue):
                    measured[i] += 1
                    if is_read:
                        mr[0] += 1
                    else:
                        mr[1] += 1
                if w:
                    unpark(i)
                stream_fill(stream)
            return on_done

        devices = [DeviceModel(loop, ssds[i], make_pull(i),
                               make_service_time(i), make_on_done(i),
                               backlog=host_queues[i],
                               gc_coord=coord, dev_id=i)
                   for i in range(n)]
        if coord is not None:
            for i, d in enumerate(devices):
                coord.attach(d, i)
        if tel is not None:
            tel.register_array_probes(ssds, devices, host_queues)
        if mon is not None:
            mon.register_array_sources(ssds, devices, host_queues, qd,
                                       inj=inj)

        def enqueue(stream: int, ssd_i: int, lba: int, is_read: bool,
                    kind: int):
            s = ssds[ssd_i]
            coal = False
            if kind == OP_WRITE:
                pw = s.pending_writes
                c = pw.get(lba)
                if c is None:
                    pw[lba] = 1
                else:
                    coal = True
                    pw[lba] = c + 1
            outstanding[stream] += 1
            if tel_spans:  # span rides at the end; indices 0-5 keep meaning
                if media_on:  # ... plus the attempt counter at index 7
                    req = (stream, lba, is_read, coal, loop.now, kind,
                           tel.new_span(kind, stream, ssd_i, loop.now), 0)
                else:
                    req = (stream, lba, is_read, coal, loop.now, kind,
                           tel.new_span(kind, stream, ssd_i, loop.now))
            elif media_on:  # attempt counter rides at the end, same shape
                req = (stream, lba, is_read, coal, loop.now, kind, 0)
            elif ten_on:    # tenant id rides at the end (qos trace replay)
                req = (stream, lba, is_read, coal, loop.now, kind,
                       cur_tenant[stream])
            else:
                req = (stream, lba, is_read, coal, loop.now, kind)
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def place(stream: int, ssd_i: int, lba: int, is_read: bool,
                  kind: int) -> bool:
            """Enqueue or park; True if the stream may keep submitting.
            GC-aware steering caps admission to a GC-busy member at
            ``steer_qd`` so the window's slots go to members that serve."""
            dev = devices[ssd_i]
            q = steer_qd if steer_on and gc_busy[ssd_i] else qd
            if qcap is not None and qcap[ssd_i] < q:
                q = qcap[ssd_i]     # quarantined member: shrink admission
            if len(host_queues[ssd_i]) + len(dev.admitted) + dev.in_service < q:
                enqueue(stream, ssd_i, lba, is_read, kind)
                return True
            parked[stream] = (ssd_i, lba, is_read, kind)
            waiters[ssd_i].append(stream)
            return False

        def wake(args):
            stream, ssd_i, lba, is_read, kind = args
            sleeping[stream] = False
            if place(stream, ssd_i, lba, is_read, kind):
                stream_fill(stream)

        def stream_fill(stream: int):
            """Submit until the stream's window is full, it parks, or the
            source's next arrival lies in the future."""
            if parked[stream] is not None or sleeping[stream]:
                return
            while outstanding[stream] < window:
                op = next_op(loop.now)
                if ten_on:
                    cur_tenant[stream] = op.tenant
                glba = op.lba
                ssd_i, lba = glba % n, glba // n
                kind = op.kind
                if kind < 0:
                    kind = OP_READ if op.is_read else OP_WRITE
                if op.at > loop.now:
                    sleeping[stream] = True
                    loop.call_at(op.at, wake,
                                 (stream, ssd_i, lba, op.is_read, kind))
                    return
                if not place(stream, ssd_i, lba, op.is_read, kind):
                    return

        def unpark(ssd_i: int):
            w = waiters[ssd_i]
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            while w:
                q = steer_qd if steer_on and gc_busy[ssd_i] else qd
                if qcap is not None and qcap[ssd_i] < q:
                    q = qcap[ssd_i]
                if len(hq) + len(dev.admitted) + dev.in_service >= q:
                    break
                stream = w.popleft()
                tgt, lba, is_read, kind = parked[stream]
                parked[stream] = None
                enqueue(stream, tgt, lba, is_read, kind)
                stream_fill(stream)

        if coord is not None:
            coord.on_release = unpark
        if total_ops > 0:   # run(0) is a no-op: never pull from the source
            for si in range(n_streams):
                stream_fill(si)

        t_wall = time.perf_counter()
        # total_ops == 0: nothing to measure (matches the old run_while exit)
        events = loop.run() if total_ops > 0 else 0
        wall_s = time.perf_counter() - t_wall

        span = mw.span
        if tel is not None:
            tel.finalize(loop.now, mw.t0)
        if mon is not None:
            mon.finalize(loop.now)
        summ = mw.latency.summary()
        self.last_latency = mw.latency.values()
        self.last_stall = None
        tstats, share_err = None, 0.0
        if ten_on:
            from .qos import build_tenant_stats
            tstats, share_err = build_tenant_stats(
                qos, trec, span, {t: 0.0 for t in qos.ids})
            self.last_tenant_latency = {t: r.values()
                                        for t, r in trec.items()}
        else:
            self.last_tenant_latency = None
        self.last_telemetry = tel.result() if tel is not None else None
        self.last_monitor = mon.result() if mon is not None else None
        measured_arr = np.asarray(measured, dtype=np.int64)
        util, ftl_w, ftl_c, trims, gc_wa = _ftl_window_stats(
            ssds, ftl_snap, span, self.p.channels)
        if tel is not None and tel.has_series("busy_time"):
            # derived from the telemetry busy-time probe's final sample —
            # bit-identical to the legacy per-SSD arithmetic (pinned by test)
            util = tel.util_final(span, self.p.channels)
        gkw = self._gc_window_stats(coord, loop, span)
        return ArrayResults(
            iops=float(measured_arr.sum() / span),
            per_ssd_iops=measured_arr / span,
            read_iops=mr[0] / span,
            write_iops=mr[1] / span,
            util=util,
            sim_time=span,
            gc_pause_frac=np.array([s.gc_time / span for s in ssds]),
            mean_latency=summ.mean,
            p50_latency=summ.p50,
            p95_latency=summ.p95,
            p99_latency=summ.p99,
            events=events,
            wall_s=wall_s,
            gc_wa=gc_wa,
            array_wa=gc_wa,
            util_spread=float(util.max() - util.min()) if n else 0.0,
            util_min=float(util.min()) if n else 0.0,
            trims=trims,
            ftl_writes=ftl_w,
            ftl_gc_copies=ftl_c,
            tenant_stats=tstats,
            share_error=share_err,
            faults=inj.finalize(loop.now) if inj is not None else None,
            telemetry=self.last_telemetry,
            monitor=self.last_monitor,
            **gkw,
        )

    def run_phased(self, phases) -> "list[tuple[str, ArrayResults]]":
        """Drive a phased scenario: one ``run()`` call per
        :class:`~repro.core.workloads.Phase`, swapping ``self.source`` at
        each boundary (``run`` re-binds the source on entry). FTL and GC
        state persist across phases, so a preconditioning phase is just an
        unmeasured leading phase — no ad-hoc prefill flags. Returns
        ``(phase.name, results)`` for every ``measure=True`` phase;
        unmeasured phases still run their full budget."""
        out = []
        for ph in phases:
            self.source = ph.source
            res = self.run(ph.ops, ph.warmup)
            if ph.measure:
                out.append((ph.name, res))
        return out

    def _gc_window_stats(self, coord, loop, span: float) -> dict:
        """Close the coordinator's window and return the ``ArrayResults``
        coordination kwargs (empty for ``gc=None`` — dataclass defaults
        describe the reactive story). Also latches ``last_gc_wait`` for the
        sharded pooled-sample merge."""
        if coord is None:
            self.last_gc_wait = None
            return {}
        coord.finalize(loop.now)
        self.last_gc_wait = coord.wait_rec.values()
        return coord.window_stats(span)


    # -- layout-general loop (RAID-0 / RAID-5; JBOD keeps the fast path) -----
    def _run_layout(self, measure_ops: int,
                    warmup_ops: int | None = None) -> ArrayResults:
        """Run with a non-trivial array layout: each logical op is lowered by
        the layout's planner into phases of per-SSD page children
        (``core/raid.py``); the op completes with its LAST child, so a stripe
        write synchronizes on the slowest member — one straggling mid-GC SSD
        stalls every stripe touching it, which is the paper's imbalance
        magnified by striping. The submission machinery (windowed streams,
        bounded per-SSD host queues, head-of-line parking) mirrors the fast
        path; RMW/reconstruction follow-on phases and detached background
        plans (catch-up parity) bypass the qd bound like device-internal
        traffic, so they can never deadlock against a full host queue."""
        from .raid import RebuildSource
        n, wl = self.n, self.wl
        layout = self.layout
        planner = layout.make_planner(n, self.live_per_ssd)
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        total_ops = warmup_ops + measure_ops
        loop = EventLoop()
        tel = self._make_telemetry(loop)
        mon = self._make_monitor(loop, tel)
        tel_spans = tel is not None and tel.spans_on
        qd = wl.qd_per_ssd
        coord = self.gc.make_coordinator(n, loop, self.layout.shard_unit(n)) \
            if self.gc is not None else None
        steer_on = coord is not None and coord.steer
        steer_qd = min(qd, coord.steer_qd) if steer_on else qd
        gc_busy = coord.gc_busy if coord is not None else None
        if steer_on:
            # RAID-5 read redirection: the planner serves reads of a GC-busy
            # member by reconstruction from its row siblings
            planner.gc_busy = gc_busy

        # fault injection (core/faults.py): inj=None keeps this loop
        # byte-identical to the pre-fault path. On top of the fast loop's
        # FailSlow/MediaError/quarantine, parity layouts add hedged reads
        # (sibling reconstruction racing a slow member) and mid-run Crash
        # (the group flips degraded and the rebuild stream opens live).
        inj = self._make_injector()
        if coord is not None and inj is not None and inj.detect:
            coord.quarantined = inj.quarantined
        media_on = inj is not None and inj.any_media
        hedge_on = inj is not None and inj.hedge_after > 0.0 and layout.parity
        crash = inj.crash_event if inj is not None else None
        qcap: "list[int] | None" = None
        if inj is not None and inj.detect:
            qcap = [qd] * n
            q_lo = min(qd, inj.policy.quarantine_qd)

            def _apply_q(i: int) -> None:
                qcap[i] = q_lo

            def _lift_q(i: int) -> None:
                qcap[i] = qd
                unpark(i)
            inj.on_quarantine = _apply_q
            inj.on_release = _lift_q
            if layout.parity:
                # steer reads away from quarantined members exactly like
                # GC-busy ones (reconstruct from row siblings)
                planner.avoid = inj.quarantined

        n_fg = max(1, wl.n_streams)
        rebuild_on = bool(getattr(planner, "rebuild", False))
        has_rebuild_stream = rebuild_on or crash is not None
        n_streams = n_fg + (1 if has_rebuild_stream else 0)
        window = max(1, wl.w_total // n_fg)
        windows = [window] * n_fg
        srcs = [self.source] * n_fg
        rebuild_st = n_fg
        rebuild_need = [0]     # rows to rebuild after a mid-run crash
        if has_rebuild_stream:
            # a crash pre-allocates the rebuild stream with a closed window
            # (0): it opens at crash time and closes again once the dead
            # member's rows are reconstructed
            windows.append(0 if not rebuild_on
                           else max(1, layout.rebuild_window))
            srcs.append(RebuildSource())

        outstanding = [0] * n_streams
        pending: list[deque] = [deque() for _ in range(n_streams)]
        parked = [False] * n_streams
        sleeping = [False] * n_streams
        waiters: list[deque] = [deque() for _ in range(n)]
        host_queues: list[deque] = [deque() for _ in range(n)]
        ssds = self.ssds

        measured = [0] * n           # per-SSD child completions in-window
        mr = [0, 0]                  # measured logical [reads, writes]
        rebuild_done = [0]
        ftl_snap = [(0, 0, 0)] * n
        stall = LatencyRecorder()
        stat_snap = [planner.snapshot()]

        def begin_measure():
            measured[:] = [0] * n
            mr[0] = mr[1] = 0
            for ss in ssds:
                ss.busy_time = 0.0
                ss.gc_time = 0.0
            ftl_snap[:] = [(s.ftl.writes, s.ftl.gc_copies, s.ftl.trims)
                           for s in ssds]
            stat_snap[0] = planner.snapshot()
            stall.reset()
            if coord is not None:
                coord.begin_measure(loop.now)
            if mon is not None:
                mon.begin_measure(loop.now)

        mw = MeasurementWindow(loop, warmup_ops, begin_measure,
                               target=total_ops)
        note_completion = mw.note_completion
        # nominal per-kind media time, the span "service" component
        # (indexed by OP_* kind; only read under tel_spans)
        svc_k = (self.p.t_read, self.p.t_prog, self.p.t_trim, self.p.t_prog)

        def make_pull(i: int):
            hq = host_queues[i]
            return lambda: hq.popleft() if hq else None

        def make_service_time(i: int):
            t_read, t_prog = self.p.t_read, self.p.t_prog
            t_coal, t_trim = self.p.t_coalesce, self.p.t_trim

            def service_time(req):
                if req[3]:
                    return t_coal
                k = req[2]
                if k == OP_READ:
                    return t_read
                return t_trim if k == OP_TRIM else t_prog
            if inj is not None and (inj.detect or inj.has_slow(i)):
                return inj.wrap_service_time(i, service_time, loop)
            return service_time

        # child requests are (plan, member_lba, kind, coal) — plus a trailing
        # attempt counter when media errors are configured
        def enqueue_child(plan, ssd_i: int, lba: int, kind: int):
            coal = False
            if kind == OP_WRITE:
                pw = ssds[ssd_i].pending_writes
                c = pw.get(lba)
                if c is None:
                    pw[lba] = 1
                else:
                    coal = True
                    pw[lba] = c + 1
            sp = plan.span
            if sp is not None and sp.t_admit < 0.0:
                tel.note_admit(sp, loop.now)   # first child admission
            if media_on:
                req = (plan, lba, kind, coal, 0)
            else:
                req = (plan, lba, kind, coal)
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def reissue_child(args):
            # media-error retry landing after its backoff (mirror of
            # enqueue_child's tail; coalescing state is already held)
            i, req = args
            hq = host_queues[i]
            dev = devices[i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def submit_phase(plan):
            children = plan.phases[plan.phase_i]
            plan.remaining = len(children)
            for ssd_i, lba, kind in children:
                enqueue_child(plan, ssd_i, lba, kind)

        def finish_plan(plan):
            h = plan.hedge
            if h is not None:
                if h[0]:
                    return   # the other leg already completed this op
                h[0] = True  # first completion wins; the loser early-returns
                if plan is not h[1]:
                    inj.note_hedge_win()
                    plan = h[1]   # complete on behalf of the primary
            st = plan.stream
            if st >= 0:
                outstanding[st] -= 1
            if plan.measured:
                m = note_completion(plan.t_issue)
                if m:
                    if plan.kind == OP_READ:
                        mr[0] += 1
                    else:
                        mr[1] += 1
                if plan.stall_track and mw.measuring and plan.t_first >= 0.0:
                    stall.record(plan.t_last - plan.t_first)
                sp = plan.span
                if sp is not None:
                    sync = plan.t_last - plan.t_first \
                        if plan.t_first >= 0.0 else 0.0
                    tel.close_plan_span(sp, loop.now, sync,
                                        svc_k[plan.kind], m)
            elif plan.kind == OP_REBUILD:
                rebuild_done[0] += 1
                if rebuild_need[0] and rebuild_done[0] >= rebuild_need[0]:
                    # crash rebuild complete: close the stream's window
                    # BEFORE healing so stream_fill never spins on a planner
                    # with no rebuild groups left
                    rebuild_need[0] = 0
                    windows[rebuild_st] = 0
                    planner.heal_member(crash.device)
                    inj.note_rebuild_complete(loop.now)
            if st >= 0:
                stream_fill(st)

        def make_on_done(i: int):
            s = ssds[i]
            ftl = s.ftl
            program = ftl._program
            pw = s.pending_writes
            w = waiters[i]

            if media_on:
                def on_done(req):
                    plan, lba, kind, coal, att = req
                    if kind == OP_READ:
                        if inj.read_fails(i):
                            retry, delay = inj.retry_decision(
                                att, plan.t_issue, loop.now)
                            if retry:
                                sp = plan.span
                                if sp is not None:
                                    tel.note_retry(sp, loop.now)
                                loop.call_at(loop.now + delay, reissue_child,
                                             (i, (plan, lba, kind, coal,
                                                  att + 1)))
                                if w:
                                    unpark(i)
                                return
                            # exhausted/timed out: the child completes as a
                            # failed read so the plan can't wedge
                        s.served_reads += 1
                    elif kind == OP_TRIM:
                        ftl.trim(lba)
                        s.served_trims += 1
                    else:
                        s.served_writes += 1
                        c = pw[lba] - 1
                        if c:
                            pw[lba] = c
                        else:
                            del pw[lba]
                        if not coal:      # inlined ftl.user_write
                            program(lba)
                            ftl.writes += 1
                    if mw.measuring:
                        measured[i] += 1
                    now = loop.now
                    if plan.t_first < 0.0:
                        plan.t_first = now
                    plan.t_last = now
                    r = plan.remaining - 1
                    plan.remaining = r
                    if r == 0:
                        nxt = plan.phase_i + 1
                        if nxt < len(plan.phases):
                            plan.phase_i = nxt
                            plan.t_first = -1.0
                            submit_phase(plan)
                        else:
                            finish_plan(plan)
                    if w:
                        unpark(i)
                return on_done

            def on_done(req):
                plan, lba, kind, coal = req
                if kind == OP_READ:
                    s.served_reads += 1
                elif kind == OP_TRIM:
                    ftl.trim(lba)
                    s.served_trims += 1
                else:
                    s.served_writes += 1
                    c = pw[lba] - 1
                    if c:
                        pw[lba] = c
                    else:
                        del pw[lba]
                    if not coal:      # inlined ftl.user_write
                        program(lba)
                        ftl.writes += 1
                if mw.measuring:
                    measured[i] += 1
                now = loop.now
                if plan.t_first < 0.0:
                    plan.t_first = now
                plan.t_last = now
                r = plan.remaining - 1
                plan.remaining = r
                if r == 0:
                    nxt = plan.phase_i + 1
                    if nxt < len(plan.phases):
                        plan.phase_i = nxt
                        plan.t_first = -1.0   # stall spans the final phase
                        submit_phase(plan)
                    else:
                        finish_plan(plan)
                if w:
                    unpark(i)
            return on_done

        devices = [DeviceModel(loop, ssds[i], make_pull(i),
                               make_service_time(i), make_on_done(i),
                               backlog=host_queues[i],
                               gc_coord=coord, dev_id=i)
                   for i in range(n)]
        if coord is not None:
            for i, d in enumerate(devices):
                coord.attach(d, i)
        if tel is not None:
            tel.register_array_probes(ssds, devices, host_queues)
        if mon is not None:
            mon.register_array_sources(ssds, devices, host_queues, qd,
                                       inj=inj)

        def try_drain(st: int) -> bool:
            """Place the stream's pending children in order; parks the stream
            (False) when a target host queue is at the qd bound (steering
            caps GC-busy members at ``steer_qd``)."""
            pend = pending[st]
            while pend:
                ssd_i, lba, kind, plan = pend[0]
                dev = devices[ssd_i]
                q = steer_qd if steer_on and gc_busy[ssd_i] else qd
                if qcap is not None and qcap[ssd_i] < q:
                    q = qcap[ssd_i]     # quarantined member: shrink admission
                if len(host_queues[ssd_i]) + len(dev.admitted) \
                        + dev.in_service < q:
                    pend.popleft()
                    enqueue_child(plan, ssd_i, lba, kind)
                else:
                    parked[st] = True
                    waiters[ssd_i].append(st)
                    return False
            return True

        def maybe_hedge(plan):
            """Hedged-read deadline fired: if the primary is still pending,
            race a sibling-reconstruction leg against it. Both legs share
            ``plan.hedge = [done, primary]``; the first completion flips
            ``done`` and the loser is discarded in finish_plan (the same
            stale-check shape as the flusher's lost-write epoch guard)."""
            h = plan.hedge
            if h[0]:
                return
            tgt, lba, _k = plan.phases[0][0]
            hp = planner.hedge_plan(tgt, lba)
            if hp is None:      # group went degraded meanwhile: the planner
                return          # would reconstruct from a missing member
            inj.note_hedge()
            sp = plan.span
            if sp is not None:
                tel.note_hedge_issue(sp, loop.now)
            hp.hedge = h
            hp.t_issue = plan.t_issue
            submit_phase(hp)    # latency rescue: bypasses the qd bound

        def issue_op(st: int, op) -> bool:
            plan, detached = planner.plan(op)
            if plan is None:          # host-level no-op (e.g. TRIM whose
                return True           # only target is the failed member)
            plan.stream = st
            plan.t_issue = loop.now
            if tel_spans and plan.measured:
                plan.span = tel.new_plan_span(
                    plan.kind, st, _plan_devs(plan), loop.now)
            outstanding[st] += 1
            if detached:
                for d in detached:
                    d.t_issue = loop.now
                    submit_phase(d)   # background: bypasses the qd bound
            children = plan.phases[0]
            if hedge_on and plan.kind == OP_READ and len(children) == 1 \
                    and len(plan.phases) == 1:
                # healthy single-member striped read: arm the hedge deadline
                plan.hedge = [False, plan]
                loop.call_at(loop.now + inj.hedge_after, maybe_hedge, plan)
            plan.remaining = len(children)
            pend = pending[st]
            for ch in children:
                pend.append((ch[0], ch[1], ch[2], plan))
            return try_drain(st)

        def wake(args):
            st, op = args
            sleeping[st] = False
            if issue_op(st, op):
                stream_fill(st)

        def stream_fill(st: int):
            if parked[st] or sleeping[st] or pending[st]:
                return
            win = windows[st]
            src = srcs[st]
            next_op = src.next_op
            while outstanding[st] < win:
                op = next_op(loop.now)
                if op.at > loop.now:
                    sleeping[st] = True
                    loop.call_at(op.at, wake, (st, op))
                    return
                if not issue_op(st, op):
                    return

        def unpark(ssd_i: int):
            w = waiters[ssd_i]
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            while w:
                q = steer_qd if steer_on and gc_busy[ssd_i] else qd
                if qcap is not None and qcap[ssd_i] < q:
                    q = qcap[ssd_i]
                if len(hq) + len(dev.admitted) + dev.in_service >= q:
                    break
                st = w.popleft()
                parked[st] = False
                if try_drain(st):
                    stream_fill(st)

        if crash is not None:
            def on_crash(_):
                # instant spare swap: children already queued or in flight
                # drain to the spare unchanged — only NEW plans see the group
                # as degraded. The pre-allocated rebuild stream opens here.
                inj.note_crash(crash.device, loop.now)
                rebuild_need[0] = planner.fail_member(crash.device)
                windows[rebuild_st] = max(1, layout.rebuild_window)
                stream_fill(rebuild_st)
            loop.call_at(crash.at_time, on_crash, None)

        if coord is not None:
            coord.on_release = unpark
        for si in range(n_streams):
            stream_fill(si)

        t_wall = time.perf_counter()
        events = loop.run() if total_ops > 0 else 0
        wall_s = time.perf_counter() - t_wall

        span = mw.span
        if tel is not None:
            tel.finalize(loop.now, mw.t0)
        if mon is not None:
            mon.finalize(loop.now)
        summ = mw.latency.summary()
        stall_summ = stall.summary()
        self.last_latency = mw.latency.values()
        self.last_stall = stall.values()
        self.last_tenant_latency = None
        self.last_telemetry = tel.result() if tel is not None else None
        self.last_monitor = mon.result() if mon is not None else None
        measured_arr = np.asarray(measured, dtype=np.int64)
        util, ftl_w, ftl_c, trims, gc_wa = _ftl_window_stats(
            ssds, ftl_snap, span, self.p.channels)
        if tel is not None and tel.has_series("busy_time"):
            util = tel.util_final(span, self.p.channels)
        sd = planner.delta(stat_snap[0])
        parity_wa = sd["child_writes"] / sd["logical_writes"] \
            if sd["logical_writes"] else 1.0
        gkw = self._gc_window_stats(coord, loop, span)
        return ArrayResults(
            iops=float(summ.n / span),
            per_ssd_iops=measured_arr / span,
            read_iops=mr[0] / span,
            write_iops=mr[1] / span,
            util=util,
            sim_time=span,
            gc_pause_frac=np.array([s.gc_time / span for s in ssds]),
            mean_latency=summ.mean,
            p50_latency=summ.p50,
            p95_latency=summ.p95,
            p99_latency=summ.p99,
            events=events,
            wall_s=wall_s,
            layout=layout.name,
            parity_wa=parity_wa,
            gc_wa=gc_wa,
            array_wa=parity_wa * gc_wa,
            stripe_stall_mean=stall_summ.mean,
            stripe_stall_p99=stall_summ.p99,
            util_spread=float(util.max() - util.min()) if n else 0.0,
            util_min=float(util.min()) if n else 0.0,
            logical_writes=sd["logical_writes"],
            child_writes=sd["child_writes"],
            child_reads=sd["child_reads"],
            parity_writes=sd["parity_writes"],
            full_stripe_rows=sd["full_stripe_rows"],
            rmw_ops=sd["rmw_ops"],
            degraded_reads=sd["degraded_reads"],
            rebuild_rows=rebuild_done[0],
            trims=trims,
            trim_parity_skipped=sd["trim_parity_skipped"],
            steered_reads=sd["steered_reads"],
            ftl_writes=ftl_w,
            ftl_gc_copies=ftl_c,
            faults=inj.finalize(loop.now) if inj is not None else None,
            telemetry=self.last_telemetry,
            monitor=self.last_monitor,
            **gkw,
        )

    # -- QoS admission loop (per-tenant streams; core/qos.py) ----------------
    def _run_qos(self, measure_ops: int,
                 warmup_ops: int | None = None) -> ArrayResults:
        """Run with a :class:`~.qos.QosPolicy` at the host admission point.

        Each tenant is its own greedy closed-loop stream (source built from
        its ``TenantSpec``); all tenants share the host window ``w_total``,
        and whenever a window slot frees the ``QosScheduler`` — deficit
        round robin over tenant classes, gated by token buckets, throttled
        by the SLO controller — decides whose op is admitted next. Per-SSD
        host queues, qd-bound parking, NCQ service, and the layout planner
        machinery are exactly the `_run_layout` discipline (JBOD runs
        through the trivial pass-through planner here; the ``qos=None``
        JBOD path keeps the byte-identical fast loop).

        The child-lifecycle helpers (enqueue_child / submit_phase / on_done /
        try_drain / unpark) are deliberate copies of ``_run_layout``'s — the
        run loops stay closure-flat on the hot path instead of sharing
        through injected callbacks. A semantic fix to the child lifecycle in
        either loop MUST be mirrored in the other; the fill policy and the
        per-tenant telemetry are the only intended differences."""
        from .qos import (QosScheduler, build_tenant_stats, tenant_rng_seed,
                          tenant_source)
        from .raid import RebuildSource
        n, wl = self.n, self.wl
        policy = self.qos
        layout = self.layout
        planner = layout.make_planner(n, self.live_per_ssd)
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        total_ops = warmup_ops + measure_ops
        loop = EventLoop()
        tel = self._make_telemetry(loop)
        mon = self._make_monitor(loop, tel)
        tel_spans = tel is not None and tel.spans_on
        qd = wl.qd_per_ssd
        W = max(1, wl.w_total)
        coord = self.gc.make_coordinator(n, loop, self.layout.shard_unit(n)) \
            if self.gc is not None else None
        steer_on = coord is not None and coord.steer
        steer_qd = min(qd, coord.steer_qd) if steer_on else qd
        gc_busy = coord.gc_busy if coord is not None else None
        if steer_on:
            planner.gc_busy = gc_busy

        # fault injection: the same wiring as _run_layout (see the MUST-mirror
        # note in the docstring); only the rebuild stream index (n_t) and the
        # window bookkeeping (rebuild_win) differ
        inj = self._make_injector()
        if coord is not None and inj is not None and inj.detect:
            coord.quarantined = inj.quarantined
        media_on = inj is not None and inj.any_media
        hedge_on = inj is not None and inj.hedge_after > 0.0 and layout.parity
        crash = inj.crash_event if inj is not None else None
        qcap: "list[int] | None" = None
        if inj is not None and inj.detect:
            qcap = [qd] * n
            q_lo = min(qd, inj.policy.quarantine_qd)

            def _apply_q(i: int) -> None:
                qcap[i] = q_lo

            def _lift_q(i: int) -> None:
                qcap[i] = qd
                unpark(i)
            inj.on_quarantine = _apply_q
            inj.on_release = _lift_q
            if layout.parity:
                planner.avoid = inj.quarantined

        ids = list(policy.ids)
        n_t = len(ids)
        idx_of = {t: i for i, t in enumerate(ids)}
        sched = QosScheduler(policy)
        # per-tenant greedy sources on decorrelated RNG streams (the prefill
        # RNG is untouched, so prefill state matches the qos=None build)
        srcs: list = [
            tenant_source(policy.spec(t), self.n_live,
                          np.random.default_rng(tenant_rng_seed(self.seed, t)))
            for t in ids
        ]
        rebuild_on = bool(getattr(planner, "rebuild", False))
        has_rebuild_stream = rebuild_on or crash is not None
        n_streams = n_t + (1 if has_rebuild_stream else 0)
        rebuild_need = [0]
        # rebuild window, mutable: 0 = closed (pre-crash / post-rebuild)
        rebuild_win = [max(1, layout.rebuild_window) if rebuild_on else 0]
        if has_rebuild_stream:
            srcs.append(RebuildSource())

        outstanding = [0] * n_streams
        total_out = [0]              # tenant (non-rebuild) plans in flight
        pending: list[deque] = [deque() for _ in range(n_streams)]
        parked = [False] * n_streams
        sleeping = [False] * n_streams
        waiters: list[deque] = [deque() for _ in range(n)]
        host_queues: list[deque] = [deque() for _ in range(n)]
        ssds = self.ssds

        measured = [0] * n
        mr = [0, 0]
        rebuild_done = [0]
        ftl_snap = [(0, 0, 0)] * n
        stall = LatencyRecorder()
        stat_snap = [planner.snapshot()]
        trec = {t: LatencyRecorder() for t in ids}
        thr_snap = {t: 0.0 for t in ids}

        def begin_measure():
            measured[:] = [0] * n
            mr[0] = mr[1] = 0
            for ss in ssds:
                ss.busy_time = 0.0
                ss.gc_time = 0.0
            ftl_snap[:] = [(s.ftl.writes, s.ftl.gc_copies, s.ftl.trims)
                           for s in ssds]
            stat_snap[0] = planner.snapshot()
            stall.reset()
            for r in trec.values():
                r.reset()
            now = loop.now
            for t in ids:
                thr_snap[t] = sched.throttle_time(t, now)
            if coord is not None:
                coord.begin_measure(loop.now)
            if mon is not None:
                mon.begin_measure(loop.now)

        mw = MeasurementWindow(loop, warmup_ops, begin_measure,
                               target=total_ops)
        note_completion = mw.note_completion
        # nominal per-kind media time, the span "service" component
        # (indexed by OP_* kind; only read under tel_spans)
        svc_k = (self.p.t_read, self.p.t_prog, self.p.t_trim, self.p.t_prog)

        def make_pull(i: int):
            hq = host_queues[i]
            return lambda: hq.popleft() if hq else None

        def make_service_time(i: int):
            t_read, t_prog = self.p.t_read, self.p.t_prog
            t_coal, t_trim = self.p.t_coalesce, self.p.t_trim

            def service_time(req):
                if req[3]:
                    return t_coal
                k = req[2]
                if k == OP_READ:
                    return t_read
                return t_trim if k == OP_TRIM else t_prog
            if inj is not None and (inj.detect or inj.has_slow(i)):
                return inj.wrap_service_time(i, service_time, loop)
            return service_time

        # child requests are (plan, member_lba, kind, coal) — plus a trailing
        # attempt counter when media errors are configured
        def enqueue_child(plan, ssd_i: int, lba: int, kind: int):
            coal = False
            if kind == OP_WRITE:
                pw = ssds[ssd_i].pending_writes
                c = pw.get(lba)
                if c is None:
                    pw[lba] = 1
                else:
                    coal = True
                    pw[lba] = c + 1
            sp = plan.span
            if sp is not None and sp.t_admit < 0.0:
                tel.note_admit(sp, loop.now)   # first child admission
            if media_on:
                req = (plan, lba, kind, coal, 0)
            else:
                req = (plan, lba, kind, coal)
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def reissue_child(args):
            # media-error retry landing after its backoff (mirror of
            # enqueue_child's tail; coalescing state is already held)
            i, req = args
            hq = host_queues[i]
            dev = devices[i]
            if hq:
                hq.append(req)
                dev.kick()
            elif not dev.offer(req):
                hq.append(req)

        def submit_phase(plan):
            children = plan.phases[plan.phase_i]
            plan.remaining = len(children)
            for ssd_i, lba, kind in children:
                enqueue_child(plan, ssd_i, lba, kind)

        def finish_plan(plan):
            h = plan.hedge
            if h is not None:
                if h[0]:
                    return   # the other leg already completed this op
                h[0] = True  # first completion wins; the loser early-returns
                if plan is not h[1]:
                    inj.note_hedge_win()
                    plan = h[1]   # complete on behalf of the primary
            st = plan.stream
            tenant_plan = 0 <= st < n_t
            if st >= 0:
                outstanding[st] -= 1
                if tenant_plan:
                    total_out[0] -= 1
            if plan.measured:
                now = loop.now
                if tenant_plan:
                    # the SLO controller sees the FULL latency stream
                    # (warmup included) so throttling reaches steady state
                    # before the measurement window opens
                    sched.note_completion(ids[st], now - plan.t_issue, now)
                    if mon is not None:
                        mon.note_completion(ids[st], now - plan.t_issue, now)
                m = note_completion(plan.t_issue)
                if m:
                    if plan.kind == OP_READ:
                        mr[0] += 1
                    else:
                        mr[1] += 1
                    if tenant_plan:
                        trec[ids[st]].record(now - plan.t_issue)
                if plan.stall_track and mw.measuring and plan.t_first >= 0.0:
                    stall.record(plan.t_last - plan.t_first)
                sp = plan.span
                if sp is not None:
                    sync = plan.t_last - plan.t_first \
                        if plan.t_first >= 0.0 else 0.0
                    tel.close_plan_span(sp, loop.now, sync,
                                        svc_k[plan.kind], m)
            elif plan.kind == OP_REBUILD:
                rebuild_done[0] += 1
                if rebuild_need[0] and rebuild_done[0] >= rebuild_need[0]:
                    # crash rebuild complete: close the window BEFORE healing
                    # so rebuild_fill never spins on an empty planner
                    rebuild_need[0] = 0
                    rebuild_win[0] = 0
                    planner.heal_member(crash.device)
                    inj.note_rebuild_complete(loop.now)
            if tenant_plan:
                qos_fill()
            elif st >= 0:
                rebuild_fill()

        def make_on_done(i: int):
            s = ssds[i]
            ftl = s.ftl
            program = ftl._program
            pw = s.pending_writes
            w = waiters[i]

            if media_on:
                def on_done(req):
                    plan, lba, kind, coal, att = req
                    if kind == OP_READ:
                        if inj.read_fails(i):
                            retry, delay = inj.retry_decision(
                                att, plan.t_issue, loop.now)
                            if retry:
                                sp = plan.span
                                if sp is not None:
                                    tel.note_retry(sp, loop.now)
                                loop.call_at(loop.now + delay, reissue_child,
                                             (i, (plan, lba, kind, coal,
                                                  att + 1)))
                                if w:
                                    unpark(i)
                                return
                            # exhausted/timed out: the child completes as a
                            # failed read so the plan can't wedge
                        s.served_reads += 1
                    elif kind == OP_TRIM:
                        ftl.trim(lba)
                        s.served_trims += 1
                    else:
                        s.served_writes += 1
                        c = pw[lba] - 1
                        if c:
                            pw[lba] = c
                        else:
                            del pw[lba]
                        if not coal:      # inlined ftl.user_write
                            program(lba)
                            ftl.writes += 1
                    if mw.measuring:
                        measured[i] += 1
                    now = loop.now
                    if plan.t_first < 0.0:
                        plan.t_first = now
                    plan.t_last = now
                    r = plan.remaining - 1
                    plan.remaining = r
                    if r == 0:
                        nxt = plan.phase_i + 1
                        if nxt < len(plan.phases):
                            plan.phase_i = nxt
                            plan.t_first = -1.0
                            submit_phase(plan)
                        else:
                            finish_plan(plan)
                    if w:
                        unpark(i)
                return on_done

            def on_done(req):
                plan, lba, kind, coal = req
                if kind == OP_READ:
                    s.served_reads += 1
                elif kind == OP_TRIM:
                    ftl.trim(lba)
                    s.served_trims += 1
                else:
                    s.served_writes += 1
                    c = pw[lba] - 1
                    if c:
                        pw[lba] = c
                    else:
                        del pw[lba]
                    if not coal:      # inlined ftl.user_write
                        program(lba)
                        ftl.writes += 1
                if mw.measuring:
                    measured[i] += 1
                now = loop.now
                if plan.t_first < 0.0:
                    plan.t_first = now
                plan.t_last = now
                r = plan.remaining - 1
                plan.remaining = r
                if r == 0:
                    nxt = plan.phase_i + 1
                    if nxt < len(plan.phases):
                        plan.phase_i = nxt
                        plan.t_first = -1.0
                        submit_phase(plan)
                    else:
                        finish_plan(plan)
                if w:
                    unpark(i)
            return on_done

        devices = [DeviceModel(loop, ssds[i], make_pull(i),
                               make_service_time(i), make_on_done(i),
                               backlog=host_queues[i],
                               gc_coord=coord, dev_id=i)
                   for i in range(n)]
        if coord is not None:
            for i, d in enumerate(devices):
                coord.attach(d, i)
        if tel is not None:
            tel.register_array_probes(ssds, devices, host_queues)
        if mon is not None:
            mon.register_array_sources(ssds, devices, host_queues, qd,
                                       inj=inj, sched=sched)

        def try_drain(st: int) -> bool:
            pend = pending[st]
            while pend:
                ssd_i, lba, kind, plan = pend[0]
                dev = devices[ssd_i]
                q = steer_qd if steer_on and gc_busy[ssd_i] else qd
                if qcap is not None and qcap[ssd_i] < q:
                    q = qcap[ssd_i]     # quarantined member: shrink admission
                if len(host_queues[ssd_i]) + len(dev.admitted) \
                        + dev.in_service < q:
                    pend.popleft()
                    enqueue_child(plan, ssd_i, lba, kind)
                else:
                    parked[st] = True
                    waiters[ssd_i].append(st)
                    return False
            return True

        def maybe_hedge(plan):
            # see _run_layout.maybe_hedge — shared [done, primary] record,
            # first completion wins, loser discarded in finish_plan
            h = plan.hedge
            if h[0]:
                return
            tgt, lba, _k = plan.phases[0][0]
            hp = planner.hedge_plan(tgt, lba)
            if hp is None:
                return
            inj.note_hedge()
            sp = plan.span
            if sp is not None:
                tel.note_hedge_issue(sp, loop.now)
            hp.hedge = h
            hp.t_issue = plan.t_issue
            submit_phase(hp)    # latency rescue: bypasses the qd bound

        def issue_op(st: int, op) -> None:
            plan, detached = planner.plan(op)
            if plan is None:
                return
            plan.stream = st
            plan.t_issue = loop.now
            if tel_spans and plan.measured:
                plan.span = tel.new_plan_span(
                    plan.kind, ids[st] if st < n_t else -1,
                    _plan_devs(plan), loop.now)
            outstanding[st] += 1
            if st < n_t:
                total_out[0] += 1
            if detached:
                for d in detached:
                    d.t_issue = loop.now
                    submit_phase(d)
            children = plan.phases[0]
            if hedge_on and plan.kind == OP_READ and len(children) == 1 \
                    and len(plan.phases) == 1:
                plan.hedge = [False, plan]
                loop.call_at(loop.now + inj.hedge_after, maybe_hedge, plan)
            plan.remaining = len(children)
            pend = pending[st]
            for ch in children:
                pend.append((ch[0], ch[1], ch[2], plan))
            try_drain(st)

        def ready(t: int) -> bool:
            """Tenant can take a window slot right now: not parked on a full
            device queue, not sleeping on an open-loop arrival, no undrained
            children (head-of-line)."""
            i = idx_of[t]
            return not (parked[i] or sleeping[i] or pending[i])

        rate_wake = [False]

        def rate_fire(_=None):
            rate_wake[0] = False
            qos_fill()

        def tenant_wake(args):
            st, op = args
            sleeping[st] = False
            issue_op(st, op)
            qos_fill()

        def qos_fill():
            """Fill the shared window: the scheduler picks the next tenant
            per admission until the window is full or nobody is eligible
            (every ready tenant rate-blocked -> wake at the next token)."""
            while total_out[0] < W:
                now = loop.now
                t = sched.pick(now, ready)
                if t is None:
                    if not rate_wake[0]:
                        tr = sched.next_release(now, ready)
                        if tr is not None:
                            rate_wake[0] = True
                            loop.call_at(tr, rate_fire)
                    return
                st = idx_of[t]
                op = srcs[st].next_op(now)
                if op.at > now:
                    sleeping[st] = True
                    loop.call_at(op.at, tenant_wake, (st, op))
                    continue
                issue_op(st, op)

        def rebuild_fill():
            st = n_t
            if parked[st] or pending[st]:
                return
            win = rebuild_win[0]
            src = srcs[st]
            while outstanding[st] < win:
                issue_op(st, src.next_op(loop.now))
                if parked[st]:
                    return

        def unpark(ssd_i: int):
            w = waiters[ssd_i]
            hq = host_queues[ssd_i]
            dev = devices[ssd_i]
            freed_tenant = False
            while w:
                q = steer_qd if steer_on and gc_busy[ssd_i] else qd
                if qcap is not None and qcap[ssd_i] < q:
                    q = qcap[ssd_i]
                if len(hq) + len(dev.admitted) + dev.in_service >= q:
                    break
                st = w.popleft()
                parked[st] = False
                if try_drain(st):
                    if st < n_t:
                        freed_tenant = True
                    else:
                        rebuild_fill()
            if freed_tenant:
                qos_fill()

        if crash is not None:
            def on_crash(_):
                # mirror of _run_layout.on_crash: instant spare swap, only
                # NEW plans see the group degraded, rebuild stream opens
                inj.note_crash(crash.device, loop.now)
                rebuild_need[0] = planner.fail_member(crash.device)
                rebuild_win[0] = max(1, layout.rebuild_window)
                rebuild_fill()
            loop.call_at(crash.at_time, on_crash, None)

        if coord is not None:
            coord.on_release = unpark
        qos_fill()
        if rebuild_on:
            rebuild_fill()

        t_wall = time.perf_counter()
        events = loop.run() if total_ops > 0 else 0
        wall_s = time.perf_counter() - t_wall

        span = mw.span
        if tel is not None:
            tel.finalize(loop.now, mw.t0)
        if mon is not None:
            mon.finalize(loop.now)
        summ = mw.latency.summary()
        stall_summ = stall.summary()
        self.last_latency = mw.latency.values()
        self.last_stall = stall.values()
        self.last_tenant_latency = {t: trec[t].values() for t in ids}
        self.last_telemetry = tel.result() if tel is not None else None
        self.last_monitor = mon.result() if mon is not None else None
        measured_arr = np.asarray(measured, dtype=np.int64)
        util, ftl_w, ftl_c, trims, gc_wa = _ftl_window_stats(
            ssds, ftl_snap, span, self.p.channels)
        if tel is not None and tel.has_series("busy_time"):
            util = tel.util_final(span, self.p.channels)
        sd = planner.delta(stat_snap[0])
        parity_wa = sd["child_writes"] / sd["logical_writes"] \
            if sd["logical_writes"] else 1.0
        now = loop.now
        throttle_times = {t: sched.throttle_time(t, now) - thr_snap[t]
                          for t in ids}
        tstats, share_error = build_tenant_stats(policy, trec, span,
                                                 throttle_times)
        gkw = self._gc_window_stats(coord, loop, span)
        return ArrayResults(
            iops=float(summ.n / span),
            per_ssd_iops=measured_arr / span,
            read_iops=mr[0] / span,
            write_iops=mr[1] / span,
            util=util,
            sim_time=span,
            gc_pause_frac=np.array([s.gc_time / span for s in ssds]),
            mean_latency=summ.mean,
            p50_latency=summ.p50,
            p95_latency=summ.p95,
            p99_latency=summ.p99,
            events=events,
            wall_s=wall_s,
            layout=layout.name,
            parity_wa=parity_wa,
            gc_wa=gc_wa,
            array_wa=parity_wa * gc_wa,
            stripe_stall_mean=stall_summ.mean,
            stripe_stall_p99=stall_summ.p99,
            util_spread=float(util.max() - util.min()) if n else 0.0,
            util_min=float(util.min()) if n else 0.0,
            logical_writes=sd["logical_writes"],
            child_writes=sd["child_writes"],
            child_reads=sd["child_reads"],
            parity_writes=sd["parity_writes"],
            full_stripe_rows=sd["full_stripe_rows"],
            rmw_ops=sd["rmw_ops"],
            degraded_reads=sd["degraded_reads"],
            rebuild_rows=rebuild_done[0],
            trims=trims,
            trim_parity_skipped=sd["trim_parity_skipped"],
            steered_reads=sd["steered_reads"],
            ftl_writes=ftl_w,
            ftl_gc_copies=ftl_c,
            tenant_stats=tstats,
            share_error=share_error,
            faults=inj.finalize(loop.now) if inj is not None else None,
            telemetry=self.last_telemetry,
            monitor=self.last_monitor,
            **gkw,
        )


def single_ssd_write_iops(occupancy: float, *, params: SSDParams = SSDParams(),
                          measure_ops: int = 60000, w_total: int = 128,
                          seed: int = 0) -> float:
    """Paper Table 1 cell: steady 4 KB random-write IOPS at an occupancy."""
    sim = ArraySim(1, params, occupancy,
                   Workload(read_frac=0.0, w_total=w_total, qd_per_ssd=w_total), seed)
    return sim.run(measure_ops).iops


def fresh_ssd_write_iops(params: SSDParams = SSDParams(), measure_ops: int = 30000) -> float:
    """Paper Table 1 'maximal' column: no GC (tiny occupancy never trips it)."""
    sim = ArraySim(1, params, 0.05, Workload(w_total=128, qd_per_ssd=128))
    return sim.run(measure_ops).iops

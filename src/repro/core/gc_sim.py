"""Discrete-event simulator of an SSD array with unsynchronized garbage collection.

This reproduces the *evaluation substrate* of the paper (§4.1): OCZ Vertex-4
class SSDs behind HBAs, raw 4 KB random I/O. Three coupled models:

1. ``FTL`` — page-mapped flash translation layer with greedy (min-valid) GC
   and free-block watermark hysteresis. Hysteresis is what makes GC *bursty*:
   an SSD reclaims several blocks back-to-back, pausing user I/O for
   milliseconds. Across an array these pauses are unsynchronized — the
   phenomenon the paper attacks.
2. ``SSDSim`` — fluid single-server service model: ``channels`` internal
   parallel units give per-op service time ``t_op / channels``; GC copies and
   erases occupy the same server (strict priority during a GC episode).
3. ``ArraySim`` — host with a bounded total outstanding window W and bounded
   per-SSD queues. Tokens regenerate only on completion, so a GC-paused SSD
   accumulates an ever larger share of W while fast SSDs starve — exactly the
   Table-2/Figure-2 dynamic.

Calibration: ``t_prog`` is set so a fresh single SSD sustains 60 928 IOPS of
4 KB random writes (paper Table 1 "maximal"); occupancy-dependent degradation
then *emerges* from the FTL (write amplification), it is not programmed in.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

# Paper Table 1 calibration target.
FRESH_WRITE_IOPS = 60928.0
READ_IOPS = 90000.0


@dataclass(frozen=True)
class SSDParams:
    capacity_pages: int = 65536          # scaled-down drive (4 KB pages)
    pages_per_block: int = 64
    op_frac: float = 0.55                # effective spare factor. Calibrated to
                                         # paper Table 1; large because the
                                         # Vertex 4 reorganizes below half fill
                                         # ("performance mode") and so behaves
                                         # as if heavily over-provisioned.
    channels: int = 32                   # internal parallelism
    t_prog: float = 32.0 / FRESH_WRITE_IOPS
    t_read: float = 32.0 / READ_IOPS
    t_erase: float = 2.0e-3
    t_coalesce: float = 10.0e-6          # DRAM write-buffer hit: a write whose
                                         # LBA already has a pending write is
                                         # absorbed at bus speed, no program
    gc_low_blocks: int = 12              # enter GC episode at <= low free blocks
    gc_high_blocks: int = 16             # leave episode at >= high free blocks
                                         # (width => ~5 ms pauses; calibrated so
                                         # the Table-2 array decline matches)
    device_slots: int = 32               # NCQ-style concurrent admissions
    gc_window: int = 0                   # 0 = pure greedy; else greedy over the
                                         # oldest-sealed window (wear-leveling-
                                         # constrained controllers; raises WA)
    gc_sample: int = 2                   # 0 = full scan; else min-valid over a
                                         # random sample of sealed blocks
                                         # (d-choices, as firmware actually does).
                                         # Calibrated (with op_frac) to Table 1.

    @property
    def phys_pages(self) -> int:
        blocks = int(round(self.capacity_pages * (1 + self.op_frac))) // self.pages_per_block
        return blocks * self.pages_per_block

    @property
    def n_blocks(self) -> int:
        return self.phys_pages // self.pages_per_block


class FTL:
    """Page-mapped FTL with greedy GC. All state in numpy for speed."""

    def __init__(self, params: SSDParams, rng: np.random.Generator):
        self.p = params
        self.rng = rng
        n_blocks = params.n_blocks
        self.page_lba = np.full(params.phys_pages, -1, dtype=np.int64)
        self.lba_loc = np.full(params.capacity_pages, -1, dtype=np.int64)
        self.valid_count = np.zeros(n_blocks, dtype=np.int32)
        self.sealed = np.zeros(n_blocks, dtype=bool)
        self.seal_fifo: list[int] = []   # blocks in seal order (gc_window policy)
        self.free_blocks: list[int] = list(range(n_blocks - 1, 0, -1))
        self.active = 0
        self.active_off = 0
        self.writes = 0          # user page programs
        self.gc_copies = 0       # GC page programs
        self.erases = 0

    # -- helpers -------------------------------------------------------------
    @property
    def n_free_blocks(self) -> int:
        return len(self.free_blocks)

    def _advance_active(self) -> None:
        if self.active_off == self.p.pages_per_block:
            self.sealed[self.active] = True
            self.seal_fifo.append(self.active)
            self.active = self.free_blocks.pop()
            self.active_off = 0

    def _program(self, lba: int) -> None:
        """Append ``lba`` to the active block (mapping update only)."""
        self._advance_active()
        phys = self.active * self.p.pages_per_block + self.active_off
        self.active_off += 1
        old = self.lba_loc[lba]
        if old >= 0:
            self.page_lba[old] = -1
            self.valid_count[old // self.p.pages_per_block] -= 1
        self.page_lba[phys] = lba
        self.lba_loc[lba] = phys
        self.valid_count[self.active] += 1

    # -- public ----------------------------------------------------------------
    def prefill(self, occupancy: float, churn: bool = True) -> None:
        """Sequentially write ``occupancy`` of the LBA space (paper's pre-
        conditioning), then churn random overwrites (with GC interleaved,
        charging no simulated time) until the drive reaches GC steady state."""
        live = int(self.p.capacity_pages * occupancy)
        for lba in range(live):
            self._program(lba)
        self.live_lbas = live
        if churn:
            spare = self.p.phys_pages - live
            lbas = self.rng.integers(0, live, size=3 * spare)
            for lba in lbas:
                self._program(int(lba))
                while self.need_gc() and not self.gc_satisfied():
                    self.gc_reclaim_one()
            # reset counters so WA statistics reflect steady state only
            self.writes = 0
            self.gc_copies = 0
            self.erases = 0

    def user_write(self, lba: int) -> None:
        self._program(lba)
        self.writes += 1

    def need_gc(self) -> bool:
        return self.n_free_blocks <= self.p.gc_low_blocks

    def gc_satisfied(self) -> bool:
        return self.n_free_blocks >= self.p.gc_high_blocks

    def gc_reclaim_one(self) -> int:
        """Reclaim the min-valid sealed block (within the seal-order window if
        ``gc_window`` > 0). Returns the number of page copies performed
        (caller charges time)."""
        if self.p.gc_window > 0:
            window = self.seal_fifo[: self.p.gc_window]
            victim = min(window, key=lambda b: self.valid_count[b])
        elif self.p.gc_sample > 0 and len(self.seal_fifo) > self.p.gc_sample:
            idx = self.rng.integers(0, len(self.seal_fifo), size=self.p.gc_sample)
            victim = min((self.seal_fifo[i] for i in idx),
                         key=lambda b: self.valid_count[b])
        else:
            cand = np.where(self.sealed)[0]
            victim = int(cand[np.argmin(self.valid_count[cand])])
        self.seal_fifo.remove(victim)
        moved = 0
        base = victim * self.p.pages_per_block
        for off in range(self.p.pages_per_block):
            lba = self.page_lba[base + off]
            if lba >= 0:
                self._program(int(lba))
                moved += 1
        self.sealed[victim] = False
        self.valid_count[victim] = 0
        self.free_blocks.insert(0, victim)  # tail: not reused before active moves on
        self.gc_copies += moved
        self.erases += 1
        return moved


def _mix64(x: int) -> int:
    """splitmix64 finalizer — cheap stateless permutation-ish hash."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class ZipfSampler:
    """Bounded Zipf(s) over ranks 1..N: exact CDF for the head, continuous
    generalized-harmonic inverse for the tail. O(1) memory in N."""

    HEAD = 4096

    def __init__(self, n: int, s: float, rng: np.random.Generator):
        self.n, self.s, self.rng = n, s, rng
        head = min(self.HEAD, n)
        ranks = np.arange(1, head + 1, dtype=np.float64)
        head_w = ranks ** (-s)
        self._head_cum = np.cumsum(head_w)
        h_head = float(self._head_cum[-1])
        if n > head:
            # integral_{head+.5}^{n+.5} x^-s dx
            if abs(s - 1.0) < 1e-9:
                tail = np.log((n + 0.5) / (head + 0.5))
            else:
                tail = ((n + 0.5) ** (1 - s) - (head + 0.5) ** (1 - s)) / (1 - s)
        else:
            tail = 0.0
        self._h_head, self._h_total = h_head, h_head + tail
        self._p_head = h_head / self._h_total

    def sample(self) -> int:
        u = self.rng.random()
        if u < self._p_head or self.n <= self.HEAD:
            t = u * self._h_total
            return int(np.searchsorted(self._head_cum, t) + 1)
        rem = u * self._h_total - self._h_head
        head, s = min(self.HEAD, self.n), self.s
        if abs(s - 1.0) < 1e-9:
            k = (head + 0.5) * np.exp(rem)
        else:
            k = ((head + 0.5) ** (1 - s) + rem * (1 - s)) ** (1.0 / (1 - s))
        return int(min(max(k, head + 1), self.n))


@dataclass(frozen=True)
class Workload:
    read_frac: float = 0.0
    dist: str = "uniform"            # "uniform" | "zipf"
    zipf_s: float = 0.99
    w_total: int = 128               # total outstanding window (app tokens)
    qd_per_ssd: int = 128            # host-side per-SSD queue bound
    n_streams: int = 1               # submission sequencers: a stream BLOCKS
                                     # (head-of-line) when its next request
                                     # targets a full device queue, as an AIO
                                     # submit loop does. SAFS's long in-memory
                                     # queues exist to break exactly this.
    virtual_scale: int = 512         # Zipf ranks live in a virtual LBA space
                                     # this many times larger than the scaled
                                     # drives (≈ real 128 GB drives), then hash
                                     # onto physical LBAs. Keeps the Zipf head
                                     # below one SSD's fair share, as at real
                                     # scale, instead of a scale-artifact
                                     # hotspot.


@dataclass
class ArrayResults:
    iops: float
    per_ssd_iops: np.ndarray
    read_iops: float
    write_iops: float
    util: np.ndarray                 # busy fraction per SSD during measurement
    sim_time: float
    gc_pause_frac: np.ndarray        # fraction of time in GC episodes
    mean_latency: float


_ARRIVE, _SSD_DONE = 0, 1


class SSDServer:
    """Fluid single-server SSD with GC episodes (wraps an FTL)."""

    def __init__(self, params: SSDParams, occupancy: float, rng: np.random.Generator):
        self.p = params
        self.ftl = FTL(params, rng)
        self.ftl.prefill(occupancy)
        self.busy = False
        self.in_gc = False
        self.queue: list = []        # admitted (tok, stream, lba, is_read, coal)
        self.host_queue: list = []   # waiting for device slots
        self.pending_writes: dict[int, int] = {}  # lba -> pending write count
        self.gc_time = 0.0
        self.busy_time = 0.0
        self.served_reads = 0
        self.served_writes = 0

    def service_time(self, is_read: bool) -> float:
        t = self.p.t_read if is_read else self.p.t_prog
        return t / self.p.channels

    def gc_episode_time(self) -> float:
        """Reclaim blocks until the high watermark; return total busy time."""
        t = 0.0
        while not self.ftl.gc_satisfied():
            copies = self.ftl.gc_reclaim_one()
            t += copies * (self.p.t_read + self.p.t_prog) / self.p.channels
            t += self.p.t_erase / self.p.channels
        return t


class ArraySim:
    """Host + n SSDs. Global LBAs stripe across SSDs page-granularly."""

    def __init__(self, n_ssds: int, ssd: SSDParams = SSDParams(),
                 occupancy: float = 0.6, workload: Workload = Workload(),
                 seed: int = 0):
        self.n = n_ssds
        self.p = ssd
        self.wl = workload
        self.rng = np.random.default_rng(seed)
        self.ssds = [SSDServer(ssd, occupancy, self.rng) for _ in range(n_ssds)]
        self.live_per_ssd = self.ssds[0].ftl.live_lbas
        self.n_live = self.live_per_ssd * n_ssds
        if workload.dist == "zipf":
            self._zipf = ZipfSampler(self.n_live * workload.virtual_scale,
                                     workload.zipf_s, self.rng)

    # -- workload ------------------------------------------------------------
    def _sample_lba(self) -> int:
        if self.wl.dist == "zipf":
            v = self._zipf.sample()
            return _mix64(v) % self.n_live
        return int(self.rng.integers(self.n_live))

    def _sample_op(self) -> tuple[int, int, bool]:
        lba = self._sample_lba()
        is_read = bool(self.rng.random() < self.wl.read_frac)
        return lba % self.n, lba // self.n, is_read

    # -- main loop -------------------------------------------------------------
    def run(self, measure_ops: int, warmup_ops: int | None = None) -> ArrayResults:
        n, wl = self.n, self.wl
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        total_ops = warmup_ops + measure_ops
        now = 0.0
        seq = 0
        heap: list[tuple[float, int, int, int]] = []  # (time, seq, kind, ssd)
        completions = 0
        t_measure_start = None
        measured = np.zeros(n, dtype=np.int64)
        measured_reads = 0
        measured_writes = 0
        lat_sum, lat_n = 0.0, 0
        issue_time: dict[int, float] = {}
        token_id = 0

        # Submitter streams: each has a window of w_total/n_streams tokens and
        # a single submission sequence. A full target queue parks the whole
        # stream (AIO io_submit head-of-line behaviour).
        n_streams = max(1, wl.n_streams)
        window = max(1, wl.w_total // n_streams)
        outstanding = [0] * n_streams
        parked: list[tuple[int, int, bool] | None] = [None] * n_streams
        waiters: list[list[int]] = [[] for _ in range(n)]  # streams parked per SSD

        def push(t, kind, ssd):
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, ssd))
            seq += 1

        def try_start(ssd_i: int):
            """Admit host-queue -> device and start service / GC episodes."""
            s = self.ssds[ssd_i]
            if s.busy:
                return
            # GC has strict priority once the watermark trips.
            if s.ftl.need_gc():
                dt = s.gc_episode_time()
                s.busy = True
                s.in_gc = True
                s.gc_time += dt
                s.busy_time += dt
                push(now + dt, _SSD_DONE, ssd_i)
                return
            while len(s.queue) < self.p.device_slots and s.host_queue:
                s.queue.append(s.host_queue.pop(0))
            if s.queue:
                _, _, _, is_read, coal = s.queue[0]
                dt = self.p.t_coalesce if coal else s.service_time(is_read)
                s.busy = True
                s.busy_time += dt
                push(now + dt, _SSD_DONE, ssd_i)

        def room(ssd_i: int) -> bool:
            s = self.ssds[ssd_i]
            return len(s.host_queue) + len(s.queue) < wl.qd_per_ssd

        def enqueue(stream: int, ssd_i: int, lba: int, is_read: bool):
            nonlocal token_id
            tok = token_id
            token_id += 1
            issue_time[tok] = now
            s = self.ssds[ssd_i]
            coal = False
            if not is_read:
                coal = s.pending_writes.get(lba, 0) > 0
                s.pending_writes[lba] = s.pending_writes.get(lba, 0) + 1
            s.host_queue.append((tok, stream, lba, is_read, coal))
            outstanding[stream] += 1
            try_start(ssd_i)

        def stream_fill(stream: int):
            """Submit until the stream's window is full or it parks."""
            if parked[stream] is not None:
                return
            while outstanding[stream] < window:
                ssd_i, lba, is_read = self._sample_op()
                if room(ssd_i):
                    enqueue(stream, ssd_i, lba, is_read)
                else:
                    parked[stream] = (ssd_i, lba, is_read)
                    waiters[ssd_i].append(stream)
                    return

        def unpark(ssd_i: int):
            while waiters[ssd_i] and room(ssd_i):
                stream = waiters[ssd_i].pop(0)
                tgt, lba, is_read = parked[stream]
                parked[stream] = None
                enqueue(stream, tgt, lba, is_read)
                stream_fill(stream)

        for si in range(n_streams):
            stream_fill(si)

        while completions < total_ops and heap:
            now, _, kind, ssd_i = heapq.heappop(heap)
            s = self.ssds[ssd_i]
            s.busy = False
            if s.in_gc:
                s.in_gc = False
                try_start(ssd_i)
                unpark(ssd_i)
                continue
            tok, stream, lba, is_read, coal = s.queue.pop(0)
            outstanding[stream] -= 1
            if is_read:
                s.served_reads += 1
            else:
                s.served_writes += 1
                c = s.pending_writes[lba] - 1
                if c:
                    s.pending_writes[lba] = c
                else:
                    del s.pending_writes[lba]
                if not coal:
                    s.ftl.user_write(lba)
            completions += 1
            if t_measure_start is None and completions >= warmup_ops:
                t_measure_start = now
                measured[:] = 0
                measured_reads = measured_writes = 0
                lat_sum, lat_n = 0.0, 0
                for ss in self.ssds:
                    ss.busy_time = 0.0
                    ss.gc_time = 0.0
            if t_measure_start is not None:
                measured[ssd_i] += 1
                if is_read:
                    measured_reads += 1
                else:
                    measured_writes += 1
                lat_sum += now - issue_time.pop(tok, now)
                lat_n += 1
            else:
                issue_time.pop(tok, None)
            try_start(ssd_i)
            unpark(ssd_i)
            stream_fill(stream)

        span = max(now - (t_measure_start or 0.0), 1e-9)
        return ArrayResults(
            iops=float(measured.sum() / span),
            per_ssd_iops=measured / span,
            read_iops=measured_reads / span,
            write_iops=measured_writes / span,
            util=np.array([s.busy_time / span for s in self.ssds]),
            sim_time=span,
            gc_pause_frac=np.array([s.gc_time / span for s in self.ssds]),
            mean_latency=lat_sum / max(lat_n, 1),
        )


def single_ssd_write_iops(occupancy: float, *, params: SSDParams = SSDParams(),
                          measure_ops: int = 60000, w_total: int = 128,
                          seed: int = 0) -> float:
    """Paper Table 1 cell: steady 4 KB random-write IOPS at an occupancy."""
    sim = ArraySim(1, params, occupancy,
                   Workload(read_frac=0.0, w_total=w_total, qd_per_ssd=w_total), seed)
    return sim.run(measure_ops).iops


def fresh_ssd_write_iops(params: SSDParams = SSDParams(), measure_ops: int = 30000) -> float:
    """Paper Table 1 'maximal' column: no GC (tiny occupancy never trips it)."""
    sim = ArraySim(1, params, 0.05, Workload(w_total=128, qd_per_ssd=128))
    return sim.run(measure_ops).iops

"""Array-level GC coordination: WHEN each member collects, not just what.

The paper's problem is that per-SSD garbage collection is *unsynchronized*:
at any instant some members stall in a GC episode while others idle, and
striping magnifies the imbalance (a stripe write completes at the MAX of its
members, so one mid-GC straggler stalls every stripe touching it). The FTL
deciding on its own — ``need_gc()`` trips, the device drains and runs the
whole episode — is exactly that failure mode. This module lifts the decision
to the array:

* :class:`GcPolicy` — frozen, picklable policy specs (safe for prefill-cache
  keys and for shipping to sharded worker processes):

  - :class:`ReactiveGc` — today's per-device threshold trigger, byte-identical
    to ``gc=None`` (goldens pinned in ``tests/test_gc_coord.py``).
  - :class:`StaggeredGc` — an array-wide GC lease: at most ``max_concurrent``
    members collect at once; a member whose watermark trips while the leases
    are taken *keeps serving* and waits its turn (the wait is recorded as
    ``stagger_wait``). A device at the free-block hard floor
    (``floor_blocks``) overrides the lease so forward progress is never
    blocked.
  - :class:`IdleGc` — preemptive early GC: whenever a device goes idle with
    free blocks at or below ``watermark``, it reclaims ``step_blocks`` blocks
    off the critical path (block-granular, so a new burst waits at most one
    step). The reactive threshold stays armed as a backstop under sustained
    load.

  Every policy may also enable **GC-aware host steering** (``steer=True``):
  window admission caps members currently in — or waiting to enter — GC at
  ``steer_qd`` outstanding requests (instead of the workload's
  ``qd_per_ssd``), so the host's long-queue budget is spent on members that
  can actually serve; and the RAID-5 planner redirects reads targeting a
  GC-busy member to reconstruction from its row siblings
  (``ArrayResults.steered_reads``).

* :class:`GcCoordinator` — the per-run runtime object. ``DeviceModel`` asks
  it to ``gate`` every GC decision (grant / defer / force) and reports
  episode start/end; the coordinator keeps the lease queue, the concurrency
  time-integral behind ``gc_overlap_frac``, the ``stagger_wait`` recorder,
  and the per-policy counters surfaced in the ``ArrayResults`` coordination
  block.

Determinism: the coordinator consumes no RNG and its lease queue is FIFO, so
seed-for-seed byte identity holds under every policy; with ``ReactiveGc`` the
grant is unconditional and the event sequence is identical to ``gc=None``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from .engine import LatencyRecorder

__all__ = [
    "GcCoordinator", "GcPolicy", "IdleGc", "ReactiveGc", "StaggeredGc",
    "gc_policy_from_name",
]


# ---------------------------------------------------------------------------
# Policy specs (frozen, hashable, picklable)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GcPolicy:
    """Base spec: steering knobs shared by every policy.

    ``steer=True`` enables GC-aware host steering: admission to a GC-busy
    member (in GC, draining for GC, or lease-waiting) is capped at
    ``steer_qd`` outstanding requests, and the RAID-5 planner serves reads of
    GC-busy members by reconstruction from row siblings. ``floor_blocks`` is
    the free-block hard floor below which a device starts GC regardless of
    any lease — forward progress is never blocked by coordination."""

    steer: bool = False
    steer_qd: int = 4
    floor_blocks: int = 4

    @property
    def name(self) -> str:
        return type(self).__name__.replace("Gc", "").lower()

    def make_coordinator(self, n: int, loop, unit: int = 1) -> "GcCoordinator":
        """``unit`` is the layout's stripe-group size (``shard_unit``) — the
        lease-domain size for ``StaggeredGc(scope="group")``."""
        return GcCoordinator(self, n, loop, unit)


@dataclass(frozen=True)
class ReactiveGc(GcPolicy):
    """Per-device threshold trigger — the historical behavior, made an
    explicit policy. Byte-identical to ``gc=None`` (the coordinator only
    accounts; it never defers or preempts)."""


@dataclass(frozen=True)
class StaggeredGc(GcPolicy):
    """GC lease: at most ``max_concurrent`` members of a lease *domain* in
    (or draining toward) a GC episode at once. Deferred members keep
    serving; leases hand over FIFO on episode end; the ``floor_blocks``
    hard floor overrides the lease.

    ``scope`` picks the domain: ``"array"`` is one global lease pool;
    ``"group"`` is one pool per stripe group (``layout.shard_unit``) — the
    stripe-aware variant. GC is per-device work, so an array-wide lease
    caps AGGREGATE reclaim bandwidth at ``max_concurrent`` devices' worth
    and throttles a write-saturated array; what a striped layout actually
    needs is that no two members of the *same group* pause together (a
    stripe completes at the max of its members). Group scope delivers
    exactly that while keeping one lease per group of reclaim parallelism.
    On JBOD (group size 1) ``"group"`` degenerates to uncoordinated — use
    ``"array"`` there.

    ``early_blocks`` makes the rotation *proactive* (Nagel et al.'s lever —
    schedule collection ahead of need): a member whose free blocks are
    within ``early_blocks`` of the reactive watermark takes a FREE lease
    immediately instead of waiting for ``need_gc()`` to trip. Episodes then
    start shallow (short pauses) and spread around the rotation, instead of
    every member deferring to the floor and paying one long episode; 0
    disables the early trigger (pure deferral staggering)."""

    max_concurrent: int = 1
    scope: str = "array"
    early_blocks: int = 2


@dataclass(frozen=True)
class IdleGc(GcPolicy):
    """Preemptive early GC during idle windows: when a device goes idle
    while its free blocks are at or below ``watermark``, it reclaims
    ``step_blocks`` blocks. Steps repeat while the device stays idle and
    below the watermark, so collection migrates off the critical path; the
    reactive threshold remains armed as a backstop.

    ``qd_idle`` is the maximum occupancy (admitted + in-service) still
    considered idle. NOTE: the current engine preempts ALL channels for a
    GC episode and only probes a fully drained device, so occupancy at the
    probe point is always 0 and values > 0 behave exactly like 0; the knob
    is honored by the coordinator's check and becomes meaningful only with
    a partial-preemption service model."""

    watermark: int = 24
    qd_idle: int = 0
    step_blocks: int = 1


def gc_policy_from_name(name: str, **kw) -> GcPolicy:
    """Benchmark/CLI convenience: ``"reactive" | "staggered" | "idle"``."""
    table = {"reactive": ReactiveGc, "staggered": StaggeredGc, "idle": IdleGc}
    try:
        return table[name](**kw)
    except KeyError:
        raise ValueError(f"unknown GC policy {name!r} "
                         f"(expected one of {sorted(table)})") from None


# ---------------------------------------------------------------------------
# Runtime coordinator
# ---------------------------------------------------------------------------

class GcCoordinator:
    """Per-run array GC state machine + accounting.

    The protocol with ``engine.DeviceModel`` (one device per member):

    * ``gate(dev)`` — called whenever the device could start new service.
      Returns True when the device must *stop* admitting service because it
      is draining toward (or already granted) a GC episode; False when it
      should keep serving (no GC needed, or the lease deferred it).
    * ``idle_probe(dev)`` — called when a kick leaves the device with no
      admitted work; may start a bounded idle-GC step (:class:`IdleGc`).
    * ``on_gc_start(dev, dt, idle)`` / ``on_gc_end(dev)`` — episode
      bookkeeping; ``on_gc_end`` hands the freed lease to the next FIFO
      waiter and kicks it, and notifies the host (``on_release``) so
      steering-parked streams re-place.

    ``begin_measure(now)`` resets the window counters/integrals exactly like
    the simulators' other measurement snapshots; ``finalize(now)`` closes the
    open concurrency interval before results are read.
    """

    __slots__ = ("policy", "n", "loop", "devices", "gc_busy", "dom",
                 "active", "waiting", "is_waiting", "wait_since", "wait_rec",
                 "starts", "forced", "idle_starts", "gc_time", "gc_time_idle",
                 "_count", "_last_t", "_t_overlap", "on_release",
                 "_max_conc", "_idle", "_floor", "_early", "steer",
                 "steer_qd", "quarantined", "lease_skipped", "_defers")

    def __init__(self, policy: GcPolicy, n: int, loop, unit: int = 1) -> None:
        self.policy = policy
        self.n = n
        self.loop = loop
        self.devices: list = [None] * n
        # member is in GC, draining toward it, or lease-waiting ("about to
        # enter") — the steering predicate, indexed by device id
        self.gc_busy = [False] * n
        if isinstance(policy, StaggeredGc):
            self._max_conc = policy.max_concurrent
            if policy.scope == "group":
                unit = max(1, unit)
                self.dom = [i // unit for i in range(n)]
            elif policy.scope == "array":
                self.dom = [0] * n
            else:
                raise ValueError(f"StaggeredGc.scope must be 'array' or "
                                 f"'group', got {policy.scope!r}")
        else:
            self._max_conc = n + 1   # never defers
            self.dom = [0] * n
        n_dom = (self.dom[-1] + 1) if n else 1
        self.active = [0] * n_dom    # granted leases per domain
        self.waiting: list[deque[int]] = [deque() for _ in range(n_dom)]
        self.is_waiting = [False] * n
        self.wait_since = [0.0] * n
        self.wait_rec = LatencyRecorder()
        self.starts = 0              # episodes started (incl. idle steps)
        self.forced = 0              # hard-floor lease overrides
        self.idle_starts = 0         # idle-GC steps started
        self.gc_time = 0.0           # sum of episode durations
        self.gc_time_idle = 0.0      # ... started by the idle probe
        self._count = 0              # members currently in a GC episode
        self._last_t = 0.0
        self._t_overlap = 0.0        # time integral with >= 2 members in GC
        self.on_release = None       # host hook: ssd_i -> None (unpark)
        self._idle = policy if isinstance(policy, IdleGc) else None
        self._floor = policy.floor_blocks
        self._early = policy.early_blocks \
            if isinstance(policy, StaggeredGc) else 0
        self.steer = policy.steer
        self.steer_qd = policy.steer_qd
        # fault-aware coordination: the simulators point this at the
        # injector's live quarantine list when the detector is on. Only
        # deferring policies (StaggeredGc) consult it, so ReactiveGc stays
        # behavior-identical to gc=None under faults.
        self.quarantined: "list[bool] | None" = None
        self.lease_skipped = 0
        self._defers = self._max_conc <= n

    def attach(self, dev, dev_id: int) -> None:
        self.devices[dev_id] = dev

    # -- measurement window --------------------------------------------------
    def begin_measure(self, now: float) -> None:
        self._advance(now)
        self._t_overlap = 0.0
        self.wait_rec.reset()
        self.starts = 0
        self.forced = 0
        self.idle_starts = 0
        self.gc_time = 0.0
        self.gc_time_idle = 0.0
        self.lease_skipped = 0

    def finalize(self, now: float) -> None:
        self._advance(now)

    def _advance(self, now: float) -> None:
        if self._count >= 2:
            self._t_overlap += now - self._last_t
        self._last_t = now

    # -- device protocol -----------------------------------------------------
    def gate(self, dev) -> bool:
        """True -> the device must not start new service (GC granted or
        draining); False -> keep serving (healthy, or lease-deferred)."""
        if dev.gc_granted:
            if dev.in_service == 0:
                dev._start_gc()
            return True
        ftl = dev.server.ftl
        if not ftl.need_gc():
            early = self._early
            if early and len(ftl.free_blocks) <= ftl._gc_low + early \
                    and not ftl.gc_satisfied():
                d = self.dom[dev.dev_id]
                if self.active[d] < self._max_conc:
                    if self._skip_quarantined(dev.dev_id):
                        # proactive GC on a quarantined member would stack a
                        # pause on a device the host already capped; it is
                        # above the low watermark, so just don't volunteer it
                        return False
                    # proactive rotation: take the free lease now, while the
                    # episode is still shallow (short pause), instead of
                    # deferring everyone to the watermark at once
                    self._grant(dev, dev.dev_id, d)
                    return True
            return False
        i = dev.dev_id
        d = self.dom[i]
        if self.active[d] < self._max_conc:
            if len(dev.server.ftl.free_blocks) > self._floor \
                    and self._skip_quarantined(i):
                # defer the lease while the member is quarantined (the hard
                # floor below still forces forward progress)
                return False
            self._grant(dev, i, d)
            return True
        if len(ftl.free_blocks) <= self._floor:
            # hard floor: forward progress beats the lease
            self.forced += 1
            self._grant(dev, i, d)
            return True
        if not self.is_waiting[i]:
            self.is_waiting[i] = True
            self.wait_since[i] = self.loop.now
            self.waiting[d].append(i)
            self.gc_busy[i] = True   # "about to enter" for steering
        return False

    def _skip_quarantined(self, i: int) -> bool:
        """True when a free lease should be withheld from member ``i``
        because the fail-slow detector has it quarantined (deferring
        policies only); counts the skip."""
        q = self.quarantined
        if q is not None and self._defers and q[i]:
            self.lease_skipped += 1
            return True
        return False

    def _grant(self, dev, i: int, d: int) -> None:
        self.active[d] += 1
        dev.gc_granted = True
        self.gc_busy[i] = True
        if self.is_waiting[i]:
            self.is_waiting[i] = False
            self.wait_rec.record(self.loop.now - self.wait_since[i])
        if dev.in_service == 0:
            dev._start_gc()

    def idle_probe(self, dev) -> None:
        """Start a bounded idle-GC step if the policy wants one. Called when
        a kick leaves the device with nothing admitted."""
        pol = self._idle
        if pol is None or dev.gc_granted:
            return
        if dev.in_service or len(dev.admitted) > pol.qd_idle:
            return
        ftl = dev.server.ftl
        if len(ftl.free_blocks) > pol.watermark or not len(ftl.seal_fifo):
            return
        dev._start_idle_gc(pol.step_blocks)

    def on_gc_start(self, dev, dt: float, idle: bool = False) -> None:
        now = self.loop.now
        self._advance(now)
        self._count += 1
        self.starts += 1
        self.gc_time += dt
        if idle:
            self.idle_starts += 1
            self.gc_time_idle += dt
            self.active[self.dom[dev.dev_id]] += 1   # idle steps hold a lease
            self.gc_busy[dev.dev_id] = True

    def on_gc_end(self, dev) -> None:
        now = self.loop.now
        self._advance(now)
        self._count -= 1
        i = dev.dev_id
        d = self.dom[i]
        self.active[d] -= 1
        dev.gc_granted = False
        self.gc_busy[i] = False
        # hand the freed lease to the domain's next waiter that still needs it
        waiting = self.waiting[d]
        while waiting and self.active[d] < self._max_conc:
            j = waiting.popleft()
            if not self.is_waiting[j]:
                continue             # force-started meanwhile
            w = self.devices[j]
            if w.server.ftl.need_gc():
                if len(w.server.ftl.free_blocks) > self._floor \
                        and self._skip_quarantined(j):
                    # quarantined waiter: release it to keep serving under
                    # its admission cap; its next gate() re-evaluates
                    self.is_waiting[j] = False
                    self.gc_busy[j] = False
                    if self.on_release is not None:
                        self.on_release(j)
                    continue
                self._grant(w, j, d)
                if w.in_service != 0:
                    # draining: stop further admissions via its next gate
                    w.kick()
            else:
                self.is_waiting[j] = False
                self.gc_busy[j] = False
                if self.on_release is not None:
                    self.on_release(j)
        if self.steer and self.on_release is not None:
            self.on_release(i)

    # -- results -------------------------------------------------------------
    def window_stats(self, span: float) -> dict:
        w = self.wait_rec.summary()
        return {
            "gc_policy": self.policy.name,
            "gc_overlap_frac": self._t_overlap / span if span > 0 else 0.0,
            "stagger_wait_mean": w.mean,
            "stagger_wait_p99": w.p99,
            "gc_starts": self.starts,
            "gc_forced": self.forced,
            "idle_gc_frac": (self.gc_time_idle / self.gc_time
                             if self.gc_time > 0 else 0.0),
            "gc_lease_skipped": self.lease_skipped,
        }

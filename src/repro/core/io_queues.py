"""Dual-priority per-device I/O queues (paper §3.2) + a threaded host executor.

Two layers:

* ``next_action`` / ``DualQueue`` — the pure scheduling policy (short
  high-priority queue, long low-priority queue, reserved device slots for
  high-priority requests, stale-discard at dequeue). Shared by the
  discrete-event simulator and the real executor so both are testable against
  the same invariants.
* ``IOExecutor`` — a real thread-per-device runtime used by the async
  checkpointer: device == a storage target (one shard file / one host NIC
  stream). This is the SAFS "dedicated I/O thread per SSD" design.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .policies import DEVICE_SLOTS, RESERVED_SLOTS

HIGH = 0
LOW = 1


def next_action(
    high_len: int,
    low_len: int,
    inflight_high: int,
    inflight_low: int,
    max_inflight: int = DEVICE_SLOTS,
    reserved: int = RESERVED_SLOTS,
) -> Optional[int]:
    """Which queue may issue next, or None.

    Rules (paper §3.2):
      * high-priority requests issue whenever any device slot is free;
      * low-priority requests issue only when (a) no high-priority request is
        waiting and (b) at least ``reserved`` slots would remain free for
        future high-priority arrivals.
    """
    inflight = inflight_high + inflight_low
    if high_len > 0 and inflight < max_inflight:
        return HIGH
    if low_len > 0 and high_len == 0 and inflight < max_inflight - reserved:
        return LOW
    return None


@dataclass
class IOStats:
    issued_high: int = 0
    issued_low: int = 0
    discarded_stale: int = 0
    completed: int = 0


@dataclass
class IORequest:
    payload: Any
    priority: int = LOW
    # evaluated when the request reaches the queue head (§3.3.2)
    is_stale: Optional[Callable[[Any], bool]] = None
    on_complete: Optional[Callable[[Any], None]] = None
    on_discard: Optional[Callable[[Any], None]] = None
    # tenant class for QoS-aware queues (core/qos.py TenantDualQueue);
    # ignored by the plain DualQueue discipline
    tenant: int = 0


@dataclass
class DualQueue:
    """Non-thread-safe dual queue + slot accounting (simulator building block)."""

    max_inflight: int = DEVICE_SLOTS
    reserved: int = RESERVED_SLOTS
    high_capacity: int = 4 * DEVICE_SLOTS
    low_capacity: int = 1 << 20
    high: deque = field(default_factory=deque)
    low: deque = field(default_factory=deque)
    inflight_high: int = 0
    inflight_low: int = 0
    stats: IOStats = field(default_factory=IOStats)
    # executor asks the flusher for more work after discarding stale requests
    refill: Optional[Callable[[], None]] = None

    def submit(self, req: IORequest) -> bool:
        q, cap = (self.high, self.high_capacity) if req.priority == HIGH else (self.low, self.low_capacity)
        if len(q) >= cap:
            return False
        q.append(req)
        return True

    def pop_next(self) -> Optional[IORequest]:
        """Apply the policy; drops stale low-priority heads (counts them)."""
        discarded = False
        while True:
            act = next_action(len(self.high), len(self.low), self.inflight_high,
                              self.inflight_low, self.max_inflight, self.reserved)
            if act is None:
                break
            if act == HIGH:
                req = self.high.popleft()
                self.inflight_high += 1
                self.stats.issued_high += 1
            else:
                req = self.low.popleft()
                if req.is_stale is not None and req.is_stale(req.payload):
                    self.stats.discarded_stale += 1
                    discarded = True
                    if req.on_discard:
                        req.on_discard(req.payload)
                    continue
                self.inflight_low += 1
                self.stats.issued_low += 1
            if discarded and self.refill:
                self.refill()
            return req
        if discarded and self.refill:
            # "Once discarding stale flush requests, an I/O thread will notify
            # the page cache and ask for more flush requests."
            self.refill()
        return None

    def complete(self, req: IORequest) -> None:
        if req.priority == HIGH:
            self.inflight_high -= 1
        else:
            self.inflight_low -= 1
        self.stats.completed += 1
        if req.on_complete:
            req.on_complete(req.payload)


class IOExecutor:
    """Thread-per-device executor (SAFS's dedicated I/O threads).

    ``device_fn(device_id, payload)`` performs the actual I/O synchronously in
    the worker; parallelism within a device comes from ``max_inflight`` worker
    threads per device. High-priority work preempts queued low-priority work
    (not in-flight work, matching SAFS).
    """

    def __init__(self, n_devices: int, device_fn: Callable[[int, Any], None],
                 max_inflight: int = 8, reserved: int = 2):
        self._device_fn = device_fn
        self._queues = [DualQueue(max_inflight=max_inflight, reserved=reserved)
                        for _ in range(n_devices)]
        self._locks = [threading.Lock() for _ in range(n_devices)]
        self._cvs = [threading.Condition(lock) for lock in self._locks]
        self._refill_fns: dict[int, Callable[[], None]] = {}
        self._refill_pending = [False] * n_devices
        # completion callbacks run outside the device lock; drain() must not
        # report quiescence while one is still pending
        self._cb_outstanding = [0] * n_devices
        self._stop = False
        self._threads = []
        for dev in range(n_devices):
            for slot in range(max_inflight):
                t = threading.Thread(target=self._worker, args=(dev,),
                                     name=f"io-dev{dev}-slot{slot}", daemon=True)
                t.start()
                self._threads.append(t)

    def submit(self, device: int, req: IORequest) -> bool:
        with self._cvs[device]:
            ok = self._queues[device].submit(req)
            if ok:
                self._cvs[device].notify()
            return ok

    def set_refill(self, device: int, fn: Callable[[], None]) -> None:
        """Register the refill callback (the flusher's "give me more work").

        ``DualQueue.pop_next`` fires ``refill`` inline, but workers call
        ``pop_next`` while holding the device condition lock — a callback
        that re-enters ``submit`` on the same device would self-deadlock on
        the non-reentrant lock. So the queue only *records* the request here
        and the worker invokes ``fn`` after releasing the lock."""
        self._refill_fns[device] = fn
        q = self._queues[device]

        def mark(dev: int = device) -> None:   # runs under the device lock
            self._refill_pending[dev] = True
        q.refill = mark

    def stats(self, device: int) -> IOStats:
        return self._queues[device].stats

    def drain(self, timeout: float = 60.0) -> bool:
        import time
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with_work = False
            for dev, q in enumerate(self._queues):
                with self._locks[dev]:
                    if (q.high or q.low or q.inflight_high or q.inflight_low
                            or self._cb_outstanding[dev]):
                        with_work = True
                        break
            if not with_work:
                return True
            time.sleep(0.005)
        return False

    def shutdown(self) -> None:
        self._stop = True
        for cv in self._cvs:
            with cv:
                cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def _run_pending_refill(self, dev: int, run_refill: bool) -> None:
        if run_refill:
            fn = self._refill_fns.get(dev)
            if fn is not None:
                fn()

    def _worker(self, dev: int) -> None:
        q, cv = self._queues[dev], self._cvs[dev]
        while True:
            run_refill = False
            with cv:
                req = q.pop_next()
                if self._refill_pending[dev]:
                    self._refill_pending[dev] = False
                    run_refill = True
                if req is None and not run_refill and not self._stop:
                    cv.wait(timeout=0.2)
            # deferred refill: outside the lock, so it may submit() freely
            self._run_pending_refill(dev, run_refill)
            if req is None:
                if self._stop:
                    return
                continue
            # completion callback also runs outside the lock (it may submit
            # follow-on work to this same device); the outstanding count is
            # raised in the same critical section that retires the request so
            # drain() never sees a gap between the two
            cb, req.on_complete = req.on_complete, None
            try:
                self._device_fn(dev, req.payload)
            finally:
                with cv:
                    q.complete(req)
                    if cb is not None:
                        self._cb_outstanding[dev] += 1
                    cv.notify_all()
            if cb is not None:
                try:
                    cb(req.payload)
                finally:
                    with cv:
                        self._cb_outstanding[dev] -= 1
                        cv.notify_all()

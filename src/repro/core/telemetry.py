"""Deterministic run telemetry: time-series probes, per-op spans, and a
latency-budget decomposition.

The collector is *passive by construction*: series samples piggyback on the
existing event stream (the :class:`~repro.core.engine.EventLoop` checks
tick-boundary crossings when it pops an event — no probe events are ever
scheduled), probes are read-only closures over live simulator state, and no
telemetry path consumes RNG.  Consequently a run with telemetry attached
produces **byte-identical** simulation results (latency samples, counters,
event count, RNG stream) to the same run with ``telemetry=None`` — an
invariant pinned by ``tests/test_telemetry.py`` on all four run loops.

Three capabilities:

* **Time-series probes** — per-device utilization (cumulative busy-time),
  queue backlog, free blocks, and GC-active flag, plus SAFS cache
  hit/lookup/dirty-fraction scalars, sampled at fixed sim-time ticks
  ``k * series_dt``.  An event at time ``t`` is dispatched *after* every
  boundary ``<= t`` is sampled, so a tick reflects the state produced by all
  events strictly before it (plus same-time events already dispatched).
* **Per-op spans** — one record per completed operation with additive
  wait-cause components (see ``ARRAY_COMPONENTS`` / ``SAFS_COMPONENTS``),
  exportable as Chrome trace-event JSON viewable in Perfetto
  (:meth:`TelemetryResult.export_trace`).
* **Latency budget** — the measured-window mean (and p99-tail) latency
  decomposed into those components, per tenant and per device; the
  components of every span sum to that span's measured latency, so the
  budget means sum to the run's mean latency within float tolerance.

Span component vocabulary (each list partitions a span's latency):

``ARRAY_COMPONENTS`` (ArraySim fast / layout / QoS loops)
    ``park``     time between plan issue and first child admission (stream
                 parked on a full device queue; structurally 0 in the fast
                 loop, whose latency clock starts at admission),
    ``queue``    host-queue + NCQ wait not otherwise attributed,
    ``gc``       on-device GC episode time overlapping the op's residency
                 (exact for single-device ops: episodes never overlap an
                 individual request's service slice, so the cumulative
                 GC-time delta over the op's window is pure wait),
    ``service``  nominal media service time for the op kind,
    ``sync``     stripe-member fan-in skew (first-to-last child completion
                 of the final phase; 0 for single-child plans).

``SAFS_COMPONENTS`` (SAFSSim cache path)
    ``cpu``        CPU-stage queueing + service,
    ``writeback``  demand writeback of a dirty victim (miss path),
    ``fill``       device fill read (miss path),
    ``gc``         GC overlap during writeback/fill residency,
    ``other``      remainder (hit path: 0).

Merging (sharded runners): per-device series concatenate along the device
axis on the shared tick grid (trimmed to the shortest shard), spans merge
sorted by ``(time, seq, shard)``, device ids are re-based to global ids, and
budget sums add exactly — so ``parallel=False`` and ``parallel=True`` runs
of the same shard decomposition produce bit-identical merged telemetry.
Tenant/stream ids in merged spans remain shard-local (each shard owns its
streams); per-shard percentile tails are dropped from merged budgets (only
exact-mergeable sums survive).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

# Array span components partition an op's latency exactly.  ``retry`` is
# media-error recovery time (first failed read completion -> op end) and
# ``hedge`` the post-hedge-issue wait on parity-reconstruction legs — both
# 0.0 on fault-free runs, so budgets stay additive with ``faults`` attached.
ARRAY_COMPONENTS = ("park", "queue", "gc", "service", "sync", "retry",
                    "hedge")
# SAFS spans need no extra vocabulary: ``other`` is the remainder, so
# media-retry backoff is absorbed there and additivity holds structurally.
SAFS_COMPONENTS = ("cpu", "writeback", "fill", "gc", "other")

_KIND_NAMES = {0: "read", 1: "write", 2: "trim", 3: "rebuild"}


@dataclass(frozen=True)
class TelemetrySpec:
    """Frozen, picklable telemetry configuration (ships to shard workers).

    ``series_dt``
        tick spacing in sim-seconds for the time-series probes.
    ``spans`` / ``span_limit``
        per-op span tracing on/off; at most ``span_limit`` span records are
        retained (overflow is counted in ``spans_dropped``, and the latency
        budget keeps accumulating regardless).
    ``probe_*``
        per-subsystem series toggles.
    """

    series_dt: float = 1e-3
    spans: bool = False
    span_limit: int = 65536
    probe_util: bool = True
    probe_queues: bool = True
    probe_free_blocks: bool = True
    probe_gc: bool = True
    probe_cache: bool = True

    def __post_init__(self):
        if self.series_dt <= 0.0:
            raise ValueError("series_dt must be > 0")
        if self.span_limit < 0:
            raise ValueError("span_limit must be >= 0")


class _Span(object):
    """In-flight op span (closed spans become plain tuples)."""

    __slots__ = ("kind", "tenant", "dev", "nd", "devs", "t_arr", "t_admit",
                 "gc0", "retry_t", "hedge_t")


@dataclass
class TelemetryResult:
    """Picklable end-of-run telemetry snapshot.

    ``series[name]`` is ``(T, n_devices)`` for per-device probes and
    ``(T,)`` for per-sim scalars (``(T, n_shards)`` after a sharded merge);
    ``ticks`` is the shared ``(T,)`` tick-time axis.  ``final[name]`` is one
    extra sample taken at loop end (off the tick grid).  Span records are
    ``(t_start, seq, tenant, dev, n_devs, kind, dur, components, measured)``
    with ``components`` aligned to ``components`` below.
    """

    spec: TelemetrySpec
    components: tuple
    n_devices: int
    ticks: np.ndarray
    series: dict
    final: dict
    window_t0: float
    t_end: float
    gc_episodes: list
    spans: list
    spans_dropped: int
    budget: Optional[dict] = None
    merged: bool = False

    def util_series(self, channels: int) -> np.ndarray:
        """Per-tick utilization ``(T, n)`` from the cumulative busy-time
        series: the busy-time delta per tick over the tick width, clamped to
        ``>= 0`` (the measurement-window reset zeroes busy-time mid-run,
        producing one negative delta at the warmup boundary)."""
        busy = np.asarray(self.series["busy_time"], dtype=np.float64)
        if busy.ndim == 1:
            busy = busy[:, None]
        d = np.diff(busy, axis=0, prepend=busy[:1])
        np.maximum(d, 0.0, out=d)
        return d / (float(self.spec.series_dt) * channels)

    def gc_active_any(self) -> np.ndarray:
        """Bool ``(T,)``: any device in GC at each tick."""
        g = np.asarray(self.series["gc_active"])
        return g.max(axis=1) > 0.0 if g.ndim == 2 else g > 0.0

    def gc_active_all(self) -> np.ndarray:
        """Bool ``(T,)``: *every* device in GC at each tick."""
        g = np.asarray(self.series["gc_active"])
        return g.min(axis=1) > 0.0 if g.ndim == 2 else g > 0.0

    def export_trace(self, path, time_scale: float = 1.0,
                     monitor=None) -> int:
        """Write Chrome trace-event JSON (open at https://ui.perfetto.dev —
        "Open trace file" — or chrome://tracing).  Spans become ``"X"``
        duration events on one track per device, GC episodes a second
        process, series a third (``"C"`` counter events); pass the run's
        :class:`~.monitor.MonitorResult` as ``monitor`` to add its alerts
        as ``"i"`` instant events on a fourth process.  ``ts``/``dur`` are
        microseconds of sim time (scaled by ``time_scale``).  Returns the
        number of trace events written."""
        us = 1e6 * time_scale
        ev = [
            {"name": "process_name", "ph": "M", "pid": 0,
             "args": {"name": "io spans"}},
            {"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "gc episodes"}},
            {"name": "process_name", "ph": "M", "pid": 2,
             "args": {"name": "series"}},
        ]
        if monitor is not None:
            ev.append({"name": "process_name", "ph": "M", "pid": 3,
                       "args": {"name": "alerts"}})
            for a in monitor.alerts:
                t, seq, rule, dev, tenant, value, thresh, cause = a
                ev.append({
                    "name": rule, "cat": "alert", "ph": "i", "s": "g",
                    "ts": t * us, "pid": 3,
                    "tid": dev if dev >= 0 else 9999,
                    "args": {"seq": seq, "device": dev, "tenant": tenant,
                             "value": value, "threshold": thresh,
                             "cause": cause}})
        comp = self.components
        for rec in sorted(self.spans, key=lambda r: (r[0], r[1])):
            t_arr, seq, tenant, dev, nd, kind, dur, comps, measured = rec
            args = dict(zip(comp, comps))
            args["tenant"] = tenant
            args["n_devs"] = nd
            args["measured"] = bool(measured)
            ev.append({"name": _KIND_NAMES.get(kind, str(kind)),
                       "cat": "op", "ph": "X", "ts": t_arr * us,
                       "dur": dur * us, "pid": 0,
                       "tid": dev if dev >= 0 else 9999, "args": args})
        for dev, t0, t1, idle in self.gc_episodes:
            ev.append({"name": "idle-gc" if idle else "gc", "cat": "gc",
                       "ph": "X", "ts": t0 * us, "dur": (t1 - t0) * us,
                       "pid": 1, "tid": dev})
        ticks = self.ticks
        for name, arr in self.series.items():
            a = np.asarray(arr)
            if a.ndim == 1:
                a = a[:, None]
            for i, t in enumerate(ticks):
                ev.append({"name": name, "ph": "C", "pid": 2, "ts": t * us,
                           "args": {str(d): float(a[i, d])
                                    for d in range(a.shape[1])}})
        payload = {"traceEvents": ev, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(payload, f, default=float)
        return len(ev)


class Telemetry:
    """Per-run collector.  Attach to an :class:`EventLoop` (sets
    ``loop.telemetry``); the loop calls :meth:`on_tick` at tick-boundary
    crossings.  Simulators register read-only probes and feed span
    lifecycle notes; :meth:`finalize` freezes everything into a
    :class:`TelemetryResult`."""

    def __init__(self, spec: TelemetrySpec, n_devices: int,
                 components: tuple = ARRAY_COMPONENTS):
        self.spec = spec
        self.n_devices = n_devices
        self.components = components
        self.spans_on = bool(spec.spans)
        self.dt = float(spec.series_dt)
        self._k = 0
        self.next_tick = 0.0
        self._probes: list[tuple[str, Callable, list]] = []
        self._ticks: list[float] = []
        # GC cumulative-time function C_d(t) (closed episodes + open one)
        self._gc_closed = [0.0] * n_devices
        self._gc_open = [-1.0] * n_devices
        self.gc_episodes: list[tuple] = []
        # closed spans + budget accumulators (budget: measured ops only)
        self._seq = 0
        self.spans: list[tuple] = []
        self.spans_dropped = 0
        self._b_lat: list[float] = []
        self._b_comps: list[tuple] = []
        self._b_tenant: list[int] = []
        self._b_dev: list[int] = []
        self._res: Optional[TelemetryResult] = None
        # optional chained HealthMonitor (core/monitor.py): shares this
        # telemetry's tick grid instead of installing its own loop hook
        self.monitor = None

    # -- wiring -----------------------------------------------------------
    def attach(self, loop) -> "Telemetry":
        """Hook into ``loop`` and align the tick grid: the first tick is the
        smallest ``k * series_dt >= loop.now`` (keeps the grid anchored at
        sim time 0 even when the loop is resumed mid-stream)."""
        now = loop.now
        dt = self.dt
        k = int(now / dt)
        while k * dt < now:
            k += 1
        self._k = k
        self.next_tick = k * dt
        loop.telemetry = self
        return self

    def add_series(self, name: str, fn: Callable[[], object]) -> None:
        """Register a read-only probe; ``fn()`` is called at every tick and
        must return a per-device sequence (or a scalar for per-sim
        series)."""
        self._probes.append((name, fn, []))

    def has_series(self, name: str) -> bool:
        return any(n == name for n, _, _ in self._probes)

    def register_array_probes(self, ssds, devices, host_queues) -> None:
        """Standard ArraySim probe set (per-device)."""
        sp = self.spec
        if sp.probe_util:
            self.add_series("busy_time",
                            lambda: [s.busy_time for s in ssds])
        if sp.probe_queues:
            self.add_series(
                "backlog",
                lambda: [len(q) + len(d.admitted) + d.in_service
                         for q, d in zip(host_queues, devices)])
        if sp.probe_free_blocks:
            self.add_series(
                "free_blocks",
                lambda: [float(len(s.ftl.free_blocks)) for s in ssds])
        if sp.probe_gc:
            self.add_series(
                "gc_active",
                lambda: [1.0 if d.in_gc else 0.0 for d in devices])

    def register_safs_probes(self, devices, cache) -> None:
        """Standard SAFSSim probe set: per-device series over the wrapped
        DeviceModels plus per-sim cache scalars."""
        sp = self.spec
        if sp.probe_util:
            self.add_series(
                "busy_time", lambda: [d.server.busy_time for d in devices])
        if sp.probe_queues:
            self.add_series(
                "backlog", lambda: [_qlen(d.queue) + d.model.occupancy
                                    for d in devices])
        if sp.probe_free_blocks:
            self.add_series(
                "free_blocks",
                lambda: [float(len(d.server.ftl.free_blocks))
                         for d in devices])
        if sp.probe_gc:
            self.add_series(
                "gc_active",
                lambda: [1.0 if d.model.in_gc else 0.0 for d in devices])
        if sp.probe_cache:
            self.add_series("cache_hits", lambda: float(cache.hit_count))
            self.add_series("cache_lookups", lambda: float(cache.lookups))
            cap = float(max(cache.num_sets * cache.set_size, 1))
            self.add_series(
                "cache_dirty_frac",
                lambda: float(sum(cache._dirty_n)) / cap)

    # -- tick sampling (called by the EventLoop hot path) -----------------
    def on_tick(self, now: float) -> float:
        """Sample every boundary ``k * series_dt <= now`` and return the
        next boundary.  Boundaries are computed multiplicatively from the
        integer tick index — no accumulated float drift."""
        dt = self.dt
        k = self._k
        t = k * dt
        ticks = self._ticks
        probes = self._probes
        mon = self.monitor
        while t <= now:
            ticks.append(t)
            for _, fn, store in probes:
                store.append(fn())
            if mon is not None:
                mon.on_tick(t)
            k += 1
            t = k * dt
        self._k = k
        self.next_tick = t
        return t

    # -- GC episode notes (DeviceModel cold paths) ------------------------
    def note_gc_start(self, dev: int, now: float, dur: float,
                      idle: bool = False) -> None:
        self._gc_open[dev] = now
        self.gc_episodes.append((dev, now, now + dur, idle))

    def note_gc_end(self, dev: int, now: float) -> None:
        t0 = self._gc_open[dev]
        if t0 >= 0.0:
            self._gc_closed[dev] += now - t0
            self._gc_open[dev] = -1.0

    def gc_cum(self, dev: int, now: float) -> float:
        """Cumulative on-device GC time through ``now`` (C_d(t)); the delta
        over an op's residency window is its GC-wait exposure."""
        t0 = self._gc_open[dev]
        c = self._gc_closed[dev]
        return c + (now - t0) if t0 >= 0.0 else c

    # -- spans ------------------------------------------------------------
    def new_span(self, kind: int, tenant: int, dev: int,
                 now: float) -> _Span:
        """Single-device op admitted now (fast loop / SAFS)."""
        sp = _Span()
        sp.kind = kind
        sp.tenant = tenant
        sp.dev = dev
        sp.nd = 1
        sp.devs = None
        sp.t_arr = now
        sp.t_admit = now
        sp.gc0 = self.gc_cum(dev, now) if dev >= 0 else 0.0
        sp.retry_t = -1.0
        sp.hedge_t = -1.0
        return sp

    def new_plan_span(self, kind: int, tenant: int, devs: tuple,
                      now: float) -> _Span:
        """Striped plan issued now; admission is noted at the first child
        enqueue (:meth:`note_admit`)."""
        sp = _Span()
        sp.kind = kind
        sp.tenant = tenant
        sp.devs = devs
        sp.dev = devs[0] if len(devs) == 1 else -1
        sp.nd = len(devs)
        sp.t_arr = now
        sp.t_admit = -1.0
        sp.gc0 = 0.0
        sp.retry_t = -1.0
        sp.hedge_t = -1.0
        return sp

    def note_admit(self, sp: _Span, now: float) -> None:
        sp.t_admit = now
        gc_cum = self.gc_cum
        sp.gc0 = sum(gc_cum(d, now) for d in sp.devs)

    def note_retry(self, sp: _Span, now: float) -> None:
        """First media-error retry decision for this op: everything from
        here to op end that isn't gc/service is recovery time."""
        if sp.retry_t < 0.0:
            sp.retry_t = now

    def note_hedge_issue(self, sp: _Span, now: float) -> None:
        """Hedged reconstruction leg issued for this op's plan."""
        if sp.hedge_t < 0.0:
            sp.hedge_t = now

    def close_fast_span(self, sp: _Span, now: float, svc: float,
                        measured: bool) -> None:
        """Fast loop: latency clock == admission; park is structurally 0."""
        devt = now - sp.t_arr
        if svc > devt:
            svc = devt
        gc = self.gc_cum(sp.dev, now) - sp.gc0
        lim = devt - svc
        gc = 0.0 if gc < 0.0 else (lim if gc > lim else gc)
        rem = devt - svc - gc
        if sp.retry_t >= 0.0:
            retry = now - sp.retry_t
            retry = 0.0 if retry < 0.0 else (rem if retry > rem else retry)
        else:
            retry = 0.0
        self.record_span(sp.t_arr, sp.tenant, sp.dev, 1, sp.kind, now,
                         (0.0, rem - retry, gc, svc, 0.0, retry, 0.0),
                         measured)

    def close_plan_span(self, sp: _Span, now: float, sync: float,
                        svc: float, measured: bool) -> None:
        t_admit = sp.t_admit if sp.t_admit >= 0.0 else now
        park = t_admit - sp.t_arr
        devt = (now - t_admit) - sync
        if devt < 0.0:
            devt = 0.0
        if svc > devt:
            svc = devt
        gc_cum = self.gc_cum
        gc = sum(gc_cum(d, now) for d in sp.devs) - sp.gc0
        lim = devt - svc
        gc = 0.0 if gc < 0.0 else (lim if gc > lim else gc)
        rem = devt - svc - gc
        if sp.retry_t >= 0.0:
            retry = now - sp.retry_t
            retry = 0.0 if retry < 0.0 else (rem if retry > rem else retry)
        else:
            retry = 0.0
        if sp.hedge_t >= 0.0:
            lim = rem - retry
            hedge = now - sp.hedge_t
            hedge = 0.0 if hedge < 0.0 else (lim if hedge > lim else hedge)
        else:
            hedge = 0.0
        self.record_span(sp.t_arr, sp.tenant, sp.dev, sp.nd, sp.kind, now,
                         (park, rem - retry - hedge, gc, svc, sync, retry,
                          hedge), measured)

    def record_span(self, t_arr: float, tenant: int, dev: int, nd: int,
                    kind: int, t_end: float, comps: tuple,
                    measured: bool) -> None:
        """Append a closed span; ``comps`` aligns with ``self.components``
        and sums (with the clamps above) to ``t_end - t_arr``.  Measured
        (in-window) spans also feed the latency budget — past
        ``span_limit`` the span record is dropped but the budget still
        accumulates."""
        if self._res is not None:     # op straddled a finalized run
            return
        seq = self._seq
        self._seq = seq + 1
        if len(self.spans) < self.spec.span_limit:
            self.spans.append((t_arr, seq, tenant, dev, nd, kind,
                               t_end - t_arr, comps, measured))
        else:
            self.spans_dropped += 1
        if measured:
            self._b_lat.append(t_end - t_arr)
            self._b_comps.append(comps)
            self._b_tenant.append(tenant)
            self._b_dev.append(dev)

    # -- finalize ---------------------------------------------------------
    def finalize(self, now: float, window_t0: float = 0.0) -> TelemetryResult:
        """Freeze collected data into a :class:`TelemetryResult` (detaching
        from the loop is the caller's job where the loop outlives the
        run)."""
        series = {}
        final = {}
        for name, fn, store in self._probes:
            series[name] = np.asarray(store, dtype=np.float64)
            final[name] = np.asarray(fn(), dtype=np.float64)
        self._res = TelemetryResult(
            spec=self.spec, components=self.components,
            n_devices=self.n_devices,
            ticks=np.asarray(self._ticks, dtype=np.float64),
            series=series, final=final, window_t0=window_t0, t_end=now,
            gc_episodes=self.gc_episodes, spans=self.spans,
            spans_dropped=self.spans_dropped,
            budget=self._build_budget() if self.spans_on else None)
        return self._res

    def result(self) -> Optional[TelemetryResult]:
        return self._res

    def util_final(self, span: float, channels: int) -> np.ndarray:
        """Measured-window utilization from the busy-time probe's final
        sample — bit-identical to the legacy per-SSD computation
        (``busy_time`` is reset to 0 at the window start, so the final
        cumulative value *is* the window total)."""
        assert self._res is not None
        busy = self._res.final["busy_time"]
        return busy / (span * channels)

    def _group(self, idx: np.ndarray, lat: np.ndarray,
               comps: np.ndarray) -> dict:
        out = {"n": int(idx.size), "lat_sum": float(lat[idx].sum())}
        out["sums"] = {c: float(comps[idx, j].sum())
                       for j, c in enumerate(self.components)}
        n = max(out["n"], 1)
        out["mean_latency"] = out["lat_sum"] / n
        out["mean"] = {c: s / n for c, s in out["sums"].items()}
        return out

    def _build_budget(self) -> dict:
        lat = np.asarray(self._b_lat, dtype=np.float64)
        comps = np.asarray(self._b_comps, dtype=np.float64)
        if lat.size == 0:
            comps = comps.reshape(0, len(self.components))
        every = np.arange(lat.size)
        budget = self._group(every, lat, comps)
        budget["components"] = list(self.components)
        budget["merged"] = False
        if lat.size:
            p50 = float(np.percentile(lat, 50))
            p99 = float(np.percentile(lat, 99))
            budget["p50_latency"] = p50
            budget["p99_latency"] = p99
            budget["tail_p99"] = self._group(np.nonzero(lat >= p99)[0],
                                             lat, comps)
        else:
            budget["p50_latency"] = budget["p99_latency"] = 0.0
            budget["tail_p99"] = None
        tenants = np.asarray(self._b_tenant)
        devs = np.asarray(self._b_dev)
        budget["by_tenant"] = {
            int(t): self._group(np.nonzero(tenants == t)[0], lat, comps)
            for t in np.unique(tenants)} if lat.size else {}
        budget["by_device"] = {
            int(d): self._group(np.nonzero(devs == d)[0], lat, comps)
            for d in np.unique(devs)} if lat.size else {}
        return budget


def _qlen(q) -> int:
    """Backlog of a DualQueue-like object (plain ``high``/``low`` deques or
    the QoS per-tenant ``high`` dict-of-deques)."""
    h = q.high
    n = sum(len(d) for d in h.values()) if isinstance(h, dict) else len(h)
    return n + len(q.low)


def _merge_budgets(parts: list, components: tuple,
                   bases: list) -> Optional[dict]:
    if all(p.budget is None for p in parts):
        return None
    comp = list(components)
    out = {"components": comp, "merged": True, "n": 0, "lat_sum": 0.0,
           "sums": {c: 0.0 for c in comp},
           "p50_latency": None, "p99_latency": None, "tail_p99": None}
    by_tenant: dict = {}
    by_dev: dict = {}
    for p, base in zip(parts, bases):
        b = p.budget
        if b is None:
            continue
        out["n"] += b["n"]
        out["lat_sum"] += b["lat_sum"]
        for c in comp:
            out["sums"][c] += b["sums"][c]
        # device keys re-base to global ids (shard order = device order);
        # tenant/stream ids stay shard-local (each shard owns its streams)
        for dst, src, off in ((by_tenant, b.get("by_tenant") or {}, 0),
                              (by_dev, b.get("by_device") or {}, base)):
            for k, g in src.items():
                gk = k + off if k >= 0 else k
                d = dst.setdefault(gk, {"n": 0, "lat_sum": 0.0,
                                        "sums": {c: 0.0 for c in comp}})
                d["n"] += g["n"]
                d["lat_sum"] += g["lat_sum"]
                for c in comp:
                    d["sums"][c] += g["sums"][c]
    for g in [out] + list(by_tenant.values()) + list(by_dev.values()):
        n = max(g["n"], 1)
        g["mean_latency"] = g["lat_sum"] / n
        g["mean"] = {c: s / n for c, s in g["sums"].items()}
    out["by_tenant"] = by_tenant
    out["by_device"] = by_dev
    return out


def merge_telemetry(parts: list) -> Optional[TelemetryResult]:
    """Merge per-shard :class:`TelemetryResult` objects (shard order =
    device order).  Deterministic: series concatenate along the device axis
    on the common tick-grid prefix, per-sim scalar series become
    ``(T, n_shards)`` columns, spans/GC episodes re-base device ids by each
    shard's device offset and sort by ``(time, seq, shard)``.  Returns
    ``None`` if no shard carried telemetry."""
    if any(p is None for p in parts) or not parts:
        return None
    T = min(p.ticks.size for p in parts)
    first = parts[0]
    series = {}
    final = {}
    for name in first.series:
        cols = []
        fins = []
        for p in parts:
            a = np.asarray(p.series[name])[:T]
            cols.append(a if a.ndim == 2 else a[:, None])
            f = np.atleast_1d(np.asarray(p.final[name]))
            fins.append(f)
        series[name] = np.concatenate(cols, axis=1)
        final[name] = np.concatenate(fins)
    bases = np.cumsum([0] + [p.n_devices for p in parts[:-1]])
    spans = []
    episodes = []
    for si, (p, base) in enumerate(zip(parts, map(int, bases))):
        for rec in p.spans:
            t_arr, seq, tenant, dev, nd, kind, dur, comps, m = rec
            spans.append((t_arr, seq, si,
                          (t_arr, seq, tenant,
                           dev + base if dev >= 0 else -1, nd, kind, dur,
                           comps, m)))
        for dev, t0, t1, idle in p.gc_episodes:
            episodes.append((dev + base, t0, t1, idle))
    spans.sort(key=lambda r: (r[0], r[1], r[2]))
    episodes.sort(key=lambda r: (r[1], r[0]))
    return TelemetryResult(
        spec=first.spec, components=first.components,
        n_devices=int(sum(p.n_devices for p in parts)),
        ticks=first.ticks[:T], series=series, final=final,
        window_t0=min(p.window_t0 for p in parts),
        t_end=max(p.t_end for p in parts),
        gc_episodes=episodes, spans=[r[3] for r in spans],
        spans_dropped=int(sum(p.spans_dropped for p in parts)),
        budget=_merge_budgets(parts, first.components,
                              [int(b) for b in bases]), merged=True)

"""Set-associative cache (SA-cache, paper §3.1) as a functional JAX state machine.

The paper's SA-cache groups pages into many small page sets to eliminate global
locking. On TPU the analogous win is *vectorization*: every policy decision
(GClock eviction, flush scoring) is an elementwise/argmin computation over a
``(num_sets, set_size)`` array — one fused kernel instead of a locked list walk.

Key identity used throughout (this is why the paper's flush score works): a
GClock sweep starting at the hand visits slot ``p`` (forward distance ``d``)
with hit count ``h`` and evicts it at sweep-time ``t = h * set_size + d`` — the
paper's ``distance_score``. Hence the sweep victim is simply
``argmin(distance_score)`` over eligible slots, which makes eviction analytic
(O(set_size), branch-free) instead of an unbounded loop: TPU-native GClock.

All ops are pure ``state -> state`` functions over a :class:`CacheState`
pytree, jit/vmap-friendly, and property-tested against ``policies.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MAX_HITS = 15          # saturation cap on GClock reference counts
EMPTY = jnp.int32(-1)  # tag value for an empty slot


class CacheState(NamedTuple):
    """Bookkeeping for a set-associative page cache (no payload storage).

    The payload (KV pages, checkpoint chunks, ...) lives elsewhere (e.g. the
    HBM page pool); this state maps tags -> slots and drives the policies.

    ``epoch`` versions each slot's dirty content: it is bumped on every
    ``mark_dirty`` and on every ``insert``, and :func:`clean_slot` may clear
    the dirty bit only while the epoch it captured is still current (the
    flush-completion lost-write race). ``None`` (legacy states built without
    the field) disables the check.
    """

    tags: jax.Array    # (num_sets, set_size) int32, EMPTY = free slot
    hits: jax.Array    # (num_sets, set_size) int32 GClock counts
    dirty: jax.Array   # (num_sets, set_size) bool
    clock: jax.Array   # (num_sets,) int32 hand position
    epoch: jax.Array | None = None  # (num_sets, set_size) int32 dirty version

    @property
    def num_sets(self) -> int:
        return self.tags.shape[0]

    @property
    def set_size(self) -> int:
        return self.tags.shape[1]


def make_cache(num_sets: int, set_size: int) -> CacheState:
    return CacheState(
        tags=jnp.full((num_sets, set_size), EMPTY, dtype=jnp.int32),
        hits=jnp.zeros((num_sets, set_size), dtype=jnp.int32),
        dirty=jnp.zeros((num_sets, set_size), dtype=jnp.bool_),
        clock=jnp.zeros((num_sets,), dtype=jnp.int32),
        epoch=jnp.zeros((num_sets, set_size), dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# Scoring (paper §3.3.1) — vectorized over all sets.
# ---------------------------------------------------------------------------

def distance_scores(state: CacheState) -> jax.Array:
    """(num_sets, set_size) distance_score = hits * set_size + distance."""
    s = state.set_size
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    dist = jnp.mod(pos - state.clock[:, None], s)
    return state.hits.astype(jnp.int32) * s + dist


def flush_scores(state: CacheState) -> jax.Array:
    """Rank-based flush scores; invalid slots get -1. Matches policies.flush_scores."""
    s = state.set_size
    valid = state.tags != EMPTY
    d = jnp.where(valid, distance_scores(state), jnp.iinfo(jnp.int32).max)
    # rank of each slot in ascending (d, slot) order, computed by pairwise
    # comparison — set_size is tiny (paper: 12) so O(s^2) beats a sort.
    di = d[..., :, None]
    dj = d[..., None, :]
    idx = jnp.arange(s, dtype=jnp.int32)
    lt = (dj < di) | ((dj == di) & (idx[None, None, :] < idx[None, :, None]))
    rank = lt.sum(axis=-1).astype(jnp.int32)
    fs = s - 1 - rank
    return jnp.where(valid, fs, -1)


def dirty_counts(state: CacheState) -> jax.Array:
    return (state.dirty & (state.tags != EMPTY)).sum(axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Per-set primitive ops (compose with vmap for batches).
# ---------------------------------------------------------------------------

def _touch_row(hits_row: jax.Array, slot: jax.Array) -> jax.Array:
    return hits_row.at[slot].set(jnp.minimum(hits_row[slot] + 1, MAX_HITS))


def lookup(state: CacheState, set_idx: jax.Array, tag: jax.Array):
    """Probe one set for ``tag``; bump GClock hits on a hit.

    Returns (hit: bool[], slot: int32[], new_state).
    """
    row = state.tags[set_idx]
    matches = row == tag
    hit = matches.any()
    slot = jnp.argmax(matches).astype(jnp.int32)
    new_hits_row = jnp.where(hit, _touch_row(state.hits[set_idx], slot), state.hits[set_idx])
    return hit, slot, state._replace(hits=state.hits.at[set_idx].set(new_hits_row))


def _evict_analytic(hits_row, clock, valid, dirty, clean_first: bool):
    """Analytic GClock sweep over one set. Returns (victim_slot, new_hits, new_clock).

    Mirrors policies.gclock_evict exactly, including empty-slot fast path and
    decrement bookkeeping of the simulated sweep.
    """
    s = hits_row.shape[0]
    pos = jnp.arange(s, dtype=jnp.int32)
    dist = jnp.mod(pos - clock, s)
    score = hits_row.astype(jnp.int32) * s + dist

    empty = ~valid
    has_empty = empty.any()
    first_empty = jnp.argmax(empty).astype(jnp.int32)

    clean = valid & ~dirty
    use_clean = jnp.logical_and(clean_first, clean.any())
    eligible = jnp.where(use_clean, clean, valid)

    big = jnp.iinfo(jnp.int32).max
    masked = jnp.where(eligible, score, big)
    victim = jnp.argmin(masked).astype(jnp.int32)
    t_evict = masked[victim]

    # Sweep decrements for eligible non-victim slots: slots with dist < dist_v
    # are visited h_v + 1 times before eviction, others h_v times.
    h_v = hits_row[victim]
    visits = jnp.where(dist < dist[victim], h_v + 1, h_v)
    dec_hits = jnp.maximum(hits_row - jnp.where(eligible, visits, 0), 0)
    dec_hits = dec_hits.at[victim].set(0)
    new_clock = jnp.mod(pos[victim] + 1, s)

    victim = jnp.where(has_empty, first_empty, victim)
    new_hits = jnp.where(has_empty, hits_row, dec_hits)
    new_clock = jnp.where(has_empty, clock, new_clock)
    del t_evict
    return victim, new_hits, new_clock


def insert(state: CacheState, set_idx: jax.Array, tag: jax.Array, dirty: jax.Array,
           clean_first: bool = True):
    """Insert ``tag`` into ``set_idx`` (caller guarantees it is absent).

    Returns (victim_tag, victim_dirty, slot, new_state). victim_tag == EMPTY
    when a free slot was claimed; victim_dirty indicates a required writeback
    (the stall the flusher exists to prevent).
    """
    hits_row = state.hits[set_idx]
    tags_row = state.tags[set_idx]
    dirty_row = state.dirty[set_idx]
    valid = tags_row != EMPTY
    slot, new_hits_row, new_clock = _evict_analytic(
        hits_row, state.clock[set_idx], valid, dirty_row, clean_first)
    victim_tag = tags_row[slot]
    victim_dirty = jnp.logical_and(victim_tag != EMPTY, dirty_row[slot])
    new_state = state._replace(
        tags=state.tags.at[set_idx, slot].set(tag),
        hits=state.hits.at[set_idx].set(new_hits_row.at[slot].set(0)),
        dirty=state.dirty.at[set_idx, slot].set(dirty),
        clock=state.clock.at[set_idx].set(new_clock),
    )
    if state.epoch is not None:
        # new occupant: in-flight flushes for the old content are dead, even
        # if the same tag is later re-inserted into this slot
        new_state = new_state._replace(
            epoch=state.epoch.at[set_idx, slot].add(1))
    return victim_tag, victim_dirty, slot, new_state


def mark_dirty(state: CacheState, set_idx, slot, value=True) -> CacheState:
    new_state = state._replace(dirty=state.dirty.at[set_idx, slot].set(value))
    if state.epoch is not None:
        # every write is a new dirty version; a no-op when cleaning
        inc = jnp.asarray(value).astype(jnp.int32)
        new_state = new_state._replace(
            epoch=state.epoch.at[set_idx, slot].add(inc))
    return new_state


def dirty_epoch_of(state: CacheState, set_idx, slot) -> jax.Array:
    """Dirty version to stamp into a FlushRequest at issue time."""
    assert state.epoch is not None, "cache built without epoch tracking"
    return state.epoch[set_idx, slot]


def clean_slot(state: CacheState, set_idx, slot, expect_tag,
               expect_epoch=None) -> CacheState:
    """Flush completion: clear dirty iff the slot still holds ``expect_tag``
    (paper §3.3.2 staleness rule (i): the page may have been evicted) AND —
    when ``expect_epoch`` is given — no write re-dirtied the slot since the
    flush was issued. Without the epoch check a write that lands after the
    flush is issued but before it completes would be silently dropped."""
    ok = state.tags[set_idx, slot] == expect_tag
    if expect_epoch is not None and state.epoch is not None:
        ok = jnp.logical_and(ok, state.epoch[set_idx, slot] == expect_epoch)
    return state._replace(
        dirty=state.dirty.at[set_idx, slot].set(jnp.logical_and(state.dirty[set_idx, slot], ~ok)))


# ---------------------------------------------------------------------------
# Flush candidate selection (paper §3.3) — all sets at once.
# ---------------------------------------------------------------------------

def select_flush_candidates(state: CacheState, trigger: int, per_set: int):
    """For every set with > ``trigger`` dirty pages, pick the ``per_set`` dirty
    pages with the highest flush scores.

    Returns (set_mask (num_sets,), slots (num_sets, per_set) int32 with -1
    padding, scores (num_sets, per_set)). Vectorized: this is the computation
    the ``flush_score`` Pallas kernel accelerates for very large caches.
    """
    fs = flush_scores(state)
    eligible = state.dirty & (state.tags != EMPTY)
    masked = jnp.where(eligible, fs, -1)
    scores, slots = jax.lax.top_k(masked, per_set)
    slots = jnp.where(scores >= 0, slots.astype(jnp.int32), -1)
    set_mask = dirty_counts(state) > trigger
    slots = jnp.where(set_mask[:, None], slots, -1)
    return set_mask, slots, scores

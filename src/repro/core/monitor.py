"""Online health monitoring: a deterministic alert engine over the live run.

The paper's pathology is *silent*: unsynchronized GC degrades individual
members while the host-visible aggregate only droops — nothing says which
device, when, or why. PR 8's telemetry records everything passively for
post-hoc analysis; this module is the online consumer. A per-run
:class:`HealthMonitor` (configured by a frozen, picklable
:class:`MonitorSpec`) watches read-only probes on the telemetry tick grid
plus op completions, evaluates the alert rules below, and emits a
sim-time-stamped structured alert log where every alert carries a
root-cause annotation (active fault episode, overlapping GC activity, or
tenant throttle action).

Alert rules (all edge-latched: one alert per episode at the rising edge,
re-armed when the condition clears):

``gc_storm``
    >= ``gc_storm_frac`` of devices in GC simultaneously for
    ``gc_storm_ticks`` consecutive ticks — the paper's synchronized-GC
    pathology (reactive GC hits it ~1e3 ticks/run where staggered hits 0).
``util_skew``
    one device's busy-time accumulation over the trailing
    ``util_skew_window`` ticks exceeds ``util_skew_ratio`` x the peer
    median — the online face of the fail-slow detector, but window-based,
    so it typically fires at or before quarantine.
``backlog_sat``
    a device's backlog (host queue + admitted + in service) sits at
    >= ``backlog_frac`` of its admission bound for ``backlog_ticks``
    consecutive ticks.
``wa_spike``
    windowed write amplification ``(writes + gc_copies) / writes`` jumps
    above ``wa_ratio`` x the previous window's value.
``hit_collapse``
    windowed SAFS cache hit rate drops below ``hit_drop`` x the previous
    window's rate.
``slo_burn``
    a protected tenant's violation fraction over its last
    ``slo_burn_window`` completions exceeds ``slo_burn_frac`` — it is
    burning its SLO budget even if the controller's p99 check has not
    tripped yet.

Determinism contract (same as telemetry, stricter than most subsystems):
``monitor=None`` is byte-identical everywhere, and monitoring ON is a
passive observer — it piggybacks on the telemetry tick grid (or installs
the identical grid itself when telemetry is off), schedules no events,
draws no randomness, and only *reads* simulator state, so enabling it
never perturbs results. Sharded runs keep per-shard monitors whose alert
streams merge by ``(time, seq)`` with device ids re-based — serial and
parallel shard execution produce bit-identical streams.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Optional

from .metrics import EdgeLatch, SlidingWindow, WindowDelta, fast_median

RULES = ("gc_storm", "util_skew", "backlog_sat", "wa_spike",
         "hit_collapse", "slo_burn")


@dataclass(frozen=True)
class MonitorSpec:
    """Frozen, picklable monitor configuration (ships to shard workers).

    ``tick_dt`` is used only when the run has no telemetry — with
    telemetry attached the monitor locks to its ``series_dt`` grid so both
    consumers sample identical instants. ``include_warmup=False`` (the
    default) suppresses alerts until the measurement window opens; latches
    are re-armed at the boundary, so a pathology persisting across it
    still alerts on the first measured tick.
    """

    tick_dt: float = 1e-3
    include_warmup: bool = False
    rules: tuple = RULES
    # gc_storm
    gc_storm_frac: float = 1.0
    gc_storm_ticks: int = 3
    # util_skew
    util_skew_ratio: float = 2.0
    util_skew_window: int = 50
    util_skew_min_busy: float = 1e-4   # min peer-median window busy (s)
    # backlog_sat
    backlog_frac: float = 1.0
    backlog_ticks: int = 50
    # wa_spike
    wa_ratio: float = 1.5
    wa_window: int = 100
    wa_min_writes: float = 100.0
    # hit_collapse
    hit_window: int = 100
    hit_drop: float = 0.5
    hit_min_lookups: float = 100.0
    # slo_burn
    slo_burn_window: int = 256
    slo_burn_frac: float = 0.5
    slo_burn_min_samples: int = 64

    def __post_init__(self):
        if self.tick_dt <= 0.0:
            raise ValueError("tick_dt must be > 0")
        bad = [r for r in self.rules if r not in RULES]
        if bad:
            raise ValueError(f"unknown monitor rules: {bad} "
                             f"(known: {list(RULES)})")


@dataclass
class MonitorResult:
    """Picklable end-of-run alert log.

    ``alerts`` records are ``(time, seq, rule, device, tenant, value,
    threshold, cause)`` — ``device``/``tenant`` are ``-1`` for array-wide
    or tenant-less alerts, ``cause`` is the root-cause annotation string
    (``fault:...``, ``gc:...``, ``throttle:...``, or ``none``).
    """

    spec: MonitorSpec
    n_devices: int
    alerts: list
    counts: dict = field(default_factory=dict)
    merged: bool = False

    @property
    def n_alerts(self) -> int:
        return len(self.alerts)

    def by_rule(self, rule: str) -> list:
        return [a for a in self.alerts if a[2] == rule]

    def to_jsonl(self, path) -> int:
        """Write the alert log as JSON-lines (one object per alert, in
        stream order); returns the number of lines written."""
        with open(path, "w") as f:
            for t, seq, rule, dev, tenant, value, thresh, cause in self.alerts:
                f.write(json.dumps({
                    "time": t, "seq": seq, "rule": rule, "device": dev,
                    "tenant": tenant, "value": value, "threshold": thresh,
                    "cause": cause}) + "\n")
        return len(self.alerts)


class HealthMonitor:
    """Per-run online rule engine. Implements the same loop-hook protocol
    as :class:`~.telemetry.Telemetry` (``next_tick`` + ``on_tick``), so it
    either chains off an attached telemetry's tick grid or installs itself
    as ``loop.telemetry`` when the run carries no telemetry."""

    def __init__(self, spec: MonitorSpec, n_devices: int):
        self.spec = spec
        self.n = n_devices
        self.dt = float(spec.tick_dt)
        self._k = 0
        self.next_tick = 0.0
        self.armed = bool(spec.include_warmup)
        self.alerts: list[tuple] = []
        self.counts: dict[str, int] = {}
        self._seq = 0
        self._res: Optional[MonitorResult] = None
        # probe closures (read-only; registered by the simulators)
        self._gc_fn: Optional[Callable] = None
        self._busy_fn: Optional[Callable] = None
        self._backlog_fn: Optional[Callable] = None
        self._qd = 0
        self._wa_fn: Optional[Callable] = None       # () -> (writes, copies)
        self._cache_fn: Optional[Callable] = None    # () -> (hits, lookups)
        # root-cause sources
        self._inj = None
        self._slo = None
        # rule state
        r = spec.rules
        self._gc_latch = EdgeLatch(spec.gc_storm_ticks) \
            if "gc_storm" in r else None
        if "util_skew" in r:
            self._skew_d = [WindowDelta(spec.util_skew_window)
                            for _ in range(n_devices)]
            self._skew_latch = [EdgeLatch(1) for _ in range(n_devices)]
        else:
            self._skew_d = None
        if "backlog_sat" in r:
            self._bl_latch = [EdgeLatch(spec.backlog_ticks)
                              for _ in range(n_devices)]
        else:
            self._bl_latch = None
        self._wa_on = "wa_spike" in r
        self._wa_k = 0
        self._wa_prev = (-1.0, 0.0)      # (prev window WA, prev writes)
        self._wa_snap = (0.0, 0.0)
        self._wa_latch = EdgeLatch(1)
        self._hit_on = "hit_collapse" in r
        self._hit_k = 0
        self._hit_prev = -1.0
        self._hit_snap = (0.0, 0.0)
        self._hit_latch = EdgeLatch(1)
        self._slo_on = "slo_burn" in r
        self._burn_win: dict[int, SlidingWindow] = {}
        self._burn_bad: dict[int, int] = {}
        self._burn_p99: dict[int, float] = {}
        self._burn_latch: dict[int, EdgeLatch] = {}

    # -- wiring -----------------------------------------------------------
    def attach(self, loop, telemetry=None) -> "HealthMonitor":
        """Hook into the run. With ``telemetry`` the monitor chains off its
        tick grid (``telemetry.monitor = self``, identical ``dt``);
        without, it installs itself as the loop's tick hook with the same
        grid-anchoring rule as ``Telemetry.attach``."""
        if telemetry is not None:
            self.dt = telemetry.dt
            telemetry.monitor = self
        now = loop.now
        dt = self.dt
        k = int(now / dt)
        while k * dt < now:
            k += 1
        self._k = k
        self.next_tick = k * dt
        if telemetry is None:
            loop.telemetry = self
        return self

    def register_array_sources(self, ssds, devices, host_queues, qd,
                               inj=None, sched=None) -> None:
        """ArraySim sources: read-only closures over live simulator state
        (independent of which telemetry probes are enabled)."""
        self._busy_fn = lambda: [s.busy_time for s in ssds]
        self._backlog_fn = lambda: [
            len(q) + len(d.admitted) + d.in_service
            for q, d in zip(host_queues, devices)]
        self._qd = qd
        self._gc_fn = lambda: [d.in_gc for d in devices]
        self._wa_fn = lambda: (
            float(sum(s.ftl.writes for s in ssds)),
            float(sum(s.ftl.gc_copies for s in ssds)))
        self._inj = inj
        if sched is not None:
            self._slo = sched.slo
            self.register_slo(sched.policy)

    def register_safs_sources(self, devices, cache, qd,
                              inj=None, sched=None) -> None:
        """SAFSSim sources (device list wraps DeviceModels; cache adds the
        hit-collapse scalars)."""
        from .telemetry import _qlen
        self._busy_fn = lambda: [d.server.busy_time for d in devices]
        self._backlog_fn = lambda: [_qlen(d.queue) + d.model.occupancy
                                    for d in devices]
        self._qd = qd
        self._gc_fn = lambda: [d.model.in_gc for d in devices]
        self._wa_fn = lambda: (
            float(sum(d.server.ftl.writes for d in devices)),
            float(sum(d.server.ftl.gc_copies for d in devices)))
        self._cache_fn = lambda: (float(cache.hit_count),
                                  float(cache.lookups))
        self._inj = inj
        if sched is not None:
            self._slo = sched.slo
            self.register_slo(sched.policy)

    def register_slo(self, policy) -> None:
        """Track SLO burn for every protected tenant of ``policy``."""
        if not self._slo_on:
            return
        w = self.spec.slo_burn_window
        for s in policy.tenants:
            if s.protected:
                self._burn_win[s.tenant] = SlidingWindow(w)
                self._burn_bad[s.tenant] = 0
                self._burn_p99[s.tenant] = s.slo_p99
                self._burn_latch[s.tenant] = EdgeLatch(1)

    def begin_measure(self, now: float) -> None:
        """Measurement window opened: arm alerting (unless already armed
        via ``include_warmup``) and re-arm active latches so pathologies
        persisting across the boundary alert on the first measured tick."""
        if self.armed:
            return
        self.armed = True
        if self._gc_latch is not None:
            self._gc_latch.rearm()
        if self._skew_d is not None:
            for la in self._skew_latch:
                la.rearm()
        if self._bl_latch is not None:
            for la in self._bl_latch:
                la.rearm()
        self._wa_latch.rearm()
        self._hit_latch.rearm()
        for la in self._burn_latch.values():
            la.rearm()

    # -- alert emission ---------------------------------------------------
    def _alert(self, t: float, rule: str, dev: int, tenant: int,
               value: float, thresh: float) -> None:
        seq = self._seq
        self._seq = seq + 1
        self.alerts.append((t, seq, rule, dev, tenant, value, thresh,
                            self._root_cause(dev, t)))
        self.counts[rule] = self.counts.get(rule, 0) + 1

    def _root_cause(self, dev: int, now: float) -> str:
        """Best overlapping explanation, most specific first: an active
        fault episode on the device (or any device, for array-wide
        alerts), then overlapping GC activity, then an active tenant
        throttle, else ``none``."""
        inj = self._inj
        if inj is not None:
            devs = range(self.n) if dev < 0 else (dev,)
            for i in devs:
                if inj.quarantined[i]:
                    return f"fault:quarantined:dev{i}"
                if inj.crashed[i]:
                    return f"fault:crashed:dev{i}"
                if inj.is_slow_now(i, now):
                    return f"fault:fail_slow:dev{i}"
        gc_fn = self._gc_fn
        if gc_fn is not None:
            g = gc_fn()
            if dev >= 0:
                if g[dev]:
                    return f"gc:dev{dev}"
            else:
                n_gc = sum(1 for x in g if x)
                if n_gc:
                    return f"gc:{n_gc}_devices"
        slo = self._slo
        if slo is not None:
            for t, f in slo.throttle.items():
                if f < 1.0:
                    return f"throttle:tenant{t}:{f:.3g}"
        return "none"

    # -- loop-hook compatibility ------------------------------------------
    # When self-hooked as ``loop.telemetry`` the engine also routes its GC
    # episode notes here; the monitor reads GC state through its own probe
    # closures, so these are deliberate no-ops.
    def note_gc_start(self, dev: int, now: float, dur: float,
                      idle: bool = False) -> None:
        pass

    def note_gc_end(self, dev: int, now: float) -> None:
        pass

    # -- tick evaluation (loop hook protocol) -----------------------------
    def on_tick(self, now: float) -> float:
        """Evaluate every boundary ``k * dt <= now``; returns the next
        boundary (the loop-hook contract). When chained from telemetry
        this is called once per boundary and the loop body runs once."""
        dt = self.dt
        k = self._k
        t = k * dt
        while t <= now:
            self._eval(t)
            k += 1
            t = k * dt
        self._k = k
        self.next_tick = t
        return t

    def _eval(self, t: float) -> None:
        armed = self.armed
        spec = self.spec
        if self._gc_latch is not None:
            g = self._gc_fn()
            n_gc = sum(1 for x in g if x)
            frac = n_gc / self.n
            if self._gc_latch.push(frac >= spec.gc_storm_frac) and armed:
                self._alert(t, "gc_storm", -1, -1, frac, spec.gc_storm_frac)
        busy = None
        if self._skew_d is not None:
            busy = self._busy_fn()
            deltas = [wd.push(busy[i])
                      for i, wd in enumerate(self._skew_d)]
            # the busy-time counters reset at the window boundary — a
            # negative delta marks stale pre-reset samples; skip the sweep
            if all(d >= 0.0 for d in deltas) and self.n >= 2:
                med = fast_median(deltas)
                if med > spec.util_skew_min_busy:
                    lim = spec.util_skew_ratio * med
                    for i, d in enumerate(deltas):
                        if self._skew_latch[i].push(d > lim) and armed:
                            self._alert(t, "util_skew", i, -1, d / med,
                                        spec.util_skew_ratio)
        if self._bl_latch is not None:
            bl = self._backlog_fn()
            lim = spec.backlog_frac * self._qd
            for i, b in enumerate(bl):
                if self._bl_latch[i].push(b >= lim) and armed:
                    self._alert(t, "backlog_sat", i, -1, float(b), lim)
        if self._wa_on and self._wa_fn is not None:
            self._wa_k += 1
            if self._wa_k >= spec.wa_window:
                self._wa_k = 0
                w, c = self._wa_fn()
                dw = w - self._wa_snap[0]
                dc = c - self._wa_snap[1]
                self._wa_snap = (w, c)
                prev = self._wa_prev[0]
                if dw >= spec.wa_min_writes:
                    wa = (dw + dc) / dw
                    fire = prev > 0.0 and wa > spec.wa_ratio * prev
                    if self._wa_latch.push(fire) and armed:
                        self._alert(t, "wa_spike", -1, -1, wa,
                                    spec.wa_ratio * prev)
                    self._wa_prev = (wa, dw)
                else:
                    self._wa_latch.push(False)
        if self._hit_on and self._cache_fn is not None:
            self._hit_k += 1
            if self._hit_k >= spec.hit_window:
                self._hit_k = 0
                h, lk = self._cache_fn()
                dh = h - self._hit_snap[0]
                dl = lk - self._hit_snap[1]
                self._hit_snap = (h, lk)
                prev = self._hit_prev
                if dl >= spec.hit_min_lookups:
                    rate = dh / dl
                    fire = prev > 0.0 and rate < spec.hit_drop * prev
                    if self._hit_latch.push(fire) and armed:
                        self._alert(t, "hit_collapse", -1, -1, rate,
                                    spec.hit_drop * prev)
                    self._hit_prev = rate
                else:
                    self._hit_latch.push(False)

    # -- completion stream (slo_burn) -------------------------------------
    def note_completion(self, tenant: int, latency: float,
                        now: float) -> None:
        """Protected-tenant completion (wired next to the QoS scheduler's
        own ``note_completion``); evaluates SLO burn online."""
        w = self._burn_win.get(tenant)
        if w is None:
            return
        p99 = self._burn_p99[tenant]
        bad = self._burn_bad[tenant]
        if len(w) == self.spec.slo_burn_window:
            # the sample about to fall off the window leaves the count
            if w.oldest() > p99:
                bad -= 1
        w.push(latency)
        if latency > p99:
            bad += 1
        self._burn_bad[tenant] = bad
        n = len(w)
        fire = (n >= self.spec.slo_burn_min_samples
                and bad / n > self.spec.slo_burn_frac)
        if self._burn_latch[tenant].push(fire) and self.armed:
            self._alert(now, "slo_burn", -1, tenant, bad / n,
                        self.spec.slo_burn_frac)

    # -- finalize ---------------------------------------------------------
    def finalize(self, now: float) -> MonitorResult:
        self._res = MonitorResult(spec=self.spec, n_devices=self.n,
                                  alerts=self.alerts, counts=self.counts)
        return self._res

    def result(self) -> Optional[MonitorResult]:
        return self._res


def merge_monitor(parts: list) -> Optional[MonitorResult]:
    """Merge per-shard :class:`MonitorResult` objects (shard order =
    device order). Deterministic: alerts re-base device ids by each
    shard's device offset, sort by ``(time, seq, shard)``, and renumber
    ``seq`` in merged stream order; rule counts add. Returns ``None`` if
    no shard carried a monitor."""
    if not parts or any(p is None for p in parts):
        return None
    base = 0
    keyed = []
    for si, p in enumerate(parts):
        for (t, seq, rule, dev, tenant, value, thresh, cause) in p.alerts:
            if dev >= 0:
                dev += base
            keyed.append((t, seq, si,
                          (rule, dev, tenant, value, thresh,
                           _rebase_cause(cause, base))))
        base += p.n_devices
    keyed.sort(key=lambda r: (r[0], r[1], r[2]))
    alerts = [(t, i) + rec for i, (t, _seq, _si, rec) in enumerate(keyed)]
    counts: dict[str, int] = {}
    for p in parts:
        for rule, c in p.counts.items():
            counts[rule] = counts.get(rule, 0) + c
    return MonitorResult(spec=parts[0].spec, n_devices=base,
                         alerts=alerts, counts=counts, merged=True)


def _rebase_cause(cause: str, base: int) -> str:
    """Shift the ``devN`` suffix of a root-cause annotation by the shard's
    device offset (tenant/throttle annotations pass through — tenant ids
    stay shard-local, matching the budget merge convention)."""
    if base and ":dev" in cause:
        head, _, tail = cause.rpartition(":dev")
        if tail.isdigit():
            return f"{head}:dev{int(tail) + base}"
    return cause

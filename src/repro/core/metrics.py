"""Deterministic streaming aggregators shared by the online subsystems.

Before this module, every online consumer of the event stream grew its own
private windowed-stat implementation: the SLO controller kept a deque +
``sorted()`` p99 (qos.py), the fail-slow detector kept parallel
``ewma``/``ew_n`` lists (faults.py), and the health monitor would have been
a third. This module is the single home for those primitives; the two
existing call sites are refactored onto it with **byte-identical**
arithmetic — same operations in the same order on the same floats — so
every golden and BENCH gate is unchanged.

Contract (matches the telemetry/monitor determinism rules):

- zero RNG — every aggregator is a pure fold over its inputs;
- picklable — plain attributes only, so sharded workers can ship state
  back through the pool (``__reduce__``-free, deque/list/float members);
- allocation-light — hot-path ``push``/``update`` methods do O(1) work
  (``SlidingWindow.quantile`` pays its ``sorted()`` only when asked, which
  is once per check interval, exactly like the code it replaced).
"""
from __future__ import annotations

from collections import deque

import numpy as np

__all__ = [
    "SlidingWindow", "Ewma", "WindowDelta", "EdgeLatch", "peer_median",
    "fast_median",
]


class SlidingWindow:
    """Fixed-size sliding window of samples with order-statistic queries.

    ``quantile(0.99)`` reproduces ``SloController._p99`` exactly:
    ``sorted(win)[min(len-1, int(len*q))]`` — the same upper-index pick on
    the same sorted list, so the refactored controller is byte-identical.
    """

    __slots__ = ("_win",)

    def __init__(self, maxlen: int):
        self._win: deque = deque(maxlen=maxlen)

    def push(self, x: float) -> None:
        self._win.append(x)

    def __len__(self) -> int:
        return len(self._win)

    def clear(self) -> None:
        self._win.clear()

    def oldest(self) -> float:
        """The sample that falls off on the next full-window push."""
        return self._win[0]

    def quantile(self, q: float) -> float:
        """Empirical quantile by upper-index pick (window must be non-empty)."""
        a = sorted(self._win)
        return a[min(len(a) - 1, int(len(a) * q))]

    def count_above(self, thresh: float) -> int:
        """How many window samples exceed ``thresh`` (SLO burn numerator)."""
        n = 0
        for x in self._win:
            if x > thresh:
                n += 1
        return n


class Ewma:
    """Exponentially weighted moving average, first-sample initialised.

    Reproduces ``FaultInjector.note_service`` exactly: the first sample
    *sets* the value (no zero-bias warmup), every later sample folds in as
    ``value += alpha * (x - value)`` — identical float ops in identical
    order, so the refactored fail-slow detector is byte-identical.
    """

    __slots__ = ("alpha", "value", "n")

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.value = 0.0
        self.n = 0

    def update(self, x: float) -> None:
        if self.n == 0:
            self.value = x
        else:
            self.value += self.alpha * (x - self.value)
        self.n += 1


class WindowDelta:
    """Windowed delta of a cumulative counter sampled on a fixed tick grid.

    ``push(total)`` records the counter's current cumulative value and
    returns the increase over the trailing ``window`` pushes (or over the
    shorter available history while filling). Used for per-tick-window
    rates: busy-time per window, writes per window, GC copies per window.
    """

    __slots__ = ("_hist", "_window")

    def __init__(self, window: int):
        if window < 1:
            raise ValueError("WindowDelta window must be >= 1")
        # window+1 samples span `window` intervals
        self._hist: deque = deque(maxlen=window + 1)
        self._window = window

    def push(self, total: float) -> float:
        h = self._hist
        h.append(total)
        return h[-1] - h[0]

    def full(self) -> bool:
        return len(self._hist) == self._hist.maxlen


class EdgeLatch:
    """Rising-edge detector with a consecutive-tick arming requirement.

    ``push(cond)`` returns True exactly once per episode: when ``cond`` has
    held for ``arm_ticks`` consecutive pushes and the latch is clear. The
    latch clears when ``cond`` drops, so a sustained condition produces one
    alert, not one per tick — the property that keeps alert streams bounded
    and deterministic.
    """

    __slots__ = ("arm_ticks", "_run", "_latched")

    def __init__(self, arm_ticks: int = 1):
        if arm_ticks < 1:
            raise ValueError("EdgeLatch arm_ticks must be >= 1")
        self.arm_ticks = arm_ticks
        self._run = 0
        self._latched = False

    def push(self, cond: bool) -> bool:
        if not cond:
            self._run = 0
            self._latched = False
            return False
        self._run += 1
        if self._latched or self._run < self.arm_ticks:
            return False
        self._latched = True
        return True

    def rearm(self) -> None:
        """Clear the latch without resetting the arming run: an active
        condition re-fires on the next push (used at the warmup boundary
        so a persisting pathology alerts once the window opens)."""
        self._latched = False

    @property
    def active(self) -> bool:
        return self._latched


def peer_median(values) -> float:
    """Median across peers, as the fail-slow sweep computes it
    (``float(np.median(...))`` — identical to the pre-refactor call)."""
    return float(np.median(values))


def fast_median(values) -> float:
    """Median without the numpy dispatch overhead (same result as
    ``np.median`` for finite floats: middle element for odd n, mean of the
    two middles for even n). The health monitor evaluates a peer median
    every tick over a handful of devices, where ``np.median``'s ~100 us of
    array setup would dominate the whole rule engine."""
    a = sorted(values)
    n = len(a)
    m = n // 2
    if n % 2:
        return float(a[m])
    return (a[m - 1] + a[m]) / 2.0

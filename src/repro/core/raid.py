"""Array layout subsystem: how logical pages map onto the array's SSDs.

Sits between the workload layer (``core/workloads.py``) and the per-SSD
``DeviceModel``s: a :class:`Layout` spec describes the data placement and a
per-run *planner* turns each logical :class:`~.workloads.Op` into a
:class:`Plan` — one or two *phases* of per-SSD page children. A logical op
completes when its last child completes, so a striped write finishes at the
**max** of its members — exactly the regime where the paper's unsynchronized
GC pauses hurt most: one straggling member (mid-GC) stalls every stripe that
touches it, and parity updates amplify random writes onto sibling SSDs.

Layouts
-------
* :class:`JBODLayout` — the historical behavior (page-granular round-robin of
  independent 1-page ops). The default; ``ArraySim`` keeps its byte-identical
  fast path for it.
* :class:`Raid0Layout` — striping without parity. A logical op covers up to
  ``stripe_width`` pages of one stripe row and fans out to one child per
  member page.
* :class:`Raid5Layout` — rotating parity (one parity member per stripe row,
  ``row % group``). Small writes do the classic read-modify-write: phase 1
  reads old data + old parity, phase 2 writes new data + new parity (2 reads
  + 2 writes for a 1-page write). Sequential runs are detected online and
  coalesce into full-stripe writes that skip the RMW entirely (parity is
  written once per row, write amplification ``group/(group-1)``).

Stripe groups: ``group`` partitions the array into independent RAID sets of
``group`` SSDs; stripe rows interleave across groups so load stays even. A
stripe never spans groups, which is what lets ``ShardedArraySim`` partition a
grouped array across worker processes with bit-identical results
(``shard_unit``).

Failure scenarios: ``Raid5Layout(degraded=1)`` drops the last member of every
group — reads reconstruct from the surviving row members, writes fall back to
reconstructing parity — and ``rebuild=True`` adds a background rebuild tenant
(:class:`RebuildSource`) that streams row-reconstruction I/O (read the
``group-1`` survivors, write the spare) in competition with foreground
traffic.

Everything here is pure planning — no simulated time, no RNG. The DES
integration (windows, parking, device service, measurement) lives in
``gc_sim.ArraySim._run_layout``.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

from .workloads import OP_READ, OP_REBUILD, OP_TRIM, OP_WRITE, Op, OpSource

__all__ = [
    "JBODLayout", "Layout", "Plan", "Raid0Layout", "Raid5Layout",
    "RebuildSource", "StripeMap", "layout_from_name",
]

# how many concurrent sequential runs the RAID-5 planner tracks before the
# oldest is evicted (its open row gets a catch-up parity plan). Matches the
# multi-cursor sequential sources (a handful of cursors), with headroom.
_MAX_RUNS = 128


class StripeMap:
    """Pure address algebra shared by the planners and the tests.

    Logical pages are grouped into stripe *rows* of ``d`` data pages
    (``d = group`` for RAID-0, ``group - 1`` for RAID-5); row ``s`` lives in
    group ``s % n_groups`` at member-LBA ``r = s // n_groups``. Within a
    RAID-5 group the parity member rotates (``r % group``, left-symmetric
    style) and data index ``i`` lands on member ``(parity + 1 + i) % group``.
    Every member therefore holds one page of every row of its group at the
    same member-LBA ``r`` — member LBAs stay dense in ``[0, rows)``, which is
    what the per-SSD FTLs (prefilled to ``rows`` live LBAs) expect.
    """

    __slots__ = ("n", "group", "n_groups", "d", "parity")

    def __init__(self, n: int, group: int, parity: bool):
        if group < (3 if parity else 2):
            raise ValueError(f"group={group} too small for "
                             f"{'RAID-5' if parity else 'RAID-0'}")
        if n % group:
            raise ValueError(f"n_ssds={n} not a multiple of group={group}")
        self.n = n
        self.group = group
        self.n_groups = n // group
        self.d = group - 1 if parity else group
        self.parity = parity

    def data_members(self) -> int:
        """Data-bearing member count (sizes the logical page space)."""
        return self.n_groups * self.d

    def row_of(self, lba: int) -> tuple[int, int, int]:
        """Logical page -> (group, member_lba r, within-row index i)."""
        s, i = divmod(lba, self.d)
        return s % self.n_groups, s // self.n_groups, i

    def parity_member(self, g: int, r: int) -> int:
        """Global SSD index of row ``r``'s parity member in group ``g``."""
        return g * self.group + r % self.group

    def data_member(self, g: int, r: int, i: int) -> int:
        """Global SSD index of data index ``i`` in row ``r`` of group ``g``."""
        if self.parity:
            local = (r % self.group + 1 + i) % self.group
        else:
            local = i
        return g * self.group + local

    def locate(self, lba: int) -> tuple[int, int]:
        """Logical page -> (global SSD index, member LBA)."""
        g, r, i = self.row_of(lba)
        return self.data_member(g, r, i), r

    def logical(self, g: int, r: int, i: int) -> int:
        """Inverse of :meth:`row_of`."""
        return (r * self.n_groups + g) * self.d + i

    def row_members(self, g: int, r: int) -> list[tuple[int, int, bool]]:
        """All member pages of a row: ``(ssd, member_lba, is_parity)``."""
        out = [(self.data_member(g, r, i), r, False) for i in range(self.d)]
        if self.parity:
            out.append((self.parity_member(g, r), r, True))
        return out


class Plan:
    """One logical op, lowered to phases of per-SSD page children.

    ``phases`` is a list of child lists; each child is ``(ssd, member_lba,
    kind)`` with kinds from ``core.workloads``. Phase ``k+1`` is submitted
    only when every child of phase ``k`` has completed (the RMW
    read-then-write dependency); the logical op completes with the last
    child of the last phase. ``ArraySim._run_layout`` owns the mutable
    bookkeeping fields (``stream``/``t_issue``/``remaining``/...)."""

    __slots__ = ("phases", "kind", "measured", "stall_track", "stream",
                 "t_issue", "phase_i", "remaining", "t_first", "t_last",
                 "hedge", "span")

    def __init__(self, phases, kind: int, measured: bool = True,
                 stall_track: bool = False):
        self.phases = phases
        self.kind = kind                  # OP_READ/OP_WRITE/OP_TRIM/OP_REBUILD
        self.measured = measured
        self.stall_track = stall_track
        # run-loop bookkeeping (set at submission)
        self.stream = -1
        self.t_issue = 0.0
        self.phase_i = 0
        self.remaining = 0
        self.t_first = -1.0
        self.t_last = 0.0
        # hedged-read record shared by the primary and its hedge leg
        # (core/faults.py): [done, primary_plan]. None outside hedging.
        self.hedge = None
        # telemetry span (core/telemetry.py); None unless span tracing is on
        # and this is a measured foreground plan
        self.span = None


class RebuildSource(OpSource):
    """Background rebuild tenant: an endless stream of ``OP_REBUILD`` ops,
    one per stripe row, cycling over every group's rows. The planner lowers
    each into (read the survivors, write the spare)."""

    def __init__(self) -> None:
        self._c = 0

    def next_op(self, now: float) -> Op:
        c = self._c
        self._c = c + 1
        return Op(c, False, kind=OP_REBUILD, tenant=-1)


def _new_stats() -> dict:
    return {
        "logical_writes": 0,      # logical data pages written (foreground)
        "logical_reads": 0,       # logical data pages read (foreground)
        "child_writes": 0,        # member page writes issued (data + parity)
        "child_reads": 0,         # member page reads issued (incl. RMW/rec.)
        "parity_writes": 0,       # parity member page writes
        "full_stripe_rows": 0,    # rows closed by the coalesced path
        "rmw_ops": 0,             # logical writes that took read-modify-write
        "deferred_writes": 0,     # seq-run writes that skipped the RMW
        "catchup_rows": 0,        # broken-run rows finished by catch-up plans
        "degraded_reads": 0,      # reads served by reconstruction
        "steered_reads": 0,       # healthy reads redirected to reconstruction
                                  # around a GC-busy member (gc_coord steer)
        "trims": 0,               # logical trims planned
        "trim_parity_skipped": 0, # RAID-5 TRIMs whose parity update was
                                  # skipped (modeling gap: parity left stale
                                  # for the trimmed pages; see benchmarks/
                                  # README.md)
        "rebuild_rows": 0,        # rebuild rows planned
        "rebuild_reads": 0,       # survivor reads issued by the rebuild tenant
        "rebuild_writes": 0,      # spare writes issued by the rebuild tenant
    }


class _PlannerStats:
    """Shared per-run stats bookkeeping (the snapshot/delta contract the
    run loops and sharded merges rely on)."""

    def snapshot(self) -> dict:
        return dict(self.stats)

    def delta(self, snap: dict) -> dict:
        return {k: v - snap[k] for k, v in self.stats.items()}


class _BasePlanner(_PlannerStats):
    """Shared planner state: stripe map, per-run stats, degraded member."""

    def __init__(self, smap: StripeMap, rows: int, stripe_width: int,
                 degraded: int):
        self.smap = smap
        self.rows = rows                          # member LBAs per SSD
        self.w = max(1, min(stripe_width, smap.d))
        if degraded not in (0, 1):
            raise ValueError("degraded must be 0 or 1 (single-parity array)")
        if degraded and not smap.parity:
            raise ValueError("degraded mode needs a parity layout (RAID-5); "
                             "a degraded RAID-0/JBOD member is data loss")
        self.degraded = degraded
        # the failed SSD is the last member of every group (arbitrary but
        # fixed; rotation spreads its role across data and parity rows)
        self.dead_local = smap.group - 1 if degraded else -1
        # per-group dead member (global SSD index, -1 = healthy). The static
        # degraded=1 spec fills every group; a mid-run Crash (core/faults.py)
        # flips exactly one via fail_member() and heal_member() clears it
        # when the rebuild completes.
        self.dead = [self._dead_ssd(g) if degraded else -1
                     for g in range(smap.n_groups)]
        self.stats = _new_stats()

    # -- shared helpers ------------------------------------------------------
    def _segment(self, lba: int) -> tuple[int, int, int, int]:
        """Aligned window of the op: (group, row, start_i, end_i).

        Ops are aligned to ``stripe_width`` *within* their stripe row, so a
        logical op never spans rows (and therefore never spans groups —
        the invariant stripe-group sharding relies on). The tail window of a
        row is short when the width doesn't divide ``d``."""
        g, r, i = self.smap.row_of(lba)
        start = i - i % self.w
        return g, r, start, min(start + self.w, self.smap.d)

    def _dead_ssd(self, g: int) -> int:
        return g * self.smap.group + self.dead_local


class _JBODPlanner(_PlannerStats):
    """Trivial pass-through planner: one 1-page child per logical op, using
    the fast path's round-robin mapping (``ssd = lba % n``, member LBA
    ``lba // n``). Exists for the QoS admission loop
    (``ArraySim._run_qos``), where per-tenant arbitration — not striping —
    is the point; the ``qos=None`` JBOD path keeps the byte-identical fast
    loop and never builds a planner."""

    rebuild = False

    def __init__(self, n: int):
        self.n = n
        self.stats = _new_stats()

    def plan(self, op: Op):
        kind = op.op_kind()
        ssd, lba = op.lba % self.n, op.lba // self.n
        st = self.stats
        if kind == OP_READ:
            st["logical_reads"] += 1
            st["child_reads"] += 1
        elif kind == OP_TRIM:
            st["trims"] += 1
        else:
            kind = OP_WRITE
            st["logical_writes"] += 1
            st["child_writes"] += 1
        return Plan([[(ssd, lba, kind)]], kind), None

    def flush(self):
        return []


class _Raid0Planner(_BasePlanner):
    """Striping without parity: one child per member page of the window."""

    rebuild = False

    def plan(self, op: Op):
        smap = self.smap
        kind = op.op_kind()
        g, r, s_i, e_i = self._segment(op.lba)
        k = e_i - s_i
        st = self.stats
        if kind == OP_READ:
            st["logical_reads"] += k
        elif kind == OP_TRIM:
            st["trims"] += k
        else:
            kind = OP_WRITE
            st["logical_writes"] += k
            st["child_writes"] += k
        children = [(smap.data_member(g, r, i), r, kind)
                    for i in range(s_i, e_i)]
        if kind == OP_READ:
            st["child_reads"] += k
        return Plan([children], kind,
                    stall_track=(kind == OP_WRITE and k > 1)), None

    def flush(self):
        return []


class _Raid5Planner(_BasePlanner):
    """Rotating parity with online sequential-run detection.

    A *run* is a contiguous ascending sequence of write windows (one per
    submitting cursor; the bounded ``_runs`` dict keys each run by the next
    logical page it expects). A write window that contiguously extends a run
    from the start of its stripe row skips the RMW — its parity is deferred
    and written once when the run closes the row (the full-stripe path). A
    window that doesn't (random writes, broken runs) pays the classic RMW:
    read old data + old parity, write new data + new parity. When a run with
    a half-covered row is evicted, a detached *catch-up* plan reconstructs
    and writes that row's parity (read the unwritten data pages, write
    parity) so parity is eventually consistent for every touched row.
    """

    # GC-aware read steering (core/gc_coord.py, ``steer=True``): the run
    # loop points this at the coordinator's per-SSD busy list; reads whose
    # target member is in (or about to enter) GC are then served by
    # reconstruction from the row's siblings instead of waiting out the
    # pause. None (the default) keeps planning pure and byte-identical.
    gc_busy: "list[bool] | None" = None

    # Quarantine read-steering (core/faults.py): like ``gc_busy`` but fed by
    # the fail-slow detector — reads of a quarantined member reconstruct
    # from siblings. None (the default) keeps planning byte-identical.
    avoid: "list[bool] | None" = None

    def __init__(self, smap: StripeMap, rows: int, stripe_width: int,
                 degraded: int, rebuild: bool):
        super().__init__(smap, rows, stripe_width, degraded)
        self.rebuild = rebuild and degraded > 0
        # groups the rebuild tenant cycles over (all of them under the
        # static degraded=1 spec; exactly the crashed one after a Crash)
        self._rebuild_groups = [g for g in range(smap.n_groups)
                                if self.dead[g] >= 0]
        # next_expected_lba -> [run_len_pages, open_row (g, r, covered) | None]
        self._runs: OrderedDict[int, list] = OrderedDict()

    # -- dynamic failure (core/faults.py Crash) ------------------------------
    def fail_member(self, ssd: int) -> int:
        """Mark ``ssd`` dead mid-run: its group plans degraded from now on
        and joins the rebuild rotation. Returns the rows the rebuild tenant
        must complete to heal the group."""
        g = ssd // self.smap.group
        self.dead[g] = ssd
        self._rebuild_groups = [gg for gg in range(self.smap.n_groups)
                                if self.dead[gg] >= 0]
        return self.rows

    def heal_member(self, ssd: int) -> None:
        """Rebuild finished: the spare holds every row — the group plans
        healthy again."""
        g = ssd // self.smap.group
        self.dead[g] = -1
        self._rebuild_groups = [gg for gg in range(self.smap.n_groups)
                                if self.dead[gg] >= 0]

    # -- rebuild -------------------------------------------------------------
    def _plan_rebuild(self, counter: int) -> "Plan | None":
        smap = self.smap
        dg = self._rebuild_groups
        if not dg:
            return None               # healed while ops were in flight
        g = dg[counter % len(dg)]
        r = (counter // len(dg)) % self.rows
        dead = self.dead[g]
        reads = [(ssd, lba, OP_READ)
                 for ssd, lba, _ in smap.row_members(g, r) if ssd != dead]
        st = self.stats
        st["rebuild_rows"] += 1
        # rebuild traffic gets its own counters: it is background
        # reconstruction load, NOT parity write amplification, so it must
        # stay out of the child_writes/logical_writes WA split
        st["rebuild_reads"] += len(reads)
        st["rebuild_writes"] += 1
        return Plan([reads, [(dead, r, OP_WRITE)]], OP_REBUILD,
                    measured=False)

    # -- reads ---------------------------------------------------------------
    def _plan_read(self, g: int, r: int, s_i: int, e_i: int) -> Plan:
        smap = self.smap
        st = self.stats
        k = e_i - s_i
        st["logical_reads"] += k
        dead = self.dead[g]
        if dead < 0:
            busy = self.gc_busy
            avoid = self.avoid
            if busy is not None or avoid is not None:
                return self._plan_read_steered(g, r, s_i, e_i, busy, avoid)
            children = [(smap.data_member(g, r, i), r, OP_READ)
                        for i in range(s_i, e_i)]
            st["child_reads"] += k
            return Plan([children], OP_READ)
        need: list[tuple[int, int]] = []     # ordered, deduped (ssd, lba)
        seen: set[int] = set()
        reconstructed = 0
        for i in range(s_i, e_i):
            ssd = smap.data_member(g, r, i)
            if ssd != dead:
                if ssd not in seen:
                    seen.add(ssd)
                    need.append((ssd, r))
            else:
                reconstructed += 1
                for o_ssd, o_lba, _ in smap.row_members(g, r):
                    if o_ssd != dead and o_ssd not in seen:
                        seen.add(o_ssd)
                        need.append((o_ssd, o_lba))
        st["degraded_reads"] += reconstructed
        st["child_reads"] += len(need)
        children = [(ssd, lba, OP_READ) for ssd, lba in need]
        return Plan([children], OP_READ)

    def _plan_read_steered(self, g: int, r: int, s_i: int, e_i: int,
                           busy: "list | None",
                           avoid: "list | None" = None) -> Plan:
        """Healthy-array read with GC-aware steering: a page whose member is
        GC-busy is reconstructed from the row's other members (data XOR
        parity) — g-1 short reads on serving members instead of one read
        parked behind a multi-ms GC pause — but only when EVERY sibling is
        itself GC-free (otherwise reconstruction would just move the wait).
        ``avoid`` (the fail-slow quarantine list, core/faults.py) composes
        with the GC busy list: a member hot in either is steered around.
        Degraded groups skip steering: the read path is already rebuilt
        around the dead member and has no redundancy left to steer with."""
        smap = self.smap
        st = self.stats
        if busy is None:
            hot = avoid
        elif avoid is None:
            hot = busy
        else:
            hot = [b or a for b, a in zip(busy, avoid)]
        need: list[tuple[int, int]] = []     # ordered, deduped (ssd, lba)
        seen: set[int] = set()
        steered = 0
        for i in range(s_i, e_i):
            ssd = smap.data_member(g, r, i)
            if hot[ssd]:
                sibs = [(o_ssd, o_lba)
                        for o_ssd, o_lba, _ in smap.row_members(g, r)
                        if o_ssd != ssd]
                if all(not hot[o_ssd] for o_ssd, _ in sibs):
                    steered += 1
                    for o_ssd, o_lba in sibs:
                        if o_ssd not in seen:
                            seen.add(o_ssd)
                            need.append((o_ssd, o_lba))
                    continue
            if ssd not in seen:
                seen.add(ssd)
                need.append((ssd, r))
        st["steered_reads"] += steered
        st["child_reads"] += len(need)
        children = [(ssd, lba, OP_READ) for ssd, lba in need]
        return Plan([children], OP_READ)

    # -- hedged reads (core/faults.py) ---------------------------------------
    def hedge_plan(self, ssd: int, r: int) -> "Plan | None":
        """Speculative sibling-reconstruction leg for a single-member read
        of member page ``r`` on ``ssd`` that blew its latency deadline: read
        every other row member (data XOR parity reconstructs the page).
        None when the group is degraded — reconstruction is already the
        primary path and there is no redundancy left to hedge with."""
        smap = self.smap
        g = ssd // smap.group
        if self.dead[g] >= 0:
            return None
        sibs = [(o_ssd, o_lba, OP_READ)
                for o_ssd, o_lba, _ in smap.row_members(g, r)
                if o_ssd != ssd]
        self.stats["child_reads"] += len(sibs)
        return Plan([sibs], OP_READ, measured=False)

    # -- writes --------------------------------------------------------------
    def _run_continue(self, lba0: int, k: int):
        """Advance run tracking. Returns ``(run_len, evicted_open_rows)``:
        the total contiguous run length in pages INCLUDING this window
        (``k`` when the window starts a run), and the open deferred rows of
        any runs displaced on the way — a run already keyed at the new
        next-expected page (two cursors converging / a re-write of the run's
        last page), and the oldest run when the table overflows. Displaced
        open rows MUST be surfaced so the caller emits catch-up parity,
        or the row would silently stay parity-inconsistent."""
        runs = self._runs
        state = runs.pop(lba0, None)
        if state is None:
            state = [k, None]
        else:
            state[0] += k
        evicted = []
        collided = runs.pop(lba0 + k, None)
        if collided is not None and collided[1] is not None:
            evicted.append(collided[1])
        runs[lba0 + k] = state
        if len(runs) > _MAX_RUNS:
            _, oldest = runs.popitem(last=False)
            if oldest[1] is not None:
                evicted.append(oldest[1])
        return state[0], evicted

    def _catchup_plan(self, open_row) -> Plan:
        """Detached plan finishing the parity of a half-written row: read the
        data pages the run never wrote, write the parity page."""
        g, r, covered = open_row
        smap = self.smap
        dead = self.dead[g]
        reads = []
        for i in range(covered, smap.d):
            ssd = smap.data_member(g, r, i)
            if ssd != dead:
                reads.append((ssd, r, OP_READ))
        p_ssd = smap.parity_member(g, r)
        st = self.stats
        st["catchup_rows"] += 1
        st["child_reads"] += len(reads)
        st["child_writes"] += 1
        st["parity_writes"] += 1
        phases = [reads, [(p_ssd, r, OP_WRITE)]] if reads \
            else [[(p_ssd, r, OP_WRITE)]]
        return Plan(phases, OP_WRITE, measured=False)

    def _plan_write(self, lba: int, g: int, r: int, s_i: int, e_i: int,
                    trim: bool):
        smap = self.smap
        st = self.stats
        k = e_i - s_i
        lba0 = smap.logical(g, r, s_i)
        dead = self.dead[g]
        p_ssd = smap.parity_member(g, r)
        parity_dead = p_ssd == dead

        if trim:
            # TRIM invalidates the data pages; parity upkeep is skipped (the
            # modeled cost of trimming is mapping-table-only on the members).
            # The skip is a modeling gap — the row's parity goes stale for
            # the trimmed pages until the next write re-establishes it — so
            # it is COUNTED (one skipped update per data page whose row still
            # has live parity) and surfaced as
            # ``ArrayResults.trim_parity_skipped``.
            st["trims"] += k
            if not parity_dead:
                st["trim_parity_skipped"] += k
            children = [(smap.data_member(g, r, i), r, OP_TRIM)
                        for i in range(s_i, e_i)
                        if smap.data_member(g, r, i) != dead]
            if not children:
                # every target page is on the failed member: nothing to send
                # (a Plan with an empty phase would never complete and leak
                # the stream's window slot)
                return None, None
            return Plan([children], OP_TRIM), None

        st["logical_writes"] += k
        run_len, evicted = self._run_continue(lba0, k)
        detached = [self._catchup_plan(e) for e in evicted] or None
        continued = run_len > k

        data_writes = [(smap.data_member(g, r, i), r, OP_WRITE)
                       for i in range(s_i, e_i)
                       if smap.data_member(g, r, i) != dead]
        dropped = k - len(data_writes)            # writes to the dead member

        if parity_dead:
            # the row's parity page is on the failed member: no parity to
            # maintain, plain data writes (the row runs parity-less)
            st["child_writes"] += len(data_writes)
            self._clear_open(lba0 + k)
            return Plan([data_writes], OP_WRITE,
                        stall_track=len(data_writes) > 1), detached

        closes_row = e_i == smap.d
        if closes_row and run_len >= smap.d:
            # full-stripe close: the run wrote every data page of the row —
            # write the tail data + parity once, no reads
            st["full_stripe_rows"] += 1
            st["deferred_writes"] += k
            st["child_writes"] += len(data_writes) + 1
            st["parity_writes"] += 1
            children = data_writes + [(p_ssd, r, OP_WRITE)]
            self._clear_open(lba0 + k)
            return Plan([children], OP_WRITE, stall_track=True), detached

        if continued and run_len >= e_i:
            # mid-row continuation of a real run: defer parity to the close
            st["deferred_writes"] += k
            st["child_writes"] += len(data_writes)
            self._set_open(lba0 + k, (g, r, e_i))
            return Plan([data_writes], OP_WRITE,
                        stall_track=len(data_writes) > 1), detached

        # read-modify-write (2 reads + 2 writes for a 1-page write)
        st["rmw_ops"] += 1
        if dropped:
            # a target page is on the failed member: reconstruct parity from
            # the untouched data pages (parity absorbs the lost write)
            reads = [(smap.data_member(g, r, i), r, OP_READ)
                     for i in range(smap.d)
                     if not (s_i <= i < e_i)
                     and smap.data_member(g, r, i) != dead]
        else:
            reads = [(smap.data_member(g, r, i), r, OP_READ)
                     for i in range(s_i, e_i)] + [(p_ssd, r, OP_READ)]
        writes = data_writes + [(p_ssd, r, OP_WRITE)]
        st["child_reads"] += len(reads)
        st["child_writes"] += len(writes)
        st["parity_writes"] += 1
        phases = [reads, writes] if reads else [writes]
        return Plan(phases, OP_WRITE,
                    stall_track=len(writes) > 1), detached

    def _set_open(self, run_key: int, open_row) -> None:
        state = self._runs.get(run_key)
        if state is not None:
            state[1] = open_row

    def _clear_open(self, run_key: int) -> None:
        state = self._runs.get(run_key)
        if state is not None:
            state[1] = None

    # -- entry ---------------------------------------------------------------
    def plan(self, op: Op):
        kind = op.op_kind()
        if kind == OP_REBUILD:
            return self._plan_rebuild(op.lba), None
        g, r, s_i, e_i = self._segment(op.lba)
        if kind == OP_READ:
            return self._plan_read(g, r, s_i, e_i), None
        return self._plan_write(op.lba, g, r, s_i, e_i, kind == OP_TRIM)

    def flush(self) -> list[Plan]:
        """Close every still-open deferred row (end-of-run bookkeeping; the
        XOR property test uses this to reach a parity-consistent state)."""
        out = []
        for _, state in self._runs.items():
            if state[1] is not None:
                out.append(self._catchup_plan(state[1]))
                state[1] = None
        return out


# ---------------------------------------------------------------------------
# Layout specs (frozen, hashable, picklable — safe for prefill-cache keys and
# for shipping to sharded worker processes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Layout:
    """Base spec. ``trivial`` layouts keep ``ArraySim``'s fast path.

    ``trivial``/``parity``/``rebuild`` are plain class attributes (not
    dataclass fields) so subclasses may shadow them with real fields."""

    trivial = False
    parity = False
    rebuild = False

    def data_members(self, n: int) -> int:
        raise NotImplementedError

    def shard_unit(self, n: int) -> int:
        """SSDs per indivisible stripe group (shard sizes must be multiples
        of this so a stripe group never spans shards)."""
        return 1

    def make_planner(self, n: int, rows: int):
        raise NotImplementedError

    @property
    def name(self) -> str:
        return type(self).__name__.replace("Layout", "").lower()


@dataclass(frozen=True)
class JBODLayout(Layout):
    """Independent 1-page LBAs round-robined across SSDs — the historical
    ``ArraySim`` behavior. ``ArraySim`` recognizes it and keeps the
    byte-identical fast path (PR 2 goldens)."""

    trivial = True

    def data_members(self, n: int) -> int:
        return n

    def make_planner(self, n: int, rows: int) -> _JBODPlanner:
        # only the QoS loop plans JBOD ops; qos=None keeps the fast path
        return _JBODPlanner(n)


@dataclass(frozen=True)
class Raid0Layout(Layout):
    """Page-interleaved striping, no parity. ``stripe_width`` pages per
    logical op (clamped to the group's data width); ``group`` SSDs per
    independent stripe group (default: the whole array)."""

    stripe_width: int = 4
    group: int | None = None

    def _group(self, n: int) -> int:
        return self.group or n

    def data_members(self, n: int) -> int:
        return StripeMap(n, self._group(n), parity=False).data_members()

    def shard_unit(self, n: int) -> int:
        return self._group(n)

    def make_planner(self, n: int, rows: int) -> _Raid0Planner:
        smap = StripeMap(n, self._group(n), parity=False)
        return _Raid0Planner(smap, rows, self.stripe_width, degraded=0)


@dataclass(frozen=True)
class Raid5Layout(Layout):
    """Rotating-parity striping. ``group`` SSDs per RAID set (``group - 1``
    data + 1 rotating parity per row; default: the whole array).
    ``degraded=1`` fails the last member of every group; ``rebuild=True``
    (with ``degraded``) adds the background rebuild tenant, whose closed-loop
    window is ``rebuild_window`` rows."""

    stripe_width: int = 1
    group: int | None = None
    degraded: int = 0
    rebuild: bool = False
    rebuild_window: int = 4

    parity = True

    def _group(self, n: int) -> int:
        return self.group or n

    def data_members(self, n: int) -> int:
        return StripeMap(n, self._group(n), parity=True).data_members()

    def shard_unit(self, n: int) -> int:
        return self._group(n)

    def make_planner(self, n: int, rows: int) -> _Raid5Planner:
        smap = StripeMap(n, self._group(n), parity=True)
        return _Raid5Planner(smap, rows, self.stripe_width, self.degraded,
                             self.rebuild)


def layout_from_name(name: str, **kw) -> Layout:
    """Benchmark/CLI convenience: ``"jbod" | "raid0" | "raid5"``."""
    table = {"jbod": JBODLayout, "raid0": Raid0Layout, "raid5": Raid5Layout}
    try:
        return table[name](**kw)
    except KeyError:
        raise ValueError(f"unknown layout {name!r} "
                         f"(expected one of {sorted(table)})") from None

"""Shared discrete-event engine for the SSD-array simulators.

One heap-based event loop (``EventLoop``) and one queue-aware device service
model (``DeviceModel``) replace the two near-duplicate loops that used to live
in ``gc_sim.ArraySim.run`` and ``safs_sim.SAFSSim``.

The modeling change that matters: an SSD is **not** a fluid single server.
``DeviceModel`` admits up to ``device_slots`` requests into the NCQ and
services up to ``channels`` of them *concurrently*, each occupying one channel
for its full ``t_op``. Peak throughput is still ``channels / t_op`` (the
calibration target is unchanged) but now it is only reached when the host
keeps enough requests outstanding — queue depth becomes a real experimental
variable, which is the paper's central lever: long per-SSD queues hide
unsynchronized GC pauses.

GC keeps strict priority: once the free-block watermark trips, the device
stops starting new service, lets in-flight channel operations drain, then runs
the whole GC episode with every channel preempted.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np


class EventLoop:
    """Minimal heap-based discrete-event loop: schedule callbacks, run them
    in time order. Ties are broken by insertion order (FIFO), so causally
    ordered same-time events stay ordered."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + delay, fn)

    def at(self, time: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (time, self._seq, fn))
        self._seq += 1

    def step(self) -> bool:
        """Run the next event; False when no events remain."""
        if not self._heap:
            return False
        self.now, _, fn = heapq.heappop(self._heap)
        fn()
        return True

    def run_while(self, cond: Callable[[], bool]) -> None:
        while cond() and self.step():
            pass


@dataclass
class LatencySummary:
    mean: float
    p50: float
    p95: float
    p99: float
    n: int

    @staticmethod
    def empty() -> "LatencySummary":
        return LatencySummary(0.0, 0.0, 0.0, 0.0, 0)


class LatencyRecorder:
    """Per-request latency samples -> mean/p50/p95/p99."""

    def __init__(self) -> None:
        self._samples: list[float] = []

    def record(self, latency: float) -> None:
        self._samples.append(latency)

    def reset(self) -> None:
        self._samples.clear()

    def __len__(self) -> int:
        return len(self._samples)

    def summary(self) -> LatencySummary:
        if not self._samples:
            return LatencySummary.empty()
        a = np.asarray(self._samples)
        p50, p95, p99 = np.percentile(a, [50.0, 95.0, 99.0])
        return LatencySummary(mean=float(a.mean()), p50=float(p50),
                              p95=float(p95), p99=float(p99), n=a.size)


class MeasurementWindow:
    """Warmup-gated measurement shared by both simulators.

    Counts completions; at the warmup boundary it latches ``t0``, fires
    ``on_begin`` (the simulator's counter snapshot/reset hook), and starts
    recording per-request latency. The completion that crosses the boundary
    is NOT measured — its latency spans the warmup, which would skew the
    percentiles."""

    def __init__(self, loop: EventLoop, warmup: int,
                 on_begin: Callable[[], None]) -> None:
        self.loop = loop
        self.warmup = warmup
        self.on_begin = on_begin
        self.completed = 0
        self.measuring = False
        self.t0 = 0.0
        self.latency = LatencyRecorder()

    def note_completion(self, t_issue: float) -> bool:
        """Record one completion; True iff it falls inside the window."""
        self.completed += 1
        if self.measuring:
            self.latency.record(self.loop.now - t_issue)
            return True
        if self.completed >= self.warmup:
            self.measuring = True
            self.t0 = self.loop.now
            self.on_begin()
        return False

    @property
    def span(self) -> float:
        return max(self.loop.now - self.t0, 1e-9)


class DeviceModel:
    """Multi-slot NCQ service on top of an ``SSDServer``.

    * ``pull()`` supplies the next host-side request to admit (or None) —
      this is where each simulator plugs its own queue discipline (plain
      bounded FIFO for ``ArraySim``, dual-priority ``DualQueue`` for SAFS).
    * ``service_time(req)`` gives the per-request channel occupancy.
    * ``on_done(req)`` fires at completion, *before* the next kick, so the
      callback may submit follow-on work.

    Admission: NCQ holds at most ``device_slots`` requests (waiting + in
    service). Service: up to ``channels`` admitted requests run concurrently,
    FIFO from the NCQ. GC: when ``ftl.need_gc()`` trips, no new service
    starts; once the channels drain the full episode runs with the device
    (all channels) preempted, exactly once per trip.

    ``server.busy_time`` accumulates channel-seconds (a request of duration
    ``dt`` adds ``dt``; a GC episode adds ``dt * channels``), so utilization
    is ``busy_time / (span * channels)``.
    """

    def __init__(self, loop: EventLoop, server: Any,
                 pull: Callable[[], Optional[Any]],
                 service_time: Callable[[Any], float],
                 on_done: Callable[[Any], None]) -> None:
        self.loop = loop
        self.server = server
        self.pull = pull
        self.service_time = service_time
        self.on_done = on_done
        self.admitted: deque = deque()
        self.in_service = 0
        self.in_gc = False

    @property
    def occupancy(self) -> int:
        """Requests inside the device (NCQ waiting + in service)."""
        return len(self.admitted) + self.in_service

    def kick(self) -> None:
        """Admit from the host queue and start service / GC episodes."""
        p = self.server.p
        while self.occupancy < p.device_slots:
            req = self.pull()
            if req is None:
                break
            self.admitted.append(req)
        if self.in_gc:
            return
        if self.server.ftl.need_gc():
            if self.in_service == 0:
                self._start_gc()
            return  # drain channels first; completion re-kicks
        while self.in_service < p.channels and self.admitted:
            req = self.admitted.popleft()
            dt = self.service_time(req)
            self.in_service += 1
            self.server.busy_time += dt
            self.loop.schedule(dt, lambda req=req: self._complete(req))

    def _start_gc(self) -> None:
        s = self.server
        dt = s.gc_episode_time()
        self.in_gc = True
        s.in_gc = True
        s.gc_time += dt
        s.busy_time += dt * s.p.channels
        self.loop.schedule(dt, self._gc_done)

    def _gc_done(self) -> None:
        self.in_gc = False
        self.server.in_gc = False
        self.kick()

    def _complete(self, req: Any) -> None:
        self.in_service -= 1
        self.on_done(req)
        self.kick()

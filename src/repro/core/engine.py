"""Shared discrete-event engine for the SSD-array simulators.

One heap-based event loop (``EventLoop``) and one queue-aware device service
model (``DeviceModel``) replace the two near-duplicate loops that used to live
in ``gc_sim.ArraySim.run`` and ``safs_sim.SAFSSim``.

The modeling change that matters: an SSD is **not** a fluid single server.
``DeviceModel`` admits up to ``device_slots`` requests into the NCQ and
services up to ``channels`` of them *concurrently*, each occupying one channel
for its full ``t_op``. Peak throughput is still ``channels / t_op`` (the
calibration target is unchanged) but now it is only reached when the host
keeps enough requests outstanding — queue depth becomes a real experimental
variable, which is the paper's central lever: long per-SSD queues hide
unsynchronized GC pauses.

GC keeps strict priority: once the free-block watermark trips, the device
stops starting new service, lets in-flight channel operations drain, then runs
the whole GC episode with every channel preempted.

Fast path (events/sec is the binding constraint on every experiment):

* Events are slotted ``(time, seq, slot)`` records pointing into parallel
  ``handler`` / ``payload`` record arrays with free-list reuse — scheduling
  a completion allocates **no** per-event lambda or closure, only a record
  tuple. Handlers that need arguments take them as a single payload object
  (``call`` / ``call_at``); the zero-argument legacy API (``schedule`` /
  ``at``) rides on the same records with a no-payload sentinel.
* Scheduling is a two-level **calendar queue** (sorted near-term list +
  far-term time buckets) instead of a binary heap: completion times are
  near-constant ``t_op`` multiples, the ideal calendar workload, so pops
  are O(1) and far inserts are a dict append. Event *order* is the exact
  heap order — ``(time, seq, slot)`` tuples compare identically whether
  heap-sifted or Timsorted — see ``EventLoop`` for the invariants.
* ``run()`` is the inlined dispatch loop: simulators install a completion
  target on the ``MeasurementWindow`` which calls ``EventLoop.stop()``, so
  no per-event Python condition callback is needed (``run_while`` remains
  for callers that want one).
* ``LatencyRecorder`` stores samples in a preallocated, doubling float64
  numpy buffer and caches its summary until the next ``record`` — repeated
  ``summary()`` calls never rescan.

The fast path is semantics-preserving: event ordering, RNG consumption, and
float accumulation order are unchanged, so a fixed seed produces byte
identical counters/IOPS before and after (goldens recorded from the pre-
fast-path engine: ``tests/test_golden_determinism.py``).
"""
from __future__ import annotations

from bisect import insort
from collections import deque
from dataclasses import dataclass
from heapq import heappop, heappush
from typing import Any, Callable, Optional

import numpy as np

_NO_PAYLOAD = object()   # sentinel: invoke the handler with no argument

# calendar-queue tuning: number of positive scheduling deltas sampled before
# the bucket width is fixed, and the near-list compaction threshold
_CALIB_SAMPLES = 64
_COMPACT_AT = 1024


class EventLoop:
    """Minimal discrete-event loop: schedule callbacks, run them in time
    order. Ties are broken by insertion order (FIFO), so causally ordered
    same-time events stay ordered.

    Event records live in parallel slot arrays (``_handlers``/``_payloads``)
    recycled through a free list; the scheduler holds only ``(time, seq,
    slot)`` tuples. ``processed`` counts dispatched events (the events/sec
    metric).

    Scheduling is a two-level **calendar queue** rather than a binary heap:

    * ``_near`` — a sorted list of the soonest events, consumed by an
      integer pop index ``_ni`` (an O(1) pop; same-time runs of events are
      drained as an already-sorted batch, no per-pop sift-down).
    * ``_far`` — a dict of buckets ``int(time * _inv_w) -> [events]``;
      future inserts are a plain dict append. Buckets are *sparse* (any
      integer key), so there is no wheel wrap-around or overflow list: an
      event arbitrarily far in the future just lands in a higher-numbered
      bucket. ``_bheap`` is a small min-heap of pending bucket indices
      (pushed once per bucket creation, far less than once per event).
    * When ``_near`` drains, the smallest pending bucket is popped, sorted
      (C Timsort over ``(time, seq, slot)`` tuples — the exact heap
      comparison order), and becomes the new near list.

    Invariants (these make the calendar byte-identical to the old heap):

    * every near event has ``time < (cur_bucket + 1) * width`` and every far
      event has ``time >= (cur_bucket + 1) * width``, so draining near
      before touching far preserves global time order;
    * ``seq`` increases monotonically across ALL inserts, so sorting a
      bucket — or insorting a same/past-bucket event into near at position
      ``>= _ni`` — reproduces the heap's FIFO tie-break exactly;
    * the bucket width is calibrated once, from the first positive
      scheduling deltas, and is a deterministic function of the event
      stream: a fixed seed sees the same calendar shape every run. Until
      calibration (or when every delta is zero) the loop degenerates to a
      single sorted list, which is still exact.
    """

    __slots__ = ("now", "_seq", "_handlers", "_payloads", "_free",
                 "processed", "_stopped",
                 "_near", "_ni", "_far", "_bheap", "_cur", "_inv_w",
                 "_dsamples", "telemetry")

    def __init__(self) -> None:
        self.now = 0.0
        # optional core/telemetry.py collector: the dispatch loops check
        # tick-boundary crossings at event pop (one float compare per event
        # when attached; no probe events are ever scheduled)
        self.telemetry = None
        self._seq = 0
        self._handlers: list[Any] = []
        self._payloads: list[Any] = []
        self._free: list[int] = []
        self.processed = 0
        self._stopped = False
        self._near: list[tuple[float, int, int]] = []
        self._ni = 0                  # pop index into _near
        self._far: dict[int, list[tuple[float, int, int]]] = {}
        self._bheap: list[int] = []   # pending far bucket indices (min-heap)
        self._cur = 0                 # current bucket index
        self._inv_w = 0.0             # 1/width; 0.0 = uncalibrated
        self._dsamples: list[float] = []

    # -- scheduling ----------------------------------------------------------
    def call_at(self, time: float, handler: Callable, payload: Any = _NO_PAYLOAD) -> None:
        """Schedule ``handler(payload)`` (or ``handler()`` without payload)
        at absolute ``time`` using a recycled event record."""
        free = self._free
        if free:
            slot = free.pop()
            self._handlers[slot] = handler
            self._payloads[slot] = payload
        else:
            slot = len(self._handlers)
            self._handlers.append(handler)
            self._payloads.append(payload)
        seq = self._seq
        self._seq = seq + 1
        ev = (time, seq, slot)
        inv_w = self._inv_w
        if inv_w:
            b = int(time * inv_w)
            if b > self._cur:
                far = self._far
                lst = far.get(b)
                if lst is None:
                    far[b] = [ev]
                    heappush(self._bheap, b)
                else:
                    lst.append(ev)
            else:
                # current (or past) bucket: keep the near list sorted. lo=_ni
                # skips the consumed prefix; correctness of the FIFO tie-break
                # holds because seq is globally monotone. Compaction of the
                # consumed prefix happens only in the dispatch loop, so the
                # loop may cache the list and pop index in locals.
                insort(self._near, ev, self._ni)
        else:
            # uncalibrated: single sorted list (exact, just not O(1))
            insort(self._near, ev, self._ni)
            delta = time - self.now
            if delta > 0.0:
                d = self._dsamples
                d.append(delta)
                if len(d) >= _CALIB_SAMPLES:
                    self._calibrate()

    def _calibrate(self) -> None:
        """Fix the bucket width from the sampled scheduling deltas: a
        quarter of the median delta, so a typical completion lands a few
        buckets ahead and same-window events share a bucket. Deterministic —
        the samples are a pure function of the event stream."""
        d = sorted(self._dsamples)
        width = d[len(d) // 2] / 4.0
        if width <= 0.0:
            return
        self._dsamples = []
        self._inv_w = 1.0 / width
        # anchor the current bucket at the LAST near event: every far insert
        # must be strictly later than everything already in near
        near = self._near
        anchor = near[-1][0] if self._ni < len(near) else self.now
        self._cur = int(anchor * self._inv_w)

    def call(self, delay: float, handler: Callable, payload: Any = _NO_PAYLOAD) -> None:
        self.call_at(self.now + delay, handler, payload)

    # legacy zero-argument-callback API (tests, ad-hoc wakeups)
    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        self.call_at(self.now + delay, fn)

    def at(self, time: float, fn: Callable[[], None]) -> None:
        self.call_at(time, fn)

    # -- dispatch ------------------------------------------------------------
    def stop(self) -> None:
        """Make ``run()`` return after the current event's handler."""
        self._stopped = True

    def _advance(self) -> bool:
        """Near list drained: promote the smallest far bucket. False when no
        events remain anywhere."""
        bheap = self._bheap
        if not bheap:
            return False
        b = heappop(bheap)
        lst = self._far.pop(b)
        lst.sort()                    # (time, seq, slot): exact heap order
        self._near = lst
        self._ni = 0
        self._cur = b
        return True

    def step(self) -> bool:
        """Run the next event; False when no events remain."""
        near = self._near
        ni = self._ni
        if ni >= len(near):
            if not self._advance():   # far buckets are never empty
                return False
            near = self._near
            ni = 0
        elif ni > _COMPACT_AT:        # shed the consumed prefix (uncalibrated
            del near[:ni]             # mode never swaps the near list out)
            ni = 0
        t, _, slot = near[ni]
        tel = self.telemetry
        if tel is not None and t >= tel.next_tick:
            tel.on_tick(t)
        self.now = t
        self._ni = ni + 1
        handler = self._handlers[slot]
        payload = self._payloads[slot]
        self._handlers[slot] = None
        self._payloads[slot] = None
        self._free.append(slot)
        self.processed += 1
        if payload is _NO_PAYLOAD:
            handler()
        else:
            handler(payload)
        return True

    def run(self) -> int:
        """Dispatch until ``stop()`` or the calendar drains; returns the
        number of events processed by this call. This is the hot loop —
        everything is bound to locals. The near list and pop index live in
        locals across events: a handler's ``call_at`` may *insort* into the
        cached list (same object, position ``>= _ni``) but never swaps or
        compacts it — only this loop does, where the locals are re-anchored.
        ``self._ni`` is published before each dispatch so ``call_at`` sees
        the true consumed prefix."""
        handlers = self._handlers
        payloads = self._payloads
        free_append = self._free.append
        no_payload = _NO_PAYLOAD
        self._stopped = False
        n = 0
        near = self._near
        ni = self._ni
        tel = self.telemetry
        # tick-crossing guard held in a local: inf when telemetry is off, so
        # the only per-event cost is one float compare
        tick = tel.next_tick if tel is not None else float("inf")
        try:
            while not self._stopped:
                if ni >= len(near):
                    bheap = self._bheap
                    if not bheap:
                        break
                    b = heappop(bheap)
                    near = self._far.pop(b)
                    near.sort()
                    self._near = near
                    self._cur = b
                    ni = 0
                elif ni > _COMPACT_AT:
                    del near[:ni]
                    ni = 0
                t, _, slot = near[ni]
                if t >= tick:
                    tick = tel.on_tick(t)
                self.now = t
                ni += 1
                self._ni = ni
                handler = handlers[slot]
                payload = payloads[slot]
                handlers[slot] = None
                payloads[slot] = None
                free_append(slot)
                n += 1
                if payload is no_payload:
                    handler()
                else:
                    handler(payload)
        finally:
            self._ni = ni
            self.processed += n
        return n

    def run_while(self, cond: Callable[[], bool]) -> None:
        while cond() and self.step():
            pass


@dataclass
class LatencySummary:
    mean: float
    p50: float
    p95: float
    p99: float
    n: int

    @staticmethod
    def empty() -> "LatencySummary":
        return LatencySummary(0.0, 0.0, 0.0, 0.0, 0)


class LatencyRecorder:
    """Per-request latency samples -> mean/p50/p95/p99.

    Samples live in a preallocated float64 numpy buffer that doubles when
    full (amortized O(1) per record, no per-sample object). ``summary()`` is
    cached until the next ``record``/``reset`` — repeated calls don't rescan
    the buffer."""

    __slots__ = ("_buf", "_n", "_summary")

    def __init__(self, capacity: int = 4096) -> None:
        self._buf = np.empty(max(int(capacity), 16), dtype=np.float64)
        self._n = 0
        self._summary: Optional[LatencySummary] = None

    def record(self, latency: float) -> None:
        n = self._n
        buf = self._buf
        if n == buf.shape[0]:
            grown = np.empty(2 * n, dtype=np.float64)
            grown[:n] = buf
            self._buf = buf = grown
        buf[n] = latency
        self._n = n + 1
        self._summary = None

    def reset(self) -> None:
        self._n = 0
        self._summary = None

    def __len__(self) -> int:
        return self._n

    def values(self) -> np.ndarray:
        """Copy of the recorded samples (for cross-shard merging)."""
        return self._buf[:self._n].copy()

    def summary(self) -> LatencySummary:
        s = self._summary
        if s is None:
            n = self._n
            if n == 0:
                s = LatencySummary.empty()
            else:
                a = self._buf[:n]
                p50, p95, p99 = np.percentile(a, [50.0, 95.0, 99.0])
                s = LatencySummary(mean=float(a.mean()), p50=float(p50),
                                   p95=float(p95), p99=float(p99), n=n)
            self._summary = s
        return s


class MeasurementWindow:
    """Warmup-gated measurement shared by both simulators.

    Counts completions; at the warmup boundary it latches ``t0``, fires
    ``on_begin`` (the simulator's counter snapshot/reset hook), and starts
    recording per-request latency. The completion that crosses the boundary
    is NOT measured — its latency spans the warmup, which would skew the
    percentiles.

    With ``target`` set, the completion that reaches it calls
    ``loop.stop()`` so the run loop needs no per-event condition callback
    (the stopping event's handler still finishes, exactly like the legacy
    ``run_while`` exit)."""

    __slots__ = ("loop", "warmup", "on_begin", "completed", "measuring",
                 "t0", "latency", "target")

    def __init__(self, loop: EventLoop, warmup: int,
                 on_begin: Callable[[], None],
                 target: Optional[int] = None) -> None:
        self.loop = loop
        self.warmup = warmup
        self.on_begin = on_begin
        self.completed = 0
        self.measuring = False
        self.t0 = 0.0
        self.latency = LatencyRecorder()
        self.target = target

    def note_completion(self, t_issue: float) -> bool:
        """Record one completion; True iff it falls inside the window."""
        completed = self.completed + 1
        self.completed = completed
        target = self.target
        if self.measuring:
            self.latency.record(self.loop.now - t_issue)
            if target is not None and completed >= target:
                self.loop.stop()
            return True
        if completed >= self.warmup:
            self.measuring = True
            self.t0 = self.loop.now
            self.on_begin()
            if target is not None and completed >= target:
                self.loop.stop()
        return False

    @property
    def span(self) -> float:
        return max(self.loop.now - self.t0, 1e-9)


class DeviceModel:
    """Multi-slot NCQ service on top of an ``SSDServer``.

    * ``pull()`` supplies the next host-side request to admit (or None) —
      this is where each simulator plugs its own queue discipline (plain
      bounded FIFO for ``ArraySim``, dual-priority ``DualQueue`` for SAFS).
    * ``service_time(req)`` gives the per-request channel occupancy.
    * ``on_done(req)`` fires at completion, *before* the next kick, so the
      callback may submit follow-on work.

    Admission: NCQ holds at most ``device_slots`` requests (waiting + in
    service). Service: up to ``channels`` admitted requests run concurrently,
    FIFO from the NCQ. GC: when ``ftl.need_gc()`` trips, no new service
    starts; once the channels drain the full episode runs with the device
    (all channels) preempted, exactly once per trip.

    GC coordination (``core/gc_coord.py``): with a ``gc_coord`` attached the
    trigger decision is delegated — ``coord.gate(self)`` may *defer* the
    episode (the device keeps serving under an array-wide GC lease) and
    ``coord.idle_probe(self)`` may start a bounded *idle* reclaim step when a
    kick leaves the device empty. ``gc_coord=None`` (the default) keeps the
    self-triggering path above byte-identical.

    ``server.busy_time`` accumulates channel-seconds (a request of duration
    ``dt`` adds ``dt``; a GC episode adds ``dt * channels``), so utilization
    is ``busy_time / (span * channels)``.

    ``kick()`` is a batch pass: it fills every free NCQ slot from ``pull``
    and starts service on every free channel in one sweep, scheduling each
    completion as a payload event (no per-event closure). ``offer(req)`` is
    the zero-backlog fast path: when the host-side queue is empty a request
    can be admitted (and its service started) directly, skipping the
    ``pull`` indirection entirely.
    """

    __slots__ = ("loop", "server", "pull", "service_time", "on_done",
                 "admitted", "in_service", "in_gc", "_slots", "_channels",
                 "backlog", "gc_coord", "dev_id", "gc_granted")

    def __init__(self, loop: EventLoop, server: Any,
                 pull: Callable[[], Optional[Any]],
                 service_time: Callable[[Any], float],
                 on_done: Callable[[Any], None],
                 backlog: Any = None,
                 gc_coord: Any = None, dev_id: int = 0) -> None:
        self.loop = loop
        self.server = server
        self.pull = pull
        self.service_time = service_time
        self.on_done = on_done
        self.admitted: deque = deque()
        self.in_service = 0
        self.in_gc = False
        self._slots = server.p.device_slots
        self._channels = server.p.channels
        # optional host-side container backing ``pull``: when given and
        # falsy (empty), kick() skips the pull loop without calling it
        self.backlog = backlog
        # optional array-level GC coordinator (core/gc_coord.py); None keeps
        # the self-triggering drain-then-collect path byte-identical
        self.gc_coord = gc_coord
        self.dev_id = dev_id
        self.gc_granted = False      # holds a GC lease (draining toward it)

    @property
    def occupancy(self) -> int:
        """Requests inside the device (NCQ waiting + in service)."""
        return len(self.admitted) + self.in_service

    def set_slot_cap(self, k: int) -> None:
        """Quarantine hook (core/faults.py): cap NCQ admission depth at
        ``k`` (clamped to [1, device_slots]); pass ``device_slots`` to
        restore. Requests already admitted keep draining — only new
        admissions see the cap — so tightening can never strand work.
        Raising the cap re-kicks so a backlogged host queue refills the
        freed slots immediately."""
        old = self._slots
        self._slots = max(1, min(k, self.server.p.device_slots))
        if self._slots > old:
            self.kick()

    def kick(self) -> None:
        """Admit from the host queue and start service / GC episodes."""
        admitted = self.admitted
        in_service = self.in_service
        backlog = self.backlog
        if backlog is None or backlog:
            room = self._slots - len(admitted) - in_service
            if room > 0:
                pull = self.pull
                while room:
                    req = pull()
                    if req is None:
                        break
                    admitted.append(req)
                    room -= 1
        if self.in_gc:
            return
        server = self.server
        coord = self.gc_coord
        if coord is None:
            if server.ftl.need_gc():
                if in_service == 0:
                    self._start_gc()
                return  # drain channels first; completion re-kicks
        elif coord.gate(self):
            return      # granted: draining (or the episode just started)
        if not admitted or in_service >= self._channels:
            if coord is not None and not admitted and in_service == 0 \
                    and not self.in_gc:
                coord.idle_probe(self)
            return
        loop = self.loop
        call_at = loop.call_at
        now = loop.now
        service_time = self.service_time
        complete = self._complete
        channels = self._channels
        while in_service < channels and admitted:
            req = admitted.popleft()
            dt = service_time(req)
            in_service += 1
            server.busy_time += dt
            call_at(now + dt, complete, req)
        self.in_service = in_service

    def offer(self, req: Any) -> bool:
        """Zero-backlog admission fast path: accept ``req`` straight into
        the NCQ, starting service if a channel is free. Returns False when
        the NCQ is full (caller keeps the request host-side). Only valid
        when the host-side queue is empty — otherwise FIFO order would
        break; use ``kick`` there."""
        admitted = self.admitted
        in_service = self.in_service
        if len(admitted) + in_service >= self._slots:
            return False
        admitted.append(req)
        if self.in_gc:
            return True
        server = self.server
        coord = self.gc_coord
        if coord is None:
            if server.ftl.need_gc():
                if in_service == 0:
                    self._start_gc()
                return True
        elif coord.gate(self):
            return True
        channels = self._channels
        if in_service < channels:
            loop = self.loop
            call_at = loop.call_at
            now = loop.now
            service_time = self.service_time
            complete = self._complete
            while in_service < channels and admitted:
                r = admitted.popleft()
                dt = service_time(r)
                in_service += 1
                server.busy_time += dt
                call_at(now + dt, complete, r)
            self.in_service = in_service
        return True

    def _start_gc(self) -> None:
        s = self.server
        dt = s.gc_episode_time()
        self.in_gc = True
        s.in_gc = True
        s.gc_time += dt
        s.busy_time += dt * s.p.channels
        if self.gc_coord is not None:
            self.gc_coord.on_gc_start(self, dt)
        tel = self.loop.telemetry
        if tel is not None:
            tel.note_gc_start(self.dev_id, self.loop.now, dt)
        self.loop.schedule(dt, self._gc_done)

    def _start_idle_gc(self, blocks: int) -> None:
        """Bounded idle-GC step (coordinator-initiated): reclaim up to
        ``blocks`` blocks with the device preempted, like a (short) regular
        episode. Only called by the coordinator's idle probe, i.e. with no
        admitted or in-service requests."""
        s = self.server
        dt = s.gc_idle_time(blocks)
        if dt <= 0.0:
            return
        self.in_gc = True
        s.in_gc = True
        s.gc_time += dt
        s.busy_time += dt * s.p.channels
        self.gc_coord.on_gc_start(self, dt, idle=True)
        tel = self.loop.telemetry
        if tel is not None:
            tel.note_gc_start(self.dev_id, self.loop.now, dt, idle=True)
        self.loop.schedule(dt, self._gc_done)

    def _gc_done(self) -> None:
        self.in_gc = False
        self.server.in_gc = False
        if self.gc_coord is not None:
            self.gc_coord.on_gc_end(self)
        tel = self.loop.telemetry
        if tel is not None:
            tel.note_gc_end(self.dev_id, self.loop.now)
        self.kick()

    def _complete(self, req: Any) -> None:
        self.in_service -= 1
        self.on_done(req)
        self.kick()

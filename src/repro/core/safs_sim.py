"""End-to-end SAFS simulation (paper §3/§4.2): SA-cache + dirty-page flusher +
dual-priority queues in front of the GC-afflicted SSD array of ``gc_sim``.

One event loop (``engine.EventLoop``), three layers:

  app ops --(CPU pool)--> SA-cache --(miss/writeback)--> DualQueue --> DeviceModel
                              |                              ^
                              +---- DirtyPageFlusher --------+   (low priority)

Device service is the shared multi-slot NCQ model (``engine.DeviceModel``):
the DualQueue is the host-side discipline, its ``pop_next`` the admission
source, and up to ``channels`` admitted requests are serviced concurrently,
with GC episodes preempting all channels.

The ``flusher=False`` baseline is the paper's "cached I/O without the dirty
page flusher": identical cache and queues, but dirty pages are written back
only on demand (dirty-victim eviction), on the high-priority queue, with the
application blocked — exactly the configuration Figures 3-5 compare against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from . import policies
from .engine import DeviceModel, EventLoop, MeasurementWindow
from .flusher import DirtyPageFlusher, FlushRequest, StalenessChecker
from .gc_sim import SSDParams, SSDServer
from .io_queues import HIGH, LOW, DualQueue, IORequest
from .workloads import OpSource, _mix64, source_for


# ---------------------------------------------------------------------------
# Numpy SA-cache (paper §3.1) — the CacheView the flusher drives.
# ---------------------------------------------------------------------------

class NumpySACache:
    """Pure-python SA-cache tuned for the DES hot path (sets are 12-wide, so
    python lists beat numpy's per-call overhead by ~10x). Semantics are
    identical to ``policies.py`` — property-tested in tests/test_policies.py.

    Each slot carries a *dirty epoch*, bumped on every ``mark_dirty`` (and on
    every insert): a flush completion may clean the slot only if the epoch it
    captured at issue is still current, otherwise a write that re-dirtied the
    slot after the flush was issued would be silently dropped.
    """

    def __init__(self, num_sets: int, set_size: int = policies.SET_SIZE,
                 n_devices: int = 1, clean_first: bool = True):
        self.num_sets, self.set_size = num_sets, set_size
        self.n_devices = n_devices
        self.clean_first = clean_first
        self.tags = [[-1] * set_size for _ in range(num_sets)]
        self.hits = [[0] * set_size for _ in range(num_sets)]
        self.dirty = [[False] * set_size for _ in range(num_sets)]
        self.epoch = [[0] * set_size for _ in range(num_sets)]
        self.clock = [0] * num_sets
        self._dirty_n = [0] * num_sets
        self.lookups = 0
        self.hit_count = 0

    def set_of(self, tag: int) -> int:
        return _mix64(tag * 2 + 1) % self.num_sets

    # -- basic ops ----------------------------------------------------------
    def lookup(self, tag: int, touch: bool = True):
        s = self.set_of(tag)
        self.lookups += 1
        try:
            slot = self.tags[s].index(tag)
        except ValueError:
            return s, -1
        self.hit_count += 1
        if touch:
            h = self.hits[s][slot]
            if h < 15:
                self.hits[s][slot] = h + 1
        return s, slot

    def _victim(self, s: int):
        """Analytic GClock sweep (clean-first): victim = argmin distance
        score among eligible slots; decrement swept hit counts."""
        tags, hits, dirty = self.tags[s], self.hits[s], self.dirty[s]
        ss, hand = self.set_size, self.clock[s]
        for slot in range(ss):
            if tags[slot] == -1:
                return slot
        eligible = None
        if self.clean_first:
            eligible = [i for i in range(ss) if not dirty[i]]
            if not eligible:
                eligible = None
        idxs = eligible if eligible is not None else range(ss)
        best, best_score, best_dist = -1, 1 << 60, 0
        for i in idxs:
            d = (i - hand) % ss
            sc = hits[i] * ss + d
            if sc < best_score:
                best, best_score, best_dist = i, sc, d
        hv = hits[best]
        for i in idxs:
            d = (i - hand) % ss
            visits = hv + 1 if d < best_dist else hv
            if visits:
                hits[i] = max(hits[i] - visits, 0)
        hits[best] = 0
        self.clock[s] = (best + 1) % ss
        return best

    def insert(self, tag: int, dirty: bool):
        """Returns (set, slot, victim_tag, victim_dirty)."""
        s = self.set_of(tag)
        slot = self._victim(s)
        victim_tag = self.tags[s][slot]
        victim_dirty = victim_tag != -1 and self.dirty[s][slot]
        if victim_dirty:
            self._dirty_n[s] -= 1
        self.tags[s][slot] = tag
        self.hits[s][slot] = 0
        self.dirty[s][slot] = dirty
        # new occupant: any in-flight flush for this slot is now for a dead
        # version, even if the same tag is re-inserted later
        self.epoch[s][slot] += 1
        if dirty:
            self._dirty_n[s] += 1
        return s, slot, victim_tag, victim_dirty

    def mark_dirty(self, s: int, slot: int, value: bool = True):
        if value:
            self.epoch[s][slot] += 1   # every write is a new dirty version
        if self.dirty[s][slot] != value:
            self._dirty_n[s] += 1 if value else -1
            self.dirty[s][slot] = value

    # -- scoring (paper §3.3.1) ----------------------------------------------
    def _flush_scores(self, s: int) -> list[int]:
        tags, hits = self.tags[s], self.hits[s]
        ss, hand = self.set_size, self.clock[s]
        scored = []
        for i in range(ss):
            if tags[i] == -1:
                continue
            scored.append((hits[i] * ss + ((i - hand) % ss), i))
        scored.sort()
        fs = [-1] * ss
        for rank, (_, i) in enumerate(scored):
            fs[i] = ss - 1 - rank
        return fs

    # -- CacheView protocol (flusher) ----------------------------------------
    def dirty_count(self, set_idx: int) -> int:
        return self._dirty_n[set_idx]

    def flush_candidates(self, set_idx: int):
        if not self._dirty_n[set_idx]:
            return []
        fs = self._flush_scores(set_idx)
        dirty, tags = self.dirty[set_idx], self.tags[set_idx]
        out = [(slot, tags[slot], fs[slot]) for slot in range(self.set_size)
               if dirty[slot] and tags[slot] != -1]
        out.sort(key=lambda t: -t[2])
        return out

    def device_of(self, tag: int) -> int:
        return tag % self.n_devices

    def dirty_epoch_of(self, set_idx: int, slot: int) -> int:
        return self.epoch[set_idx][slot]

    def flush_score_of(self, set_idx: int, slot: int) -> int:
        return self._flush_scores(set_idx)[slot]

    @property
    def hit_rate(self) -> float:
        return self.hit_count / max(self.lookups, 1)


# ---------------------------------------------------------------------------
# SAFS workload / results
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SAFSWorkload:
    read_frac: float = 0.0
    dist: str = "uniform"          # "uniform" | "zipf"
    zipf_s: float = 0.99
    unaligned: bool = False        # 128 B writes: read-update-write on miss
    concurrency: int = 576         # in-flight app ops (async: 32 x n_ssds)
    virtual_scale: int = 512
    # -- scenario layer / pattern suite (core/workloads.py) -----------------
    scenario: str = "random"       # any PATTERNS name: "random" |
                                   # "sequential" | "strided" | "snake" |
                                   # "hot_cold" | "write_then_read" |
                                   # "bursty" | "mixed" | "trace"
    seq_streams: int = 4
    burst_on: float = 2e-3
    burst_off: float = 2e-3
    writer_frac: float = 0.5
    stride: int = 64               # LBA step for "strided"
    hot_frac: float = 0.1          # hot-zone share of the LBA space
    hot_ops: float = 0.9           # op share hitting the hot zone
    wtr_span: int = 4096           # extent pages for "write_then_read"
    trace_time_scale: float = 1.0  # seconds-per-trace-second for "trace"


@dataclass
class SAFSResults:
    app_iops: float
    hit_rate: float
    ssd_page_writes: int           # programs actually issued to SSDs
    flush_writes: int
    demand_writes: int             # dirty-victim (application-blocking)
    ssd_reads: int
    stale_discards: int
    app_ops: int
    mean_latency: float
    sim_time: float
    util: np.ndarray
    p50_latency: float = 0.0
    p95_latency: float = 0.0
    p99_latency: float = 0.0
    events: int = 0                # engine events dispatched during run()
    wall_s: float = 0.0            # host wall-clock seconds of run()
    # raw cache-counter deltas behind hit_rate: sharded merges recompute the
    # pooled hit rate from these (never averaging per-shard ratios)
    cache_hits: int = 0
    cache_lookups: int = 0
    # -- per-tenant QoS results (core/qos.py; None when qos is off) ----------
    tenant_stats: "dict | None" = None   # tenant id -> qos.TenantStats
    share_error: float = 0.0
    # -- fault injection results (core/faults.py; None when faults is off) ---
    faults: "dict | None" = None     # whole-run fault/defense counters
                                     # (see faults._new_fault_stats)
    # -- telemetry (core/telemetry.py; None when telemetry is off) -----------
    telemetry: "TelemetryResult | None" = None   # series/spans/budget snapshot
    # -- health monitoring (core/monitor.py; None when monitor is off) -------
    monitor: "MonitorResult | None" = None       # structured alert log


class _Device:
    """DualQueue discipline + shared multi-slot service model for one SSD."""

    def __init__(self, loop: EventLoop, server: SSDServer, queue: DualQueue,
                 service_time, on_done, dev_id: int = 0):
        self.server = server
        self.queue = queue
        self.model = DeviceModel(loop, server, queue.pop_next,
                                 service_time, on_done, dev_id=dev_id)


class SAFSSim:
    def __init__(self, n_ssds: int = 18, ssd: SSDParams = SSDParams(),
                 occupancy: float = 0.8, workload: SAFSWorkload = SAFSWorkload(),
                 cache_frac: float = 0.1, use_flusher: bool = True,
                 clean_first: bool = True, score_threshold: int = 2,
                 t_cpu: float = 10e-6, n_cpu: int = 16, seed: int = 0,
                 reserved_slots: int = policies.RESERVED_SLOTS,
                 source: OpSource | None = None,
                 trace: np.ndarray | None = None,
                 qos: "QosPolicy | None" = None,
                 faults: "FaultPolicy | None" = None,
                 telemetry: "TelemetrySpec | None" = None,
                 monitor: "MonitorSpec | None" = None):
        self.n = n_ssds
        self.p = ssd
        self.wl = workload
        self.rng = np.random.default_rng(seed)
        self.t_cpu, self.n_cpu = t_cpu, n_cpu
        self.use_flusher = use_flusher
        self.loop = EventLoop()
        self.qos = qos

        # fault injection (core/faults.py): one injector for the sim's whole
        # persistent loop (event times are absolute). faults=None keeps every
        # closure below byte-identical to the pre-fault path. layout=None:
        # SAFS has no parity — a Crash is a spare swap (demand I/O continues)
        # with flusher writebacks to the device deferred, never lost.
        self.faults = faults
        if faults is not None:
            from .faults import FaultInjector, validate_fault_policy
            validate_fault_policy(faults, n_ssds, layout=None)
            self._inj = FaultInjector(faults, n_ssds, seed)
        else:
            self._inj = None
        self._media_on = self._inj is not None and self._inj.any_media

        self.telemetry = telemetry
        if telemetry is not None:
            from .telemetry import TelemetrySpec
            if not isinstance(telemetry, TelemetrySpec):
                raise TypeError(f"telemetry must be a core.telemetry."
                                f"TelemetrySpec, got "
                                f"{type(telemetry).__name__}")
        self.monitor = monitor
        if monitor is not None:
            from .monitor import MonitorSpec
            if not isinstance(monitor, MonitorSpec):
                raise TypeError(f"monitor must be a core.monitor."
                                f"MonitorSpec, got "
                                f"{type(monitor).__name__}")
        # per-run collector (run() attaches a fresh one; the persistent loop
        # is detached again at the end of each run)
        self._tel = None
        self._tel_spans = False
        self.last_telemetry = None                    # TelemetryResult
        self._mon = None
        self.last_monitor = None                      # MonitorResult

        if qos is not None:
            # per-tenant HIGH classes at the DualQueue admission point: one
            # scheduler (DRR deficits, token buckets, SLO throttle) shared by
            # every device queue, so fairness is array-wide
            from .qos import QosScheduler, TenantDualQueue
            from .engine import LatencyRecorder
            self.sched = QosScheduler(qos)
            self._trec = {t: LatencyRecorder() for t in qos.ids}
            self._thr_snap = {t: 0.0 for t in qos.ids}
            make_queue = lambda i: TenantDualQueue(
                self.loop, self.sched, max_inflight=ssd.device_slots,
                reserved=reserved_slots,
                on_rate_blocked=self._rate_blocked_for(i))
        else:
            self.sched = None
            self._trec = None
            self._thr_snap = None
            make_queue = lambda i: DualQueue(max_inflight=ssd.device_slots,
                                             reserved=reserved_slots)
        self._rate_wake = [False] * n_ssds

        self.devices = [
            _Device(self.loop, SSDServer(ssd, occupancy, self.rng),
                    make_queue(i),
                    self._service_time_for(i), self._on_done_for(i),
                    dev_id=i)
            for i in range(n_ssds)
        ]
        live_per_ssd = self.devices[0].server.ftl.live_lbas
        self.n_live = live_per_ssd * n_ssds
        cache_pages = int(self.n_live * cache_frac)
        num_sets = max(cache_pages // policies.SET_SIZE, 8)
        self.cache = NumpySACache(num_sets, policies.SET_SIZE, n_ssds, clean_first)
        # Paper cap is 2048 x n_devices, sized for a production cache (hundreds
        # of GB). Scale it with our scaled-down cache so queue residence time
        # stays well below cache residence time (otherwise flushes race their
        # own page's eviction, which the real system never does).
        flush_cap = min(policies.MAX_PENDING_FLUSH_PER_DEV,
                        max(cache_pages // (8 * n_ssds), 64))
        self.flusher = (DirtyPageFlusher(self.cache, n_ssds,
                                         max_pending_per_dev=flush_cap)
                        if use_flusher else None)
        if self._inj is not None:
            inj = self._inj
            if inj.detect:
                # quarantine = NCQ admission cap on the suspect device
                # (engine.DeviceModel.set_slot_cap); release restores and
                # re-kicks so the backlog refills the freed slots
                slots = ssd.device_slots
                q_lo = min(slots, faults.quarantine_qd)
                inj.on_quarantine = \
                    lambda i: self.devices[i].model.set_slot_cap(q_lo)
                inj.on_release = \
                    lambda i: self.devices[i].model.set_slot_cap(slots)
            if inj.crash_event is not None:
                ce = inj.crash_event

                def _crash(_=None):
                    # spare swap: demand I/O keeps flowing; from here on the
                    # flusher defers this device's writebacks (pages stay
                    # dirty) instead of racing the dead member
                    inj.note_crash(ce.device, self.loop.now)
                self.loop.call_at(ce.at_time, _crash)
            if self.flusher is not None and (inj.detect
                                             or inj.crash_event is not None):
                self.flusher.deferrable = \
                    lambda d: inj.crashed[d] or inj.quarantined[d]
        self.checker = StalenessChecker(
            is_evicted=lambda r: int(self.cache.tags[r.set_idx][r.slot]) != r.tag,
            is_clean=lambda r: not bool(self.cache.dirty[r.set_idx][r.slot]),
            current_score=lambda r: self.cache.flush_score_of(r.set_idx, r.slot),
            score_threshold=score_threshold,
        )
        self.source = source or source_for(workload, self.n_live, self.rng,
                                           trace=trace)

        # counters
        self.flush_writes = 0
        self.demand_writes = 0
        self.ssd_reads = 0
        self._cpu_free = [0.0] * n_cpu
        self._mw: MeasurementWindow | None = None
        self._base = dict(wr=0, rd=0, fl=0, dm=0, st=0, hits=0, lk=0)
        self._spawned = False        # concurrency ops seeded once per sim
        self.last_latency: np.ndarray | None = None   # raw samples of the
                                                      # last run() (sharding)

    @property
    def now(self) -> float:
        return self.loop.now

    @property
    def app_completed(self) -> int:
        return self._mw.completed if self._mw else 0

    # -- device plumbing -----------------------------------------------------
    def _rate_blocked_for(self, dev_i: int):
        """Wake callback for a QoS queue whose waiting HIGH classes are all
        rate-blocked: kick the device again at the earliest token release
        (guarded so at most one wake is pending per device)."""
        def on_blocked(t_release: float) -> None:
            if self._rate_wake[dev_i]:
                return
            self._rate_wake[dev_i] = True

            def fire(_=None):
                self._rate_wake[dev_i] = False
                self.devices[dev_i].model.kick()
            self.loop.call_at(t_release, fire)
        return on_blocked

    def _service_time_for(self, dev_i: int):
        def service_time(req: IORequest) -> float:
            s = self.devices[dev_i].server
            payload = req.payload
            if payload["op"] == "write":
                return self.p.t_coalesce if payload.get("coal") \
                    else s.service_time(False)
            return s.service_time(True)
        inj = self._inj
        if inj is not None and (inj.detect or inj.has_slow(dev_i)):
            return inj.wrap_service_time(dev_i, service_time, self.loop)
        return service_time

    def _reissue(self, args) -> None:
        """Media-error retry landing after its backoff: re-submit the same
        read request (its attempt counter rides in the payload)."""
        dev_i, req = args
        self._submit(dev_i, req)

    def _on_done_for(self, dev_i: int):
        def on_done(req: IORequest) -> None:
            d = self.devices[dev_i]
            s = d.server
            payload = req.payload
            if payload["op"] == "write":
                lba = payload["lba"]
                c = s.pending_writes[lba] - 1
                if c:
                    s.pending_writes[lba] = c
                else:
                    del s.pending_writes[lba]
                if not payload.get("coal"):
                    s.ftl.user_write(lba)
                s.served_writes += 1
            else:
                if self._media_on and self._inj.read_fails(dev_i):
                    inj = self._inj
                    now = self.loop.now
                    att = payload.get("att", 0)
                    retry, delay = inj.retry_decision(
                        att, payload.get("t_iss", now), now)
                    if retry:
                        payload["att"] = att + 1
                        # release the device slot without firing on_complete
                        # (the op is still logically in flight), then
                        # re-submit after the backoff
                        cb, req.on_complete = req.on_complete, None
                        d.queue.complete(req)
                        req.on_complete = cb
                        self.loop.call_at(now + delay, self._reissue,
                                          (dev_i, req))
                        d.model.kick()
                        return
                    # exhausted/timed out: complete as a failed read (EIO
                    # surfaced to the app; the op must not wedge)
                s.served_reads += 1
                self.ssd_reads += 1
            d.queue.complete(req)
        return on_done

    def _submit(self, dev_i: int, req: IORequest) -> None:
        d = self.devices[dev_i]
        payload = req.payload
        if payload["op"] == "write":
            lba = payload["lba"]
            s = d.server
            payload["coal"] = s.pending_writes.get(lba, 0) > 0
            s.pending_writes[lba] = s.pending_writes.get(lba, 0) + 1
        elif self._media_on and "t_iss" not in payload:
            payload["t_iss"] = self.loop.now   # retry-timeout anchor
        d.queue.submit(req)
        d.model.kick()

    # -- event helpers ----------------------------------------------------------
    def _schedule_cpu(self, handler, payload) -> None:
        """Queue ``handler(payload)`` behind the least-loaded CPU (payload
        record — no per-op closure)."""
        cpu_free = self._cpu_free
        i = cpu_free.index(min(cpu_free))
        now = self.loop.now
        start = now if now > cpu_free[i] else cpu_free[i]
        done = start + self.t_cpu
        cpu_free[i] = done
        self.loop.call_at(done, handler, payload)

    # -- cache/flusher plumbing ---------------------------------------------
    def _pump_flusher(self, budget: int = 8) -> None:
        if not self.flusher:
            return
        for fr in self.flusher.make_requests(budget, max_visits=8):
            dev = fr.device
            req = IORequest(
                payload={"op": "write", "lba": fr.tag // self.n, "flush": fr},
                priority=LOW,
                is_stale=lambda p, fr=fr: self.checker(fr),
                on_complete=lambda p, fr=fr: self._on_flush_complete(fr),
                on_discard=lambda p, fr=fr: self.flusher.note_flush_discarded(fr),
            )
            self._submit(dev, req)

    def _on_flush_complete(self, fr: FlushRequest) -> None:
        self.flush_writes += 1
        c = self.cache
        # Clean only if the slot still holds the same tag AND no write
        # re-dirtied it since the flush was issued (dirty-epoch match) —
        # otherwise the newer version would be silently dropped.
        if (int(c.tags[fr.set_idx][fr.slot]) == fr.tag
                and c.epoch[fr.set_idx][fr.slot] == fr.dirty_epoch):
            c.mark_dirty(fr.set_idx, fr.slot, False)
        self.flusher.note_flush_done(fr)
        self._pump_flusher(budget=2)

    def _note_write(self, set_idx: int) -> None:
        if self.flusher:
            self.flusher.note_write(set_idx)
            if not self.flusher.saturated():
                self._pump_flusher(budget=4)

    # -- app op state machine ---------------------------------------------------
    def _begin_measure(self) -> None:
        self._base = dict(
            wr=sum(d.server.ftl.writes for d in self.devices),
            rd=self.ssd_reads,
            fl=self.flush_writes,
            dm=self.demand_writes,
            st=sum(d.queue.stats.discarded_stale for d in self.devices),
            hits=self.cache.hit_count,
            lk=self.cache.lookups,
        )
        for d in self.devices:
            d.server.busy_time = 0.0
            d.server.gc_time = 0.0
        if self._trec is not None:
            now = self.loop.now
            for t, r in self._trec.items():
                r.reset()
                self._thr_snap[t] = self.sched.throttle_time(t, now)
        if self._mon is not None:
            self._mon.begin_measure(self.loop.now)

    def _complete_op(self, t_start: float, tenant: int = 0) -> bool:
        measured = self._mw.note_completion(t_start)
        if self.sched is not None:
            now = self.loop.now
            self.sched.note_completion(tenant, now - t_start, now)
            if self._mon is not None:
                self._mon.note_completion(tenant, now - t_start, now)
            if measured:
                rec = self._trec.get(tenant)
                if rec is not None:
                    rec.record(now - t_start)
        self._spawn_op()
        return measured

    def _spawn_op(self) -> None:
        op = self.source.next_op(self.loop.now)
        if op.at > self.loop.now:
            self.loop.call_at(op.at, self._admit_deferred,
                              (op.lba, op.is_read, op.tenant))
        else:
            self._schedule_cpu(self._process_op,
                               (op.lba, op.is_read, self.loop.now, op.tenant))

    def _admit_deferred(self, args) -> None:
        tag, is_read, tenant = args
        self._schedule_cpu(self._process_op,
                           (tag, is_read, self.loop.now, tenant))

    def _process_op(self, args) -> None:
        tag, is_read, t0, tenant = args
        tel = self._tel if self._tel_spans else None
        kind = 0 if is_read else 1
        s, slot = self.cache.lookup(tag)
        if slot >= 0:
            if not is_read:
                already = self.cache.dirty[s][slot]
                self.cache.mark_dirty(s, slot)
                if not already:
                    self._note_write(s)
            m = self._complete_op(t0, tenant)
            if tel is not None:
                # hit path: the whole latency is CPU-stage queueing+service
                now = self.loop.now
                tel.record_span(t0, tenant, -1, 0, kind, now,
                                (now - t0, 0.0, 0.0, 0.0, 0.0), m)
            return
        # miss: allocate a frame (clean-first GClock)
        needs_fill = is_read or self.wl.unaligned
        s, slot, victim_tag, victim_dirty = self.cache.insert(tag, dirty=not needs_fill and not is_read)
        dev = tag % self.n
        # span stage tracker: [prev stage end, writeback, fill, gc, gc snap];
        # read-only probes of sim state — never touches event scheduling
        if tel is not None:
            t_proc = self.loop.now
            st = [t_proc, 0.0, 0.0, 0.0, 0.0]
        else:
            st = None

        def close_span(measured):
            now = self.loop.now
            lat = now - t0
            cpu = t_proc - t0
            other = lat - cpu - st[1] - st[2] - st[3]
            tel.record_span(t0, tenant, dev, 1, kind, now,
                            (cpu, st[1], st[2], st[3], other), measured)

        def after_fill(_=None):
            if st is not None:
                # fill stage ends now; carve its GC overlap out of the stage
                now = self.loop.now
                fl = now - st[0]
                g = tel.gc_cum(dev, now) - st[4]
                g = 0.0 if g < 0.0 else (fl if g > fl else g)
                st[2] = fl - g
                st[3] += g
                st[0] = now
            if not is_read:
                self.cache.mark_dirty(s, slot)
                self._note_write(s)
            m = self._complete_op(t0, tenant)
            if st is not None:
                close_span(m)

        def do_fill(_=None):
            if st is not None and victim_dirty:
                # writeback stage (this call is its completion) ends now
                now = self.loop.now
                wb = now - st[0]
                g = tel.gc_cum(vdev, now) - st[4]
                g = 0.0 if g < 0.0 else (wb if g > wb else g)
                st[1] = wb - g
                st[3] += g
                st[0] = now
            if needs_fill:
                if st is not None:
                    st[4] = tel.gc_cum(dev, self.loop.now)
                self._submit(dev, IORequest(
                    payload={"op": "read", "lba": tag // self.n},
                    priority=HIGH, on_complete=after_fill, tenant=tenant))
            else:
                if not is_read:
                    self._note_write(s)
                m = self._complete_op(t0, tenant)
                if st is not None:
                    close_span(m)

        if victim_dirty:
            # demand writeback: the application op blocks on it (paper §3.3),
            # so it is classed by the tenant whose op triggered the eviction
            self.demand_writes += 1
            vdev = victim_tag % self.n
            if st is not None:
                st[4] = tel.gc_cum(vdev, self.loop.now)
            self._submit(vdev, IORequest(
                payload={"op": "write", "lba": victim_tag // self.n},
                priority=HIGH, on_complete=do_fill, tenant=tenant))
        else:
            do_fill()

    # -- main loop -------------------------------------------------------------
    def run(self, measure_ops: int, warmup_ops: int | None = None) -> SAFSResults:
        if warmup_ops is None:
            warmup_ops = measure_ops // 2
        total = warmup_ops + measure_ops
        self._mw = mw = MeasurementWindow(self.loop, warmup_ops,
                                          self._begin_measure, target=total)
        # fresh per-run collector on the persistent loop (detached below so
        # spans from ops straddling a run boundary drop into the void)
        tel = None
        if self.telemetry is not None:
            from .telemetry import SAFS_COMPONENTS, Telemetry
            tel = Telemetry(self.telemetry, self.n,
                            components=SAFS_COMPONENTS).attach(self.loop)
            tel.register_safs_probes(self.devices, self.cache)
        self._tel = tel
        self._tel_spans = tel is not None and tel.spans_on
        mon = None
        if self.monitor is not None:
            from .monitor import HealthMonitor
            mon = HealthMonitor(self.monitor, self.n).attach(self.loop, tel)
            mon.register_safs_sources(self.devices, self.cache,
                                      self.p.device_slots, inj=self._inj,
                                      sched=self.sched)
        self._mon = mon
        # Seed the closed-loop concurrency exactly once per sim: the spawn
        # chain is self-sustaining (every completion respawns), so a later
        # run() — a new phase — resumes the in-flight population instead of
        # doubling it. First-run behaviour is unchanged (goldens).
        # total == 0 (an empty-trace shard) must be a no-op: leave
        # _spawned False so a later real run still seeds the population.
        if not self._spawned and total > 0:
            self._spawned = True
            for _ in range(self.wl.concurrency):
                self._spawn_op()
        t_wall = time.perf_counter()
        # total == 0: nothing to measure (matches the old run_while exit)
        events = self.loop.run() if total > 0 else 0
        wall_s = time.perf_counter() - t_wall
        span = mw.span
        b = self._base
        summ = mw.latency.summary()
        self.last_latency = mw.latency.values()
        if tel is not None:
            tel.finalize(self.loop.now, mw.t0)
            self.loop.telemetry = None   # the loop outlives the run
        self.last_telemetry = tel.result() if tel is not None else None
        if mon is not None:
            mon.finalize(self.loop.now)
            if self.loop.telemetry is mon:   # self-hooked (no telemetry)
                self.loop.telemetry = None
            self._mon = None
        self.last_monitor = mon.result() if mon is not None else None
        tstats, share_error = None, 0.0
        if self.qos is not None:
            from .qos import build_tenant_stats
            now = self.loop.now
            throttle_times = {t: self.sched.throttle_time(t, now)
                              - self._thr_snap[t] for t in self.qos.ids}
            tstats, share_error = build_tenant_stats(
                self.qos, self._trec, span, throttle_times)
        util = np.array([d.server.busy_time / (span * self.p.channels)
                         for d in self.devices])
        if tel is not None and tel.has_series("busy_time"):
            # derived from the telemetry busy-time probe's final sample —
            # bit-identical to the legacy per-device arithmetic
            util = tel.util_final(span, self.p.channels)
        fblock = None
        if self._inj is not None:
            if self.flusher is not None:
                self._inj.stats["flush_deferred"] = self.flusher.deferred
            fblock = self._inj.finalize(self.loop.now)
        return SAFSResults(
            app_iops=summ.n / span,
            hit_rate=(self.cache.hit_count - b["hits"]) /
                     max(self.cache.lookups - b["lk"], 1),
            ssd_page_writes=sum(d.server.ftl.writes for d in self.devices) - b["wr"],
            flush_writes=self.flush_writes - b["fl"],
            demand_writes=self.demand_writes - b["dm"],
            ssd_reads=self.ssd_reads - b["rd"],
            stale_discards=sum(d.queue.stats.discarded_stale
                               for d in self.devices) - b["st"],
            app_ops=summ.n,
            mean_latency=summ.mean,
            sim_time=span,
            util=util,
            p50_latency=summ.p50,
            p95_latency=summ.p95,
            p99_latency=summ.p99,
            events=events,
            wall_s=wall_s,
            cache_hits=self.cache.hit_count - b["hits"],
            cache_lookups=self.cache.lookups - b["lk"],
            tenant_stats=tstats,
            share_error=share_error,
            faults=fblock,
            telemetry=self.last_telemetry,
            monitor=self.last_monitor,
        )

    def run_phased(self, phases) -> "list[tuple[str, SAFSResults]]":
        """Drive a phased scenario: one ``run()`` (one measurement window)
        per :class:`~repro.core.workloads.Phase`, swapping the op source at
        each boundary. Cache, flusher, FTL, and in-flight op state persist
        across phases — that is the point: a preconditioning phase leaves
        the system warm for the phases after it (no ad-hoc prefill flags).

        Ops in flight at a boundary were drawn from the previous phase's
        source (the closed-loop overshoot); each phase's ``warmup`` budget
        absorbs them before its measurement window opens. Returns
        ``(phase.name, results)`` for every phase with ``measure=True``;
        unmeasured phases still run their full budget."""
        out = []
        for ph in phases:
            self.source = ph.source
            res = self.run(ph.ops, ph.warmup)
            if ph.measure:
                out.append((ph.name, res))
        return out

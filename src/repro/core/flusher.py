"""Dirty-page flusher (paper §3.3): trigger / FIFO round-robin / per-visit budget.

The flusher is deliberately split from any cache implementation: it talks to a
``CacheView`` protocol so the same policy object drives (a) the numpy SA-cache
in the SAFS simulator, (b) the dirty-chunk tracker of the async checkpointer,
and (c) the JAX paged-KV pool (via host-side mirrors of the device state).

Paper parameters: page sets of 12, trigger at 6 dirty pages, 1-2 flushes per
set visit, a FIFO of triggered sets visited round-robin, and a global cap of
2048 pending flush requests per device.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Protocol

from .policies import FLUSHES_PER_VISIT, FLUSH_TRIGGER, MAX_PENDING_FLUSH_PER_DEV


class CacheView(Protocol):
    """What the flusher needs to know about a cache."""

    def dirty_count(self, set_idx: int) -> int: ...

    def flush_candidates(self, set_idx: int) -> list[tuple[int, int, int]]:
        """Dirty (slot, tag, flush_score) triples, highest score first."""

    def device_of(self, tag: int) -> int:
        """Which device the page belongs to (for per-device pending caps)."""

    # Optional: caches that version their dirty bits also expose
    #   dirty_epoch_of(set_idx, slot) -> int
    # (see NumpySACache); the flusher stamps it into FlushRequest.dirty_epoch.


@dataclass(frozen=True)
class FlushRequest:
    """A queued low-priority writeback. ``score_at_issue`` is recorded so the
    staleness check (§3.3.2 rule iii) can compare against the *current* score.
    ``dirty_epoch`` captures the slot's dirty version at issue time: the
    completion may clean the slot only while the epoch is unchanged, so a
    write that re-dirties the slot after the flush was issued is never lost."""

    tag: int
    set_idx: int
    slot: int
    device: int
    score_at_issue: int
    dirty_epoch: int = 0


@dataclass
class DirtyPageFlusher:
    cache: CacheView
    n_devices: int
    trigger: int = FLUSH_TRIGGER
    per_visit: int = FLUSHES_PER_VISIT
    max_pending_per_dev: int = MAX_PENDING_FLUSH_PER_DEV
    # FIFO of set indices that crossed the trigger (paper: "placed in a FIFO
    # queue ... checks the page sets in the queue in a round-robin manner").
    _fifo: deque = field(default_factory=deque)
    _queued_sets: set = field(default_factory=set)
    _pending_per_dev: dict = field(default_factory=dict)
    # pages already in flight so we never double-flush the same (set, slot, tag)
    _inflight: set = field(default_factory=set)
    _total_pending: int = 0
    issued: int = 0
    # Optional fault hook (core/faults.py): ``deferrable(device) -> True``
    # defers that device's writebacks — the pages simply STAY DIRTY and their
    # sets stay queued for a later pump, so a crashed or quarantined member's
    # writebacks are delayed, never lost. ``deferred`` counts the skips.
    deferrable: "Callable[[int], bool] | None" = None
    deferred: int = 0
    # IOExecutor workers call note_flush_done/discarded concurrently (one
    # thread pool per device); the counters are read-modify-write. Reentrant:
    # note_flush_discarded delegates to note_flush_done. Uncontended in the
    # single-threaded simulators.
    _mu: threading.RLock = field(default_factory=threading.RLock)

    def saturated(self, frac: float = 0.95) -> bool:
        """Cheap gate: skip pumping when the global pending pool is ~full."""
        return self._total_pending >= frac * self.n_devices * self.max_pending_per_dev

    # -- cache-side notifications ------------------------------------------
    def note_write(self, set_idx: int) -> None:
        """Called after a page in ``set_idx`` becomes dirty."""
        with self._mu:
            if set_idx not in self._queued_sets and self.cache.dirty_count(set_idx) > self.trigger:
                self._queued_sets.add(set_idx)
                self._fifo.append(set_idx)

    # -- executor-side notifications ---------------------------------------
    def note_flush_done(self, req: FlushRequest) -> None:
        with self._mu:
            self._pending_per_dev[req.device] = self._pending_per_dev.get(req.device, 0) - 1
            self._total_pending -= 1
            self._inflight.discard((req.set_idx, req.slot, req.tag))

    def note_flush_discarded(self, req: FlushRequest) -> None:
        self.note_flush_done(req)

    def pending(self, device: int | None = None) -> int:
        with self._mu:
            if device is not None:
                return self._pending_per_dev.get(device, 0)
            return sum(self._pending_per_dev.values())

    # -- request generation --------------------------------------------------
    def make_requests(self, budget: int | None = None,
                      max_visits: int | None = None) -> list[FlushRequest]:
        """Round-robin over triggered sets, ``per_visit`` pages per visit,
        until queues drain or per-device pending caps are hit.

        ``max_visits`` bounds work per call: when device caps are saturated a
        full FIFO walk would be O(#sets) for nothing — visited sets keep their
        FIFO position and are retried on the next pump instead.
        """
        with self._mu:
            return self._make_requests_locked(budget, max_visits)

    def _make_requests_locked(self, budget, max_visits) -> list[FlushRequest]:
        out: list[FlushRequest] = []
        stalled: list[int] = []  # sets skipped only due to device caps
        epoch_of = getattr(self.cache, "dirty_epoch_of", None)
        if budget is None:
            budget = 1 << 30
        if max_visits is None:
            max_visits = max(32, 4 * budget)
        rounds = 0
        while self._fifo and len(out) < budget:
            rounds += 1
            if rounds > max_visits:
                break  # bounded pump; remaining sets stay queued
            set_idx = self._fifo.popleft()
            cands = [
                (slot, tag, score)
                for slot, tag, score in self.cache.flush_candidates(set_idx)
                if (set_idx, slot, tag) not in self._inflight
            ]
            if not cands:
                self._queued_sets.discard(set_idx)
                continue
            took = 0
            capped = False
            for slot, tag, score in cands:
                if took >= self.per_visit or len(out) >= budget:
                    break
                dev = self.cache.device_of(tag)
                if self.deferrable is not None and self.deferrable(dev):
                    # crashed/quarantined device: leave the page dirty and
                    # the set queued (same retry path as a full device cap)
                    self.deferred += 1
                    capped = True
                    continue
                if self._pending_per_dev.get(dev, 0) >= self.max_pending_per_dev:
                    capped = True
                    continue
                self._pending_per_dev[dev] = self._pending_per_dev.get(dev, 0) + 1
                self._total_pending += 1
                self._inflight.add((set_idx, slot, tag))
                out.append(FlushRequest(
                    tag=tag, set_idx=set_idx, slot=slot, device=dev,
                    score_at_issue=score,
                    dirty_epoch=epoch_of(set_idx, slot) if epoch_of else 0))
                took += 1
            if len(cands) > took:
                # still has flushable pages: keep in FIFO (re-append = round robin)
                if capped and took == 0:
                    stalled.append(set_idx)
                else:
                    self._fifo.append(set_idx)
            else:
                self._queued_sets.discard(set_idx)
        for s in stalled:  # retry capped sets on the next call
            self._fifo.append(s)
        self.issued += len(out)
        return out


@dataclass
class StalenessChecker:
    """Paper §3.3.2 — evaluated at the moment a flush request reaches the head
    of the low-priority queue, NOT at enqueue time."""

    is_evicted: Callable[[FlushRequest], bool]
    is_clean: Callable[[FlushRequest], bool]
    current_score: Callable[[FlushRequest], int]
    score_threshold: int = 0

    def __call__(self, req: FlushRequest) -> bool:
        if self.is_evicted(req):
            return True
        if self.is_clean(req):
            return True
        return self.current_score(req) < self.score_threshold

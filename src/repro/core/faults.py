"""Deterministic fault injection + host-side resilience (ISSUE 7).

The paper's premise is that GC makes members *intermittently* slow; real
arrays additionally face *persistently* slow members (fail-slow), transient
media errors, and outright device deaths. This module injects those faults
deterministically and carries the host-side defenses:

Injection (frozen, picklable :class:`FaultPolicy` spec, pattern-matching
``GcPolicy``/``QosPolicy``):

* :class:`FailSlow` — scales one device's service times by ``slow_factor``
  for the episode ``[onset, onset + duration)``. Pure time-interval check,
  consumes no RNG.
* :class:`MediaError` — individual reads fail with probability ``read_ber``,
  drawn from a dedicated decorrelated RNG stream (the workload RNG is never
  touched, so the op sequence matches the fault-free run).
* :class:`Crash` — kills a member mid-run: its RAID-5 group flips into the
  degraded/reconstruction path dynamically and the rebuild tenant spawns at
  crash time (subsuming the static ``Raid5Layout(degraded=1)`` path). The
  crash is modeled as an instant spare swap: in-flight and already-queued
  requests drain to the spare; only *new* planning treats the group as
  degraded until the rebuild completes and heals it.

Defense:

* :class:`RetryPolicy` — bounded host retries for failed reads with
  exponential sim-time backoff and a per-op timeout budget (give up early
  when the op has already spent its budget).
* Hedged reads (``FaultPolicy.hedge_after``) — a single-member striped read
  that has not completed after the deadline speculatively issues sibling
  reconstruction (the PR 5 ``_plan_read_steered`` machinery); the first leg
  to finish completes the logical op, the loser is discarded by an epoch
  check mirroring the flush lost-write fix. Hedges never fire on a degraded
  group — reconstruction is already the primary path and there is no
  redundancy left to hedge with.
* Fail-slow detector (``FaultPolicy.detect``) — peer-relative EWMA of
  per-device service occupancy vs. the array median; suspects are
  *quarantined*: admission depth capped at ``quarantine_qd`` through the
  existing ``steer_qd`` plumbing and (RAID-5) reads steered away via the
  planner's avoid list. Detection latency and false positives are telemetry.
  The detector observes per-op service occupancy — the completion-latency
  component the device itself controls — so GC pauses and queue waits do
  not trigger false quarantines; it consumes no RNG.

``faults=None`` keeps every simulator byte-identical to the pre-fault path
(goldens pinned); fault devices are remapped per shard (`slice_policy`) so
serial == sharded stays bit-identical.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

from .metrics import Ewma, peer_median
from .workloads import _mix64

_MASK = (1 << 64) - 1
# splitmix64 salt decorrelating the media-error stream from the workload
# stream (which uses the raw seed) and the per-tenant streams (qos.py)
_MEDIA_SALT = 0x5FA117B0_5EED_C0DE & _MASK


# ---------------------------------------------------------------------------
# Fault event + policy specs (frozen, hashable, picklable)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FailSlow:
    """Device ``device`` serves every request ``slow_factor`` x slower during
    ``[onset, onset + duration)`` (sim seconds from run start)."""

    device: int
    onset: float = 0.0
    duration: float = math.inf
    slow_factor: float = 4.0


@dataclass(frozen=True)
class MediaError:
    """Reads fail with probability ``read_ber`` (per completed read, from a
    dedicated RNG stream). ``device=-1`` applies to every device."""

    read_ber: float = 1e-4
    device: int = -1


@dataclass(frozen=True)
class Crash:
    """Device ``device`` dies at ``at_time`` (sim seconds from run start).

    RAID-5 only: the member's group plans degraded from the crash on and the
    rebuild tenant starts immediately; the group heals when every row has
    been rebuilt onto the spare. ``SAFSSim`` models the spare swap without
    redundancy: service continues, but background flusher writebacks to the
    device are deferred (pages stay dirty) — see benchmarks/README.md."""

    device: int
    at_time: float


@dataclass(frozen=True)
class RetryPolicy:
    """Host-side read-retry discipline for media errors: up to
    ``max_retries`` re-issues, the k-th after ``backoff * backoff_mult**k``
    seconds; ``timeout > 0`` additionally abandons the retry loop once the
    op's total elapsed time (including the pending backoff) would exceed
    it."""

    max_retries: int = 3
    backoff: float = 100e-6
    backoff_mult: float = 2.0
    timeout: float = 0.0


@dataclass(frozen=True)
class FaultPolicy:
    """Fault schedule + defense knobs for one run. Frozen and picklable:
    safe to ship to sharded worker processes (see :func:`slice_policy`)."""

    events: tuple = ()
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    hedge_after: float = 0.0         # > 0: hedge single-member striped reads
                                     # that are still in flight after this
                                     # many seconds (RAID-5 only)
    detect: bool = False             # peer-relative fail-slow detector
    detect_alpha: float = 0.125      # EWMA smoothing of per-op service time
    detect_ratio: float = 3.0        # quarantine when ewma > ratio * median
    detect_release: float = 1.5      # release when ewma < release * median
    detect_min_samples: int = 64     # per-device samples before judging
    detect_every: int = 64           # run the sweep every N service starts
    quarantine_qd: int = 2           # admission cap while quarantined


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def validate_fault_policy(policy: FaultPolicy, n_ssds: int,
                          layout=None) -> None:
    """Reject conflicting/out-of-range fault knobs with errors that name
    them. ``layout=None`` means the SAFS array (no layout semantics: crashes
    are modeled as a spare swap with flusher deferral, so they are allowed
    without parity)."""
    if not isinstance(policy, FaultPolicy):
        raise TypeError(f"faults must be a core.faults.FaultPolicy, "
                        f"got {type(policy).__name__}")
    r = policy.retry
    _check(r.max_retries >= 0, f"RetryPolicy.max_retries={r.max_retries} "
           f"must be >= 0")
    _check(r.backoff > 0.0, f"RetryPolicy.backoff={r.backoff} must be > 0")
    _check(r.backoff_mult >= 1.0, f"RetryPolicy.backoff_mult="
           f"{r.backoff_mult} must be >= 1")
    _check(r.timeout >= 0.0, f"RetryPolicy.timeout={r.timeout} must be >= 0")
    _check(policy.hedge_after >= 0.0, f"FaultPolicy.hedge_after="
           f"{policy.hedge_after} must be >= 0")
    _check(policy.quarantine_qd >= 1, f"FaultPolicy.quarantine_qd="
           f"{policy.quarantine_qd} must be >= 1")
    _check(0.0 < policy.detect_alpha <= 1.0, f"FaultPolicy.detect_alpha="
           f"{policy.detect_alpha} must be in (0, 1]")
    _check(policy.detect_release < policy.detect_ratio,
           f"FaultPolicy.detect_release={policy.detect_release} must be < "
           f"detect_ratio={policy.detect_ratio} (hysteresis)")
    crashes = []
    for e in policy.events:
        if isinstance(e, FailSlow):
            _check(0 <= e.device < n_ssds,
                   f"FailSlow.device={e.device} out of range for "
                   f"n_ssds={n_ssds}")
            _check(e.slow_factor >= 1.0, f"FailSlow.slow_factor="
                   f"{e.slow_factor} must be >= 1 (a speedup is not a "
                   f"fault)")
            _check(e.onset >= 0.0 and e.duration > 0.0,
                   f"FailSlow(onset={e.onset}, duration={e.duration}) "
                   f"needs onset >= 0 and duration > 0")
        elif isinstance(e, MediaError):
            _check(e.device == -1 or 0 <= e.device < n_ssds,
                   f"MediaError.device={e.device} out of range for "
                   f"n_ssds={n_ssds} (use -1 for all devices)")
            _check(0.0 <= e.read_ber < 1.0, f"MediaError.read_ber="
                   f"{e.read_ber} must be in [0, 1)")
        elif isinstance(e, Crash):
            _check(0 <= e.device < n_ssds,
                   f"Crash.device={e.device} out of range for "
                   f"n_ssds={n_ssds}")
            _check(e.at_time >= 0.0,
                   f"Crash.at_time={e.at_time} must be >= 0")
            crashes.append(e)
        else:
            raise TypeError(f"unknown fault event {type(e).__name__} "
                            f"(expected FailSlow/MediaError/Crash)")
    if crashes and layout is not None:
        if not layout.parity:
            raise ValueError(
                f"Crash(device={crashes[0].device}) on a "
                f"{layout.name!r} layout: no parity means no spare "
                f"semantics (layout.rebuild cannot reconstruct the member) "
                f"— a crashed member is data loss. Drop the Crash event or "
                f"use Raid5Layout.")
        if getattr(layout, "degraded", 0):
            raise ValueError(
                f"Crash(device={crashes[0].device}) combined with "
                f"Raid5Layout(degraded={layout.degraded}): degraded=1 "
                f"already fails a member of every group, so the crash is a "
                f"second failure in its group — beyond single parity. Drop "
                f"degraded= (the Crash subsumes it) or drop the Crash.")
    if len(crashes) > 1:
        raise ValueError(
            f"{len(crashes)} Crash events in one FaultPolicy: correlated "
            f"failures exceed single parity and are not modeled (ROADMAP "
            f"follow-on) — keep at most one Crash per run.")


def slice_policy(policy: FaultPolicy, lo: int, hi: int) -> FaultPolicy:
    """Per-shard rewrite for the sharded runner: keep the events whose
    device falls in ``[lo, hi)``, remapped to shard-local indices.
    Device-less events (``MediaError(device=-1)``) ship to every shard —
    each shard's injector draws from its own decorrelated stream (seeded
    from the shard seed), exactly as the serial decomposition does, so
    serial == sharded stays bit-identical."""
    evs = []
    for e in policy.events:
        d = getattr(e, "device", -1)
        if d < 0:
            evs.append(e)
        elif lo <= d < hi:
            evs.append(replace(e, device=d - lo))
    return replace(policy, events=tuple(evs))


# ---------------------------------------------------------------------------
# Per-run injector runtime
# ---------------------------------------------------------------------------

def _new_fault_stats() -> dict:
    return {
        "media_errors": 0,        # injected read failures
        "retries": 0,             # host re-issues scheduled
        "retry_exhausted": 0,     # reads abandoned at the retry bound
        "timeouts": 0,            # retry loops abandoned on the op timeout
        "max_attempts": 0,        # deepest retry chain observed
        "hedged_reads": 0,        # hedge legs issued
        "hedge_wins": 0,          # hedges that beat the primary leg
        "fail_slow_episodes": 0,  # FailSlow episodes that began in-run
        "crashes": 0,
        "crash_at": -1.0,         # sim time of the crash (-1: none)
        "rebuild_completed_at": -1.0,
        "data_at_risk_s": -1.0,   # crash -> rebuild complete (redundancy gap)
        "quarantines": 0,         # quarantine entries (incl. false positives)
        "false_quarantines": 0,   # device was healthy when quarantined
        "quarantine_time_s": 0.0,  # total device-seconds under quarantine
        "detect_latency_s": -1.0,  # first true positive: onset -> quarantine
        "flush_deferred": 0,      # SAFS writebacks deferred (re-dirtied)
    }


def merge_fault_stats(blocks) -> "dict | None":
    """Sharded merge of per-shard ``faults`` blocks: counters add, time
    accumulators add, first-occurrence sentinels take the defined value
    (at most one shard holds the crash; detection latency is the earliest
    detection across shards)."""
    blocks = [b for b in blocks if b is not None]
    if not blocks:
        return None
    out = _new_fault_stats()
    for b in blocks:
        for k in ("media_errors", "retries", "retry_exhausted", "timeouts",
                  "hedged_reads", "hedge_wins", "fail_slow_episodes",
                  "crashes", "quarantines", "false_quarantines",
                  "flush_deferred"):
            out[k] += b[k]
        out["max_attempts"] = max(out["max_attempts"], b["max_attempts"])
        out["quarantine_time_s"] += b["quarantine_time_s"]
        for k in ("crash_at", "rebuild_completed_at", "data_at_risk_s"):
            if b[k] >= 0.0:
                out[k] = b[k]
        if b["detect_latency_s"] >= 0.0:
            if out["detect_latency_s"] < 0.0:
                out["detect_latency_s"] = b["detect_latency_s"]
            else:
                out["detect_latency_s"] = min(out["detect_latency_s"],
                                              b["detect_latency_s"])
    return out


class FaultInjector:
    """Mutable per-run runtime for one :class:`FaultPolicy`.

    Owns the fault schedule, the dedicated media-error RNG stream, the
    detector/quarantine state, and the ``faults`` stats block. The run
    loops bind it per run (:meth:`bind`) and consult it inline; every call
    is deterministic given the seed and the (already deterministic) event
    order. A fresh injector is built per ``ArraySim.run()`` — fault event
    times are relative to each run's t=0 (``run_phased`` re-arms them each
    phase); ``SAFSSim`` keeps one injector on its persistent loop."""

    def __init__(self, policy: FaultPolicy, n: int, seed: int) -> None:
        self.policy = policy
        self.n = n
        # fail-slow episodes per device: [onset, end, factor, counted?]
        self.slow: list[list[list]] = [[] for _ in range(n)]
        self.media_ber = [0.0] * n
        self.crash_event: "Crash | None" = None
        for e in policy.events:
            if isinstance(e, FailSlow):
                end = e.onset + e.duration
                self.slow[e.device].append([e.onset, end, e.slow_factor,
                                            False])
            elif isinstance(e, MediaError):
                if e.device < 0:
                    for i in range(n):
                        self.media_ber[i] += e.read_ber
                else:
                    self.media_ber[e.device] += e.read_ber
            elif isinstance(e, Crash):
                self.crash_event = e
        for i in range(n):
            self.slow[i].sort(key=lambda ep: ep[0])
            self.media_ber[i] = min(self.media_ber[i], 1.0 - 1e-12)
        self.any_media = any(b > 0.0 for b in self.media_ber)
        # dedicated decorrelated stream: media errors must not perturb the
        # workload RNG (the op sequence matches the fault-free run)
        self._rng = np.random.default_rng(
            _mix64((seed & _MASK) ^ _MEDIA_SALT))
        self._draw = self._rng.random
        r = policy.retry
        self.max_retries = r.max_retries
        self.backoff = r.backoff
        self.backoff_mult = r.backoff_mult
        self.timeout = r.timeout
        self.hedge_after = policy.hedge_after
        # -- detector / quarantine ------------------------------------------
        self.detect = policy.detect
        # per-device service-time EWMA (core/metrics.py: first-sample init,
        # then value += alpha*(dt - value) — the pre-refactor arithmetic)
        self.ew = [Ewma(policy.detect_alpha) for _ in range(n)]
        self.quarantined = [False] * n
        self._q_since = [0.0] * n
        self._notes = 0
        self.crashed = [False] * n
        # host hooks, bound per run loop
        self.on_quarantine = None     # f(i): apply the admission cap
        self.on_release = None        # f(i): lift it (and unpark waiters)
        self.stats = _new_fault_stats()

    # -- fail-slow -----------------------------------------------------------
    def has_slow(self, i: int) -> bool:
        return bool(self.slow[i])

    def slow_mult(self, i: int, now: float) -> float:
        for ep in self.slow[i]:
            if ep[0] <= now < ep[1]:
                if not ep[3]:
                    ep[3] = True
                    self.stats["fail_slow_episodes"] += 1
                return ep[2]
            if ep[0] > now:
                break
        return 1.0

    def is_slow_now(self, i: int, now: float) -> bool:
        return any(ep[0] <= now < ep[1] for ep in self.slow[i])

    def wrap_service_time(self, i: int, base, loop):
        """Per-device service-time wrapper: FailSlow scaling plus detector
        sampling. Built only for devices that need either — ``faults=None``
        never reaches this, keeping the plain closures byte-identical."""
        has_slow = self.has_slow(i)
        if self.detect:
            note = self.note_service
            if has_slow:
                mult = self.slow_mult

                def service_time(req):
                    dt = base(req) * mult(i, loop.now)
                    note(i, dt, loop.now)
                    return dt
            else:
                def service_time(req):
                    dt = base(req)
                    note(i, dt, loop.now)
                    return dt
            return service_time
        mult = self.slow_mult

        def service_time(req):
            return base(req) * mult(i, loop.now)
        return service_time

    # -- media errors + retries ---------------------------------------------
    def read_fails(self, i: int) -> bool:
        ber = self.media_ber[i]
        if ber <= 0.0:
            return False
        if self._draw() < ber:
            self.stats["media_errors"] += 1
            return True
        return False

    def retry_decision(self, attempt: int, t_issue: float,
                       now: float) -> "tuple[bool, float]":
        """Host policy after a failed read on its ``attempt``-th try
        (0-based): ``(retry?, backoff delay)``. Deterministic and bounded:
        at most ``max_retries`` re-issues, abandoned early when the op's
        elapsed time plus the pending backoff would blow the timeout."""
        st = self.stats
        if attempt + 1 > st["max_attempts"]:
            st["max_attempts"] = attempt + 1
        if attempt >= self.max_retries:
            st["retry_exhausted"] += 1
            return False, 0.0
        delay = self.backoff * self.backoff_mult ** attempt
        if self.timeout > 0.0 and (now - t_issue) + delay > self.timeout:
            st["timeouts"] += 1
            return False, 0.0
        st["retries"] += 1
        return True, delay

    # -- hedged reads --------------------------------------------------------
    def note_hedge(self) -> None:
        self.stats["hedged_reads"] += 1

    def note_hedge_win(self) -> None:
        self.stats["hedge_wins"] += 1

    # -- crash / rebuild -----------------------------------------------------
    def note_crash(self, i: int, now: float) -> None:
        self.crashed[i] = True
        self.stats["crashes"] += 1
        self.stats["crash_at"] = now

    def note_rebuild_complete(self, now: float) -> None:
        self.stats["rebuild_completed_at"] = now
        if self.stats["crash_at"] >= 0.0:
            self.stats["data_at_risk_s"] = now - self.stats["crash_at"]

    # -- detector ------------------------------------------------------------
    def note_service(self, i: int, dt: float, now: float) -> None:
        self.ew[i].update(dt)
        notes = self._notes + 1
        self._notes = notes
        if notes % self.policy.detect_every == 0:
            self._sweep(now)

    def _sweep(self, now: float) -> None:
        pol = self.policy
        min_n = pol.detect_min_samples
        ready = [self.ew[i].value for i in range(self.n)
                 if self.ew[i].n >= min_n and not self.crashed[i]]
        # peer-relative: need a quorum of sampled peers for a stable median
        if len(ready) < max(2, self.n // 2):
            return
        med = peer_median(ready)
        if med <= 0.0:
            return
        st = self.stats
        for i in range(self.n):
            if self.ew[i].n < min_n or self.crashed[i]:
                continue
            ew = self.ew[i].value
            if not self.quarantined[i]:
                if ew > pol.detect_ratio * med:
                    self.quarantined[i] = True
                    self._q_since[i] = now
                    st["quarantines"] += 1
                    if self.is_slow_now(i, now):
                        if st["detect_latency_s"] < 0.0:
                            onset = max(ep[0] for ep in self.slow[i]
                                        if ep[0] <= now)
                            st["detect_latency_s"] = now - onset
                    else:
                        st["false_quarantines"] += 1
                    if self.on_quarantine is not None:
                        self.on_quarantine(i)
            elif ew < pol.detect_release * med:
                self._release(i, now)

    def _release(self, i: int, now: float) -> None:
        self.quarantined[i] = False
        self.stats["quarantine_time_s"] += now - self._q_since[i]
        if self.on_release is not None:
            self.on_release(i)

    # -- end of run ----------------------------------------------------------
    def finalize(self, now: float) -> dict:
        """Snapshot the results block, counting open quarantine spans up to
        ``now`` WITHOUT closing them: ``SAFSSim`` keeps one injector across
        ``run_phased`` phases, so quarantine/slot-cap state must survive a
        phase boundary (``ArraySim`` builds a fresh injector per run)."""
        out = dict(self.stats)
        for i in range(self.n):
            if self.quarantined[i]:
                out["quarantine_time_s"] += now - self._q_since[i]
        return out

from .pipeline import Prefetcher, SyntheticLM, make_global_batch

__all__ = ["Prefetcher", "SyntheticLM", "make_global_batch"]

"""Synthetic-token data pipeline with host-side prefetch.

The prefetch queue is the data-plane instance of the paper's thesis: a deep
per-consumer buffer hides unsynchronized producer stalls (page-cache misses,
network FS hiccups) from the synchronous SPMD train loop. ``Prefetcher``
therefore reuses the dual-queue discipline from ``core.io_queues``: batches
are produced on the LOW queue in the background, while an explicit
``prefetch(step)`` barrier is the HIGH-priority read.

Tokens are deterministic functions of (seed, step) — restart-reproducible,
no files — drawn from a Zipfian unigram over the vocab with a Markov-ish
second-gram mix so the loss has learnable structure.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_s: float = 1.2

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed << 20) ^ step)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        ranks = rng.zipf(self.zipf_s, size=(b, s + 1)) % v
        # mix in local structure: token_{t+1} correlates with token_t
        shift = (ranks[:, :-1] * 31 + 7) % v
        use_prev = rng.random((b, s)) < 0.25
        seq = ranks[:, 1:].copy()
        seq[use_prev] = shift[use_prev]
        tokens = np.concatenate([ranks[:, :1], seq], axis=1).astype(np.int32)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_global_batch(batch: dict[str, np.ndarray], mesh: Mesh,
                      spec: P) -> dict[str, jax.Array]:
    """Host numpy -> sharded global jax.Arrays on ``mesh``."""
    def put(x):
        s = NamedSharding(mesh, spec if x.ndim >= 2 else P(spec[0] if len(spec) else None))
        return jax.make_array_from_process_local_data(s, x)
    return {k: put(v) for k, v in batch.items()}


class Prefetcher:
    """Depth-``depth`` background prefetch of an iterator (straggler cover).

    depth sizes the low-priority buffer exactly like the paper's long flush
    queues: production continues while the consumer is busy, so a slow step
    (or a slow producer) never leaves the other side idle.
    """

    def __init__(self, it: Iterator, depth: int = 4):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None
        self._stop = False
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop:
                    return
                self._q.put(item)
        except BaseException as e:     # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

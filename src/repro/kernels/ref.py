"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        q_offset=0):
    """Materialized-softmax attention. q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, sq, kvh, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, k.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key produce uniform weights in softmax; zero them
    any_valid = mask.any(axis=-1)
    p = jnp.where(any_valid[None, None, None, :, None], p, 0.0)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        softcap=0.0):
    """Decode attention over a paged pool.

    q: (B, H, hd); k/v_pages: (P, page, KV, hd); page_table: (B, max_pages)
    int32 (entries beyond the sequence are arbitrary); lengths: (B,).
    """
    b, h, hd = q.shape
    n_pages, page, kvh, _ = k_pages.shape
    max_pages = page_table.shape[1]
    rep = h // kvh
    k_ctx = k_pages[page_table]                  # (B, max_pages, page, KV, hd)
    v_ctx = v_pages[page_table]
    k_ctx = k_ctx.reshape(b, max_pages * page, kvh, hd)
    v_ctx = v_ctx.reshape(b, max_pages * page, kvh, hd)
    qf = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, kvh, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qf, k_ctx.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(max_pages * page)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v_ctx.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)


def flush_scores_ref(hits, clock, valid):
    """Paper §3.3.1 (vectorized): distance_score = hits*set_size + distance;
    flush score = set_size - 1 - rank(distance_score), -1 for invalid slots.

    hits: (num_sets, set_size) int32; clock: (num_sets,) int32;
    valid: (num_sets, set_size) bool.
    """
    ns, ss = hits.shape
    pos = jnp.arange(ss, dtype=jnp.int32)[None, :]
    dist = jnp.mod(pos - clock[:, None], ss)
    d = hits.astype(jnp.int32) * ss + dist
    big = jnp.iinfo(jnp.int32).max
    d = jnp.where(valid, d, big)
    di = d[:, :, None]
    dj = d[:, None, :]
    idx = jnp.arange(ss, dtype=jnp.int32)
    lt = (dj < di) | ((dj == di) & (idx[None, None, :] < idx[None, :, None]))
    rank = lt.sum(axis=-1).astype(jnp.int32)
    fs = ss - 1 - rank
    return jnp.where(valid, fs, -1)

"""Pallas TPU kernels for the compute hot-spots.

Three kernels, each with the required triple:
  <name>.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     -- jit'd public wrappers (interpret=True on CPU backends)
  ref.py     -- pure-jnp oracles the tests allclose against

flash_attention  train/prefill attention (causal/SWA/softcap/GQA) -- removes
                 the S^2 logits HBM round-trip that dominates the baseline
                 roofline memory term.
paged_attention  decode attention over the SA-cache-managed paged KV pool
                 (scalar-prefetched page table -- the serving engine's data
                 plane).
flush_score      the paper's SS3.3.1 GClock distance-score + rank over page
                 sets, vectorized sets-to-sublanes (the host-side hot loop of
                 SAFS adapted to the TPU VPU).
"""

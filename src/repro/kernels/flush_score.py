"""GClock flush scores (paper §3.3.1) as a Pallas TPU kernel.

The paper's flusher walks page sets on the CPU; at TPU-serving scale the KV
pool has 10^5+ page sets and the walk becomes the control-plane hot spot.
The insight from ``core/sa_cache.py`` — a GClock sweep victim is simply
``argmin(hits * set_size + distance)`` — turns scoring into a branch-free
rank computation, which this kernel evaluates for thousands of sets per
grid step on the VPU.

Tiling: sets -> sublanes (block_sets x set_size tile in VMEM; set_size is
padded to the 128-lane register width — the padding columns are masked
invalid). Ranks come from the O(set_size^2) pairwise comparison, which at
set_size = 12 (paper) is 144 lane-ops — far cheaper than any sort network
and entirely data-parallel across sets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = jnp.iinfo(jnp.int32).max


def _flush_score_kernel(hits_ref, clock_ref, valid_ref, out_ref, *,
                        set_size: int):
    hits = hits_ref[...].astype(jnp.int32)        # (bs, ss_pad)
    valid = valid_ref[...]
    clock = clock_ref[...].astype(jnp.int32)      # (bs, 1)
    bs, ss_pad = hits.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (bs, ss_pad), 1)
    in_set = pos < set_size
    dist = jnp.mod(pos - clock, set_size)
    d = hits * set_size + dist
    d = jnp.where(valid & in_set, d, BIG)
    # rank via pairwise compare; ties broken by slot index (stable)
    di = d[:, :, None]
    dj = d[:, None, :]
    idx = jax.lax.broadcasted_iota(jnp.int32, (bs, ss_pad, ss_pad), 2)
    idx_i = jax.lax.broadcasted_iota(jnp.int32, (bs, ss_pad, ss_pad), 1)
    lt = (dj < di) | ((dj == di) & (idx < idx_i))
    rank = lt.sum(axis=-1).astype(jnp.int32)
    fs = set_size - 1 - rank
    out_ref[...] = jnp.where(valid & in_set, fs, -1)


@functools.partial(jax.jit, static_argnames=("block_sets", "interpret"))
def flush_scores(hits, clock, valid, *, block_sets: int = 256,
                 interpret: bool = False):
    """hits: (num_sets, set_size) int32; clock: (num_sets,) int32;
    valid: (num_sets, set_size) bool -> flush scores int32 (-1 invalid)."""
    ns, ss = hits.shape
    ss_pad = max(8, -(-ss // 8) * 8)
    pad_sets = (-ns) % block_sets
    if ss_pad != ss:
        hits = jnp.pad(hits, ((0, 0), (0, ss_pad - ss)))
        valid = jnp.pad(valid, ((0, 0), (0, ss_pad - ss)))
    if pad_sets:
        hits = jnp.pad(hits, ((0, pad_sets), (0, 0)))
        valid = jnp.pad(valid, ((0, pad_sets), (0, 0)))
        clock = jnp.pad(clock, (0, pad_sets))
    nb = hits.shape[0] // block_sets

    out = pl.pallas_call(
        functools.partial(_flush_score_kernel, set_size=ss),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_sets, ss_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_sets, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_sets, ss_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_sets, ss_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hits.shape[0], ss_pad), jnp.int32),
        interpret=interpret,
    )(hits, clock[:, None], valid)
    return out[:ns, :ss]

"""Paged decode attention (TPU Pallas): one new token per sequence attends
over its KV pages scattered through the SA-cache-managed HBM pool.

The page table is a SCALAR-PREFETCH operand (pltpu.PrefetchScalarGridSpec):
the index_map dereferences ``page_table[b, p]`` so the DMA engine streams
exactly the pages this sequence owns — no gather materialization in HBM,
which is the whole point of paged attention (the pool never has to be
contiguous per sequence; the paper's set-associative placement stays).

Grid = (B, max_pages), pages innermost (sequential online-softmax
accumulation in VMEM scratch). VMEM per step (page = 256 tokens, KV = 16
heads, hd = 128): k,v 2 x 1 MiB (bf16) + q/acc (H x hd f32) — ~3 MiB.
Sequences shorter than max_pages x page mask the tail; whole pages past
``lengths[b]`` are a skipped (early-exit ``pl.when``) DMA-only cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(lengths_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page: int, softcap: float,
                  sm_scale: float, num_pages: int, group: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    # pages fully past the sequence end contribute nothing — skip the math
    @pl.when(p * page < length)
    def _work():
        q = q_ref[0].astype(jnp.float32) * sm_scale       # (H, hd)
        k = k_ref[0].astype(jnp.float32)                  # (page, KV, hd)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kvh = k.shape[1]
        qg = q.reshape(kvh, group, hd)
        s = jnp.einsum("grd,pgd->grp", qg, k)             # (KV, group, page)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = p * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                               # (KV, group)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pr = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + pr.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[..., None]
                        + jnp.einsum("grp,pgd->grd", pr, v))
        m_scr[...] = m_new

    @pl.when(p == num_pages - 1)
    def _finish():
        h, hd = q_ref.shape[1], q_ref.shape[2]
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(h, hd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    softcap: float = 0.0, interpret: bool = False):
    """q: (B, H, hd); k/v_pages: (P, page, KV, hd);
    page_table: (B, max_pages) int32; lengths: (B,) -> (B, H, hd)."""
    b, h, hd = q.shape
    n_pool, page, kvh, _ = k_pages.shape
    max_pages = page_table.shape[1]
    group = h // kvh

    kernel = functools.partial(
        _paged_kernel, page=page, softcap=softcap, sm_scale=hd ** -0.5,
        num_pages=max_pages, group=group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # lengths, page_table
        grid=(b, max_pages),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda bi, pi, lens, tab: (bi, 0, 0)),
            pl.BlockSpec((1, page, kvh, hd),
                         lambda bi, pi, lens, tab: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, kvh, hd),
                         lambda bi, pi, lens, tab: (tab[bi, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda bi, pi, lens, tab: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, group), jnp.float32),
            pltpu.VMEM((kvh, group), jnp.float32),
            pltpu.VMEM((kvh, group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, q, k_pages, v_pages)

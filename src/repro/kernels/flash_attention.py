"""Flash attention (TPU Pallas): VMEM-tiled online-softmax attention.

Block decomposition: grid = (batch, q_heads, Sq/block_q, Skv/block_kv), the
kv axis innermost ("arbitrary" semantics — sequential accumulation), with
f32 scratch accumulators (m, l, acc) living in VMEM across kv steps.

VMEM working set per grid step (defaults block_q = block_kv = 512, hd = 128):
  q (512x128 bf16)  128 KiB      k,v (512x128 bf16)  2x128 KiB
  acc (512x128 f32) 256 KiB      m,l (512) ~4 KiB    s/p (512x512 f32) 1 MiB
≈ 1.7 MiB — comfortably under the ~16 MiB/core VMEM budget, MXU-aligned
(every matmul dim a multiple of 128).

GQA never replicates K/V in HBM: the BlockSpec index_map folds the
q-head -> kv-head mapping (h // group) so each kv head is streamed once per
group. Causal/sliding-window masking is positional, computed in-kernel; fully
masked kv blocks still run (documented; the hillclimbed serve path skips them
by shrinking the kv grid — see ops.flash_attention's `kv_upper` bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_kv: int, kv_len: int, q_offset: int,
                  num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                     # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    # rows with every key masked: exp(NEG_INF - NEG_INF) = 1 — zero them
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                         jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv",
                     "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_kv: int = 512, q_offset: int = 0,
                    interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, H, hd).

    H must be a multiple of KV (GQA group size). Sequence lengths are padded
    to the block sizes internally; padded keys are masked out.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    group = h // kvh
    block_q = min(block_q, max(sq, 16))
    block_kv = min(block_kv, max(skv, 16))
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    qt = jnp.moveaxis(q, 2, 1)                          # (B, H, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    nq = (sq + pad_q) // block_q
    nkv = (skv + pad_kv) // block_kv

    kernel = functools.partial(
        _flash_kernel, sm_scale=hd ** -0.5, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, kv_len=skv,
        q_offset=q_offset, num_kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)                       # (B, Sq+pad, H, hd)
    return out[:, :sq]

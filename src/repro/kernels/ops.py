"""Public jit'd wrappers for the Pallas kernels.

On CPU backends (this container) every kernel runs in interpret mode — the
kernel body executes in Python op-by-op, validating the exact TPU program
against the ref.py oracles. On TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .flush_score import flush_scores as _flush_scores
from .paged_attention import paged_attention as _paged


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash(q, k, v, **kw)


def paged_attention(q, k_pages, v_pages, page_table, lengths, **kw):
    kw.setdefault("interpret", _interpret())
    return _paged(q, k_pages, v_pages, page_table, lengths, **kw)


def flush_scores(hits, clock, valid, **kw):
    kw.setdefault("interpret", _interpret())
    return _flush_scores(hits, clock, valid, **kw)

"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-free dispatch,
expert parallelism over the data axis (all-to-all) + tensor parallelism over
the model axis (psum) via shard_map.

Design notes (DESIGN.md §4):
  * dispatch is gather/scatter based — FLOPs are exactly the active-expert
    FLOPs (one-hot einsum dispatch would be quadratic in expert count);
  * expert weights are sharded E over 'data' (EP) and d_ff over 'model' (TP);
    the pod axis replicates experts (grad all-reduce syncs them);
  * ``mesh=None`` (or 1-device) falls back to the identical local math —
    smoke tests and the reduced configs use that path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def init_moe(rng, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    k = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(k[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k[1], (e, d, f)) * s_in).astype(dt),
        "w_up": (jax.random.normal(k[2], (e, d, f)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k[3], (e, f, d)) * s_out).astype(dt),
    }


def _capacity(tokens: int, cfg: ModelConfig, factor: float | None = None) -> int:
    f = cfg.moe_capacity_factor if factor is None else factor
    c = int(tokens * cfg.moe_topk / cfg.moe_experts * f)
    return max(8, -(-c // 8) * 8)


def moe_local(x, params, cfg: ModelConfig, *, ep_axis: str | None = None,
              tp_axis: str | None = None, ep_size: int = 1,
              capacity_factor: float | None = None,
              stats_axes: tuple[str, ...] = ()):
    """Per-shard MoE math. x: (B, S, d) local. Returns (y, aux_losses)."""
    b, s, d = x.shape
    e, k_top = cfg.moe_experts, cfg.moe_topk
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k_top)                          # (T, K)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # aux losses (switch-style load balance + router z-loss), averaged over
    # every axis that shards tokens so the scalar is truly replicated
    me = probs.mean(axis=0)                                        # (E,)
    one = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1)         # (T, E)
    ce = one.mean(axis=0) / k_top
    if stats_axes:
        me = jax.lax.pmean(me, stats_axes)
        ce = jax.lax.pmean(ce, stats_axes)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    if stats_axes:
        z_loss = jax.lax.pmean(z_loss, stats_axes)

    # sort-free capacity dispatch
    cap = _capacity(t, cfg, capacity_factor)
    e_flat = idx.reshape(-1)                                       # (T*K,)
    oh = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              e_flat[:, None], axis=1)[:, 0]       # (T*K,)
    keep = pos < cap
    dest = jnp.where(keep, e_flat * cap + pos, e * cap)            # overflow slot
    buf = jnp.full((e * cap + 1,), t, dtype=jnp.int32)
    buf = buf.at[dest].set(jnp.arange(t * k_top, dtype=jnp.int32) // k_top)
    buf = buf[:e * cap]

    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xg = x_pad[buf].reshape(e, cap, d)                             # (E, C, d)

    if ep_axis and ep_size > 1:
        # EP: ship each expert's rows to its owner shard.
        xg = jax.lax.all_to_all(xg, ep_axis, split_axis=0, concat_axis=1,
                                tiled=True)                        # (E/D, C*D, d)

    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu, approximate=True)
    h = act(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    if ep_axis and ep_size > 1:
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True)                         # (E, C, d)

    y_flat = y.reshape(e * cap, d)
    out_tk = y_flat[jnp.where(keep, dest, 0)]                      # (T*K, d)
    out_tk = jnp.where(keep[:, None], out_tk, 0.0)
    out = (out_tk.reshape(t, k_top, d) * w[..., None].astype(y.dtype)).sum(axis=1)
    if tp_axis:
        # deferred past the token combine: psum of (T, d) instead of the
        # 1.25*topk-x padded (E, C, d) capacity buffer (§Perf iteration 2)
        out = jax.lax.psum(out, tp_axis)
    return out.reshape(b, s, d).astype(x.dtype), {"lb": lb_loss, "z": z_loss}


def moe_ffn(x, params, cfg: ModelConfig, mesh=None,
            dp_axes: tuple[str, ...] | None = None, ep_axis: str = "data",
            tp_axis: str = "model"):
    """MoE FFN with optional distribution. x: (B, S, d) global."""
    if mesh is None or ep_axis not in mesh.shape:
        return moe_local(x, params, cfg)
    if dp_axes is None:
        dp_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    if x.shape[0] % dp_size != 0:
        # batch too small to shard (single-sequence decode): plain GSPMD path
        return moe_local(x, params, cfg)
    tp_size = mesh.shape.get(tp_axis, 1)
    mode = cfg.moe_parallel
    if mode == "tp" and (tp_size <= 1 or cfg.moe_d_ff % tp_size != 0):
        mode = "ep"
    ep_size = mesh.shape[ep_axis]
    if cfg.moe_experts % ep_size != 0:
        ep_size = 1  # fall back to pure TP when E doesn't divide the axis

    from jax.experimental.shard_map import shard_map

    dp = P(dp_axes, None, None)
    if mode == "tp":
        # expert-TP: every shard holds a d_ff/TP slice of EVERY expert and
        # processes its LOCAL tokens end-to-end; one (T_local, d) psum
        # replaces the two (E, C, d) all-to-alls. Wire bytes per layer drop
        # from 2*E*C*d to T*d (~10-20x on the 16x16 mesh); the cost is
        # skinnier per-expert matmuls (d_ff/16 wide), noted in §Perf.
        fn = partial(moe_local, cfg=cfg, ep_axis=None, tp_axis=tp_axis,
                     ep_size=1, stats_axes=dp_axes)
        wspec_up = P(None, None, tp_axis)
        wspec_dn = P(None, tp_axis, None)
    else:
        fn = partial(moe_local, cfg=cfg,
                     ep_axis=ep_axis if ep_size > 1 else None,
                     tp_axis=tp_axis if tp_size > 1 else None,
                     ep_size=ep_size, stats_axes=dp_axes)
        wspec_up = P(ep_axis if ep_size > 1 else None, None, tp_axis)
        wspec_dn = P(ep_axis if ep_size > 1 else None, tp_axis, None)
    out = shard_map(
        fn, mesh=mesh,
        in_specs=(dp, {"router": P(), "w_gate": wspec_up,
                       "w_up": wspec_up, "w_down": wspec_dn}),
        out_specs=(dp, {"lb": P(), "z": P()}),
        check_rep=False,
    )(x, params)
    return out

"""Mamba2 (SSD — state-space duality) layer, chunked-scan training form and
O(1)-state decode form. [arXiv:2405.21060]

Shapes follow the paper: d_inner = expand * d_model, H = d_inner / head_dim
SSM heads, shared (n_groups = 1) B/C of size N = ssm_state.

Training/prefill uses the block decomposition of the SSD paper: the sequence
is split into chunks of length L; within a chunk the quadratic "attention
form" is used; across chunks a recurrent state (B, H, hd, N) is carried with
``lax.scan``. Numerically everything decays through exp(segsum(log a)).

Sharding note (DESIGN.md §4): the input projection is SPLIT by component —
``in_x``/``in_z``/``in_dt`` shard their output (d_inner / heads) over the
model axis while ``in_bc`` (shared across heads, n_groups=1) stays
replicated. A packed in_proj would force the whole projection to be
replicated; the split is what makes Mamba TP-shardable on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rmsnorm


def init_mamba(rng, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = jax.random.split(rng, 6)
    dt = jnp.dtype(cfg.dtype)
    s = d ** -0.5
    return {
        "in_x": (jax.random.normal(k[0], (d, di)) * s).astype(dt),
        "in_z": (jax.random.normal(k[1], (d, di)) * s).astype(dt),
        "in_bc": (jax.random.normal(k[2], (d, 2 * n)) * s).astype(dt),
        "in_dt": (jax.random.normal(k[3], (d, h)) * s).astype(dt),
        "conv_x_w": (jax.random.normal(k[4], (cfg.ssm_conv, di)) * 0.2).astype(dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_w": (jax.random.normal(k[5], (cfg.ssm_conv, 2 * n)) * 0.2).astype(dt),
        "conv_bc_b": jnp.zeros((2 * n,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": (jax.random.normal(rng, (di, d)) * (di ** -0.5)).astype(dt),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., L). Returns (..., L, L) with out[i, j] = sum_{k=j+1..i} x_k
    for i >= j, -inf below the causal diagonal."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv(x, w, b, s):
    """Depthwise causal conv. x: (B, S, C); w: (cw, C)."""
    cw = w.shape[0]
    padded = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(padded[:, i:i + s, :] * w[i][None, None, :] for i in range(cw))
    return jax.nn.silu(out + b)


def mamba_chunked(x, params, cfg: ModelConfig, chunk: int = 256,
                  initial_state=None, return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model). Training / prefill form."""
    b, s, _ = x.shape
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xr = x @ params["in_x"]
    z = x @ params["in_z"]
    bc = x @ params["in_bc"]
    dt_raw = x @ params["in_dt"]

    xs = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"], s)
    bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], s)
    Bmat, Cmat = bc[..., :n], bc[..., n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                          # (H,)
    dA = dt * A                                                            # (B,S,H) log-decay

    L = min(chunk, s)
    assert s % L == 0, f"seq {s} not divisible by ssd chunk {L}"
    nc = s // L

    def resh(t, last):
        return t.reshape(b, nc, L, *last)

    xs = resh(xs, (h, hd)).astype(jnp.float32)       # (B,C,L,H,hd)
    Bc = resh(Bmat, (n,)).astype(jnp.float32)        # (B,C,L,N)
    Cc = resh(Cmat, (n,)).astype(jnp.float32)
    dtc = resh(dt, (h,))                             # (B,C,L,H)
    dAc = resh(dA, (h,))

    # intra-chunk (quadratic "attention" form)
    seg = _segsum(dAc.transpose(0, 1, 3, 2))         # (B,C,H,L,L)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)   # (B,C,L,L)
    y_intra = jnp.einsum("bclm,bchlm,bcmh,bcmhp->bclhp",
                         scores, decay, dtc, xs)

    # chunk summaries -> recurrent state pass
    dA_cum = jnp.cumsum(dAc, axis=2)                 # (B,C,L,H)
    dA_tot = dA_cum[:, :, -1, :]                     # (B,C,H)
    # state contribution of each chunk: sum_m exp(dA_tot - dA_cum_m) dt_m B_m x_m
    w_in = jnp.exp(dA_tot[:, :, None, :] - dA_cum) * dtc      # (B,C,L,H)
    chunk_states = jnp.einsum("bclh,bcln,bclhp->bchnp", w_in, Bc, xs)  # (B,C,H,N,hd)

    def scan_fn(hprev, inp):
        st, tot = inp                                 # (B,H,N,hd), (B,H)
        hnew = hprev * jnp.exp(tot)[:, :, None, None] + st
        return hnew, hprev

    h0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, n, hd), jnp.float32))
    hlast, hprevs = jax.lax.scan(
        scan_fn, h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), dA_tot.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)          # (B,C,H,N,hd) state at chunk start

    # inter-chunk: y += C_l . exp(dA_cum_l) h_prev
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cc, jnp.exp(dA_cum), hprevs)

    y = (y_intra + y_inter).reshape(b, s, h, hd)
    y = y + params["D"][None, None, :, None] * xs.reshape(b, s, h, hd)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, hlast
    return out


def mamba_decode_step(x, state, params, cfg: ModelConfig):
    """Single-token decode. x: (B, 1, d_model).

    state = {"conv_x": (B, conv_w-1, di), "conv_bc": (B, conv_w-1, 2N),
    "ssm": (B, H, N, hd)} carried across steps — the O(1) "page" of a
    sequence (DESIGN.md §5: managed by the serving cache as a pinned page).
    """
    b = x.shape[0]
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x0 = x[:, 0, :]
    xr = x0 @ params["in_x"]
    z = x0 @ params["in_z"]
    bc = x0 @ params["in_bc"]
    dt_raw = x0 @ params["in_dt"]

    hist_x = jnp.concatenate([state["conv_x"], xr[:, None, :]], axis=1)
    hist_bc = jnp.concatenate([state["conv_bc"], bc[:, None, :]], axis=1)
    conv_x = jax.nn.silu((hist_x * params["conv_x_w"][None]).sum(axis=1)
                         + params["conv_x_b"])
    conv_bc = jax.nn.silu((hist_bc * params["conv_bc_w"][None]).sum(axis=1)
                          + params["conv_bc_b"])

    xs = conv_x.reshape(b, h, hd).astype(jnp.float32)
    Bv = conv_bc[:, :n].astype(jnp.float32)            # (B,N)
    Cv = conv_bc[:, n:].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                               # (B,H)

    hs = state["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bv, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cv, hs)
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32))[:, None, :].astype(x.dtype),
                params["norm"], cfg.norm_eps)
    new_state = {"conv_x": hist_x[:, 1:, :], "conv_bc": hist_bc[:, 1:, :], "ssm": hs}
    return y @ params["out_proj"], new_state


def init_mamba_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    dt = jnp.dtype(cfg.dtype)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dt),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state), dt),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                         dtype),
    }

"""Neural-net building blocks: norms, RoPE/M-RoPE, attention (GQA / sliding
window / softcap / qk-norm / cross), MLPs. Pure functions over param pytrees.

Conventions:
  * activations (B, S, D); attention heads materialized as (B, S, H, hd);
  * params are dicts of jnp arrays; init fns take an ``rng`` and return them;
  * math in the config dtype (bf16 on TPU), softmax/logits accumulate in f32;
  * long sequences use a lax.scan chunked attention (online softmax) — this is
    also the pure-jnp oracle for the flash_attention Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# Positions: RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] = ()) -> jax.Array:
    """x: (B, S, H, hd). positions: (B, S) or (B, 3, S) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 rotary frequencies are split into sections
    (t, h, w); each section rotates by its own position stream.
    """
    b, s, h, hd = x.shape
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (B, 3, S) positions"
        sec_id = jnp.repeat(jnp.arange(len(mrope_sections)),
                            jnp.array(mrope_sections), total_repeat_length=hd // 2)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),           # (B, 3, S)
            jnp.broadcast_to(sec_id[None, :, None], (b, hd // 2, s)).astype(jnp.int32),
            axis=1,
        )                                            # (B, hd/2, S)
        angles = pos.transpose(0, 2, 1) * inv[None, None, :]     # (B, S, hd/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings; positions (B, S) -> (B, S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k = jax.random.split(rng, 4)
    scale = d ** -0.5
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(k[0], (d, h * hd)) * scale).astype(dt),
        "wk": (jax.random.normal(k[1], (d, kv * hd)) * scale).astype(dt),
        "wv": (jax.random.normal(k[2], (d, kv * hd)) * scale).astype(dt),
        "wo": (jax.random.normal(k[3], (h * hd, d)) * scale).astype(dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


def _dense_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                     q_offset: int | jax.Array = 0) -> jax.Array:
    """Materialized attention. q: (B,Sq,H,hd) k/v: (B,Sk,KV,hd).

    Inputs stay in their stored dtype; the logits dot accumulates in f32
    (preferred_element_type) — no f32 copies of Q/K in HBM (§Perf)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qf = (q.astype(jnp.float32) * (hd ** -0.5)).astype(q.dtype)
    qf = qf.reshape(b, sq, kvh, rep, hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qf, k,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    if causal or window:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                       kv_chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention, scanning KV chunks.

    Memory O(Sq * kv_chunk) instead of O(Sq * Skv). Oracle for the Pallas
    flash kernel; used for long prefill.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    qf = (q.astype(jnp.float32) * (hd ** -0.5)).reshape(b, sq, kvh, rep, hd)
    qpos = jnp.arange(sq)[:, None]

    def step(carry, inp):
        m, l, acc = carry
        ci, (kb, vb) = inp
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kb.astype(jnp.float32))
        logits = _softcap(logits, softcap)
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = kpos < skv
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def multihead_attention(x, params, cfg: ModelConfig, *, positions,
                        window: int = 0, causal: bool = True,
                        kv_override=None, q_offset=0,
                        dense_threshold: int | None = None) -> jax.Array:
    """Full self-attention (or cross-attention via kv_override=(k,v))."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    if kv_override is None:
        k = (x @ params["wk"]).reshape(b, s, kvh, hd)
        v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        if kv_override is None:
            k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope_theta and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    skv = k.shape[1]
    if dense_threshold is None:
        dense_threshold = cfg.attn_dense_threshold
    if max(s, skv) > dense_threshold:
        out = _chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=cfg.attn_softcap)
    else:
        out = _dense_attention(q, k, v, causal=causal, window=window,
                               softcap=cfg.attn_softcap, q_offset=q_offset)
    return out.reshape(b, s, h * hd) @ params["wo"]


def project_kv(x, params, cfg: ModelConfig, positions) -> tuple[jax.Array, jax.Array]:
    """K/V projections only (prefill cache write, cross-attn memory)."""
    b, s, _ = x.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = (x @ params["wk"]).reshape(b, s, kvh, hd)
    v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope_theta and positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return k, v


# ---------------------------------------------------------------------------
# Decode-time attention over gathered KV (the paged pool path lives in
# serving/kv_cache.py; this consumes already-gathered dense KV windows).
# ---------------------------------------------------------------------------

def decode_attention(q, k_ctx, v_ctx, *, lengths, softcap: float = 0.0,
                     kpos=None) -> jax.Array:
    """q: (B,1,H,hd); k_ctx/v_ctx: (B,S,KV,hd); lengths: (B,) valid KV count.

    kpos optionally gives absolute key positions (B,S) for windowed caches
    where the gathered window is a rotating buffer.

    The KV cache is consumed in its STORED dtype with f32 accumulation
    (preferred_element_type) — never materialize an f32 copy of the cache,
    which would triple decode HBM traffic (§Perf, gemma2 decode cell).
    """
    b, _, h, hd = q.shape
    skv, kvh = k_ctx.shape[1], k_ctx.shape[2]
    rep = h // kvh
    qf = (q.astype(jnp.float32) * (hd ** -0.5)).reshape(b, kvh, rep, hd)
    logits = jnp.einsum("bgrd,bkgd->bgrk", qf.astype(k_ctx.dtype), k_ctx,
                        preferred_element_type=jnp.float32)
    logits = _softcap(logits, softcap)
    if kpos is None:
        valid = jnp.arange(skv)[None, :] < lengths[:, None]
    else:
        valid = kpos < lengths[:, None]
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", w.astype(v_ctx.dtype), v_ctx,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h * hd).astype(q.dtype)


def decode_cross_attention(x, params, cfg: ModelConfig, kv) -> jax.Array:
    """Decode-time cross-attention (whisper): x (B,1,D) attends over the full
    precomputed encoder KV ({"k","v"}: (B, enc_seq, KV, hd)); every key valid."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    skv = kv["k"].shape[1]
    out = decode_attention(q, kv["k"], kv["v"],
                           lengths=jnp.full((b,), skv, jnp.int32))
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.dtype)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "w_gate": (jax.random.normal(k[0], (d, f)) * s_in).astype(dt),
        "w_up": (jax.random.normal(k[1], (d, f)) * s_in).astype(dt),
        "w_down": (jax.random.normal(k[2], (f, d)) * s_out).astype(dt),
    }


def mlp(x, params, act: str = "silu") -> jax.Array:
    a = jax.nn.silu if act == "silu" else partial(jax.nn.gelu, approximate=True)
    return (a(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]

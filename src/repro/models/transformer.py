"""Full model assembly for every assigned architecture.

One code path covers dense / MoE / SSM / hybrid / enc-dec / VLM:

* the layer stack is ``n_blocks`` repetitions of the config's ``block``
  pattern (1, 2 or 8 sublayers). Parameters for pattern position ``i`` are
  stacked over blocks with leading dim ``n_blocks`` so the whole stack is a
  single ``lax.scan`` — compact HLO at 80 layers and scan-level remat.
* train/prefill forward, single-token decode with KV / SSM-state caches
  (ring buffers for sliding-window layers), whisper cross-attention, and
  qwen2-vl M-RoPE with stubbed patch embeddings.

All functions are pure; distribution comes from the shardings pjit places on
``params`` / ``cache`` (see distributed/sharding.py) plus the shard_map inside
``moe_ffn``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from . import layers as L
from .moe import init_moe, moe_ffn
from .ssm import init_mamba, init_mamba_state, mamba_chunked, mamba_decode_step

KPOS_INVALID = jnp.iinfo(jnp.int32).max // 2  # empty ring slot: always masked


def _constrain_batch(x, mesh):
    """Pin activations to data-parallel batch sharding (replicated elsewhere).

    Without this GSPMD happily propagates the embedding table's layout into
    the residual stream — d_model sharded over the FSDP axis and NO batch
    parallelism. One constraint per block boundary re-anchors the layout."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if x.shape[0] % size != 0:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _constrain_seq(x, mesh, cfg):
    """Sequence-parallel residual layout: P(dp, model, None). The sublayer
    boundaries re-constrain to P(dp, None, None), so GSPMD lowers the TP
    all-reduces as reduce-scatter (into this layout) + all-gather (out of
    it) and every norm/residual op runs on a 1/TP sequence slice."""
    if mesh is None or not cfg.seq_parallel or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    if x.shape[0] % dp_size != 0 or x.shape[1] % tp != 0 or tp <= 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None)))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_sublayer(rng, spec: LayerSpec, cfg: ModelConfig, cross: bool) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict = {"norm": L.init_rmsnorm(cfg.d_model)["scale"]}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["attn"] = init_mamba(ks[0], cfg)
    if cfg.post_norms:
        p["post_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
    if cross and spec.kind == "attn":
        p["cross_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
        p["cross"] = L.init_attention(ks[1], cfg)
    if spec.ffn == "mlp":
        p["ffn_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
        p["mlp"] = L.init_mlp(ks[2], cfg)
        if cfg.post_norms:
            p["ffn_post_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
    elif spec.ffn == "moe":
        p["ffn_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
        p["moe"] = init_moe(ks[2], cfg)
        if cfg.post_norms:
            p["ffn_post_norm"] = L.init_rmsnorm(cfg.d_model)["scale"]
    return p


def init_params(rng, cfg: ModelConfig) -> dict:
    """Returns the full parameter pytree. blocks[i] leaves have leading
    dim n_blocks (stacked for lax.scan)."""
    n_blocks = cfg.n_blocks
    k_embed, k_blocks, k_head, k_enc = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": L.init_rmsnorm(cfg.d_model)["scale"],
    }
    cross = cfg.encoder_layers > 0
    blocks = []
    for i, spec in enumerate(cfg.block):
        keys = jax.random.split(jax.random.fold_in(k_blocks, i), n_blocks)
        blocks.append(jax.vmap(lambda k: _init_sublayer(k, spec, cfg, cross))(keys))
    params["blocks"] = tuple(blocks)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                             * cfg.d_model ** -0.5).astype(dt)
    if cfg.encoder_layers:
        enc_spec = LayerSpec(kind="attn", ffn="mlp")
        keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _init_sublayer(k, enc_spec, cfg, cross=False))(keys),
            "final_norm": L.init_rmsnorm(cfg.d_model)["scale"],
        }
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig, vis_embeds=None):
    x = params["embed"][tokens]          # (B, S, D)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if vis_embeds is not None:
        nv = vis_embeds.shape[1]
        x = jnp.concatenate([vis_embeds.astype(x.dtype), x[:, nv:]], axis=1)
    if cfg.rope_theta == 0.0 and cfg.encoder_layers:   # whisper: absolute pos
        pos = jnp.arange(x.shape[1])[None, :]
        x = x + L.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    return x


def head_weight(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def logits_fn(params, x, cfg: ModelConfig):
    logits = (x @ head_weight(params, cfg)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Forward (train / prefill trunk): scan over blocks
# ---------------------------------------------------------------------------

def _apply_sublayer(x, p, spec: LayerSpec, cfg: ModelConfig, *, positions,
                    mesh, enc_out, aux):
    """One sublayer (attn/mamba + ffn) in train/prefill form."""
    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    h = _constrain_batch(h, mesh)            # seq-parallel: AG into sublayer
    if spec.kind == "attn":
        h = L.multihead_attention(h, p["attn"], cfg, positions=positions,
                                  window=spec.window, causal=True)
    else:
        h = mamba_chunked(h, p["attn"], cfg)
    if cfg.post_norms:
        h = L.rmsnorm(h, p["post_norm"], cfg.norm_eps)
    x = x + h
    if enc_out is not None and "cross" in p:
        h = L.rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        kv = L.project_kv(enc_out, p["cross"], cfg, positions=None)
        h = L.multihead_attention(h, p["cross"], cfg, positions=None,
                                  kv_override=kv, causal=False)
        x = x + h
    if spec.ffn == "mlp":
        h = L.rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        h = _constrain_batch(h, mesh)
        h = L.mlp(h, p["mlp"], cfg.act)
        if cfg.post_norms:
            h = L.rmsnorm(h, p["ffn_post_norm"], cfg.norm_eps)
        x = x + h
    elif spec.ffn == "moe":
        h = L.rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        h = _constrain_batch(h, mesh)
        h, losses = moe_ffn(h, p["moe"], cfg, mesh=mesh)
        aux = {"lb": aux["lb"] + losses["lb"], "z": aux["z"] + losses["z"]}
        x = x + h
    return x, aux


def _encoder_forward(params, frames, cfg: ModelConfig, mesh=None):
    """Whisper encoder over precomputed conv frames (B, enc_seq, D)."""
    pos = jnp.arange(frames.shape[1])[None, :]
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    x = _constrain_batch(x, mesh)

    def body(xc, p):
        xc = _constrain_batch(xc, mesh)
        h = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
        h = L.multihead_attention(h, p["attn"], cfg, positions=None, causal=False)
        xc = xc + h
        h = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
        xc = xc + L.mlp(h, p["mlp"], cfg.act)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return L.rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, *, positions=None,
            enc_frames=None, vis_embeds=None, mesh=None,
            remat: bool = True):
    """Trunk forward. Returns (final_hidden (B,S,D), aux losses dict)."""
    b, s = tokens.shape
    if positions is None:
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s))
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed_tokens(params, tokens, cfg, vis_embeds)
    enc_out = (_encoder_forward(params, enc_frames, cfg, mesh)
               if cfg.encoder_layers else None)

    def block_body(carry, block_params):
        xc, aux = carry
        xc = (_constrain_seq(xc, mesh, cfg) if cfg.seq_parallel
              else _constrain_batch(xc, mesh))
        for i, spec in enumerate(cfg.block):
            xc, aux = _apply_sublayer(xc, block_params[i], spec, cfg,
                                      positions=positions, mesh=mesh,
                                      enc_out=enc_out, aux=aux)
        return (xc, aux), None

    if remat == "dots":
        # plenty of HBM headroom in most cells: save matmul outputs and
        # recompute only elementwise chains in bwd (SSPerf: removes the
        # full-block fwd recompute)
        body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.dots_saveable)
    elif remat:
        body = jax.checkpoint(block_body)
    else:
        body = block_body
    aux0 = {"lb": jnp.zeros((), jnp.float32), "z": jnp.zeros((), jnp.float32)}
    x = _constrain_batch(x, mesh)
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def forward_logits(params, tokens, cfg: ModelConfig, **kw):
    """Materialized logits — smoke tests / tiny configs only."""
    x, aux = forward(params, tokens, cfg, **kw)
    return logits_fn(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Loss (chunked over tokens so (B,S,vocab) is never materialized)
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, hidden, labels, mask=None,
            mesh=None, seq_chunk: int = 1024):
    """Cross-entropy over the vocab, scanning SEQUENCE chunks so (B,S,vocab)
    is never materialized — peak O(B * chunk * vocab_shard) — while the batch
    dim keeps its data-parallel sharding through the scan."""
    b, s, d = hidden.shape
    w = head_weight(params, cfg)
    hidden = _constrain_batch(hidden, mesh)
    c = min(seq_chunk, s)
    if s % c:
        c = s
    nc = s // c
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    xs = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)     # (nc, B, c, D)
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    ms = jnp.moveaxis(mask.astype(jnp.float32).reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def body(tot, inp):
        xc, lc, mc = inp
        logits = (xc @ w).astype(jnp.float32)                # (B, c, V)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + ((lse - tgt) * mc).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls, ms))
    return total / jnp.maximum(ms.sum(), 1.0)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    lengths: jax.Array       # (B,) tokens already in cache
    layers: tuple            # per pattern position: dict of stacked leaves
    cross: Any = None        # whisper: {"k","v"}: (n_layers, B, enc_seq, KV, hd)


def _attn_cache_cap(spec: LayerSpec, max_seq: int) -> int:
    return min(spec.window, max_seq) if spec.window else max_seq


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int) -> DecodeCache:
    dt = jnp.dtype(cfg.dtype)
    nb = cfg.n_blocks
    layer_caches = []
    for spec in cfg.block:
        if spec.kind == "attn":
            cap = _attn_cache_cap(spec, max_seq)
            layer_caches.append({
                "k": jnp.zeros((nb, batch, cap, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((nb, batch, cap, cfg.n_kv_heads, cfg.head_dim), dt),
                "kpos": jnp.full((nb, batch, cap), KPOS_INVALID, jnp.int32),
            })
        else:
            st = init_mamba_state(batch, cfg)
            layer_caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (nb, *a.shape)), st))
    cross = None
    if cfg.encoder_layers:
        cross = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                            cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                            cfg.n_kv_heads, cfg.head_dim), dt),
        }
    return DecodeCache(lengths=jnp.zeros((batch,), jnp.int32),
                       layers=tuple(layer_caches), cross=cross)


def encode_cross_kv(params, enc_frames, cfg: ModelConfig, mesh=None):
    """Whisper: run the encoder once, project K/V for every decoder layer.

    Returns {"k","v"}: (n_layers, B, enc_seq, KV, hd). Cross-attn assumes a
    homogeneous decoder block (whisper: block = (attn,)).
    """
    if len(cfg.block) != 1 or cfg.block[0].kind != "attn":
        raise NotImplementedError("cross-attn assumes homogeneous decoder block")
    enc_out = _encoder_forward(params, enc_frames, cfg, mesh)
    cross_p = params["blocks"][0]["cross"]          # leaves: (n_layers, ...)

    def kv(pp):
        k, v = L.project_kv(enc_out, pp, cfg, positions=None)
        return {"k": k, "v": v}

    return jax.vmap(kv)(cross_p)


# ---------------------------------------------------------------------------
# Decode step (one new token per sequence)
# ---------------------------------------------------------------------------

def _decode_attn_sublayer(x, p, spec: LayerSpec, cfg: ModelConfig, cache,
                          lengths, positions):
    """x: (B,1,D). cache: {"k","v","kpos"} for THIS layer (no n_blocks dim)."""
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, kvh, hd)
    v = (x @ p["wv"]).reshape(b, 1, kvh, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    cap = cache["k"].shape[1]
    slot = lengths % cap                                   # (B,)
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
    kpos = cache["kpos"].at[bidx, slot].set(lengths)
    out = L.decode_attention(q, k_cache, v_cache, lengths=lengths + 1,
                             softcap=cfg.attn_softcap, kpos=kpos)
    return out @ p["wo"], {"k": k_cache, "v": v_cache, "kpos": kpos}


def decode_step(params, tokens, cache: DecodeCache, cfg: ModelConfig, *,
                positions=None, mesh=None):
    """tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    b = tokens.shape[0]
    lengths = cache.lengths
    if positions is None:
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(lengths[:, None, None], (b, 3, 1))
        else:
            positions = lengths[:, None]
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.rope_theta == 0.0 and cfg.encoder_layers:
        x = x + L.sinusoidal_positions(lengths[:, None], cfg.d_model).astype(x.dtype)

    def block_body(xc, scanned):
        block_params, layer_cache = scanned[0], scanned[1]
        cross_kv = scanned[2] if cfg.encoder_layers else None
        xc = _constrain_batch(xc, mesh)
        new_caches = []
        for i, spec in enumerate(cfg.block):
            p = block_params[i]
            h = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
            if spec.kind == "attn":
                h, nc = _decode_attn_sublayer(h, p["attn"], spec, cfg,
                                              layer_cache[i], lengths, positions)
            else:
                h, nc = mamba_decode_step(h, layer_cache[i], p["attn"], cfg)
            if cfg.post_norms:
                h = L.rmsnorm(h, p["post_norm"], cfg.norm_eps)
            xc = xc + h
            new_caches.append(nc)
            if cross_kv is not None and "cross" in p:
                h = L.rmsnorm(xc, p["cross_norm"], cfg.norm_eps)
                h = L.decode_cross_attention(h, p["cross"], cfg, cross_kv)
                xc = xc + h
            if spec.ffn == "mlp":
                h = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                h = L.mlp(h, p["mlp"], cfg.act)
                if cfg.post_norms:
                    h = L.rmsnorm(h, p["ffn_post_norm"], cfg.norm_eps)
                xc = xc + h
            elif spec.ffn == "moe":
                h = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                h, _ = moe_ffn(h, p["moe"], cfg, mesh=mesh)
                xc = xc + h
        return xc, tuple(new_caches)

    xs = (params["blocks"], cache.layers)
    if cfg.encoder_layers:
        xs = xs + (cache.cross,)
    x, new_layers = jax.lax.scan(block_body, x, xs)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, x, cfg)
    new_cache = DecodeCache(lengths=lengths + 1, layers=new_layers,
                            cross=cache.cross)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: trunk forward + cache construction
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: ModelConfig, max_seq: int, *,
            positions=None, enc_frames=None, vis_embeds=None, mesh=None):
    """Process the prompt, build the decode cache. Returns (last_logits, cache)."""
    b, s = tokens.shape
    if positions is None:
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s))
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = embed_tokens(params, tokens, cfg, vis_embeds)
    enc_out = (_encoder_forward(params, enc_frames, cfg, mesh)
               if cfg.encoder_layers else None)
    aux0 = {"lb": jnp.zeros((), jnp.float32), "z": jnp.zeros((), jnp.float32)}

    def block_body(carry, block_params):
        xc, aux = carry
        xc = _constrain_batch(xc, mesh)
        caches = []
        for i, spec in enumerate(cfg.block):
            p = block_params[i]
            if spec.kind == "attn":
                hpre = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
                k, v = L.project_kv(hpre, p["attn"], cfg, positions)
                cap = _attn_cache_cap(spec, max_seq)
                kc = jnp.zeros((b, cap, cfg.n_kv_heads, cfg.head_dim), k.dtype)
                vc = jnp.zeros_like(kc)
                kp = jnp.full((b, cap), KPOS_INVALID, jnp.int32)
                w = min(s, cap)
                sl = (s - w + jnp.arange(w)) % cap
                kc = kc.at[:, sl].set(k[:, -w:])
                vc = vc.at[:, sl].set(v[:, -w:])
                kp = kp.at[:, sl].set(jnp.broadcast_to(
                    (s - w + jnp.arange(w))[None, :], (b, w)))
                caches.append({"k": kc, "v": vc, "kpos": kp})
                xc, aux = _apply_sublayer(xc, p, spec, cfg, positions=positions,
                                          mesh=mesh, enc_out=enc_out, aux=aux)
            else:
                h = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
                h, state = mamba_chunked(h, p["attn"], cfg, return_state=True)
                # conv tail: rebuild the last (cw-1) conv inputs
                xr = L.rmsnorm(xc, p["norm"], cfg.norm_eps)
                tail = xr[:, -(cfg.ssm_conv - 1):, :]
                conv_x = tail @ p["attn"]["in_x"]
                conv_bc = tail @ p["attn"]["in_bc"]
                caches.append({"conv_x": conv_x.astype(jnp.dtype(cfg.dtype)),
                               "conv_bc": conv_bc.astype(jnp.dtype(cfg.dtype)),
                               "ssm": state})
                if cfg.post_norms:
                    h = L.rmsnorm(h, p["post_norm"], cfg.norm_eps)
                xc = xc + h
                if spec.ffn == "mlp":
                    hh = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                    hh = L.mlp(hh, p["mlp"], cfg.act)
                    if cfg.post_norms:
                        hh = L.rmsnorm(hh, p["ffn_post_norm"], cfg.norm_eps)
                    xc = xc + hh
                elif spec.ffn == "moe":
                    hh = L.rmsnorm(xc, p["ffn_norm"], cfg.norm_eps)
                    hh, losses = moe_ffn(hh, p["moe"], cfg, mesh=mesh)
                    aux = {"lb": aux["lb"] + losses["lb"],
                           "z": aux["z"] + losses["z"]}
                    xc = xc + hh
        return (xc, aux), tuple(caches)

    (x, _aux), layer_caches = jax.lax.scan(block_body, (x, aux0), params["blocks"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1:, :]
    logits = logits_fn(params, last, cfg)
    cross = (encode_cross_kv(params, enc_frames, cfg, mesh)
             if cfg.encoder_layers else None)
    cache = DecodeCache(lengths=jnp.full((b,), s, jnp.int32),
                        layers=layer_caches, cross=cross)
    return logits, cache

"""End-to-end training driver.

Runs on whatever devices exist (CPU: 1): reduced configs train for real;
full configs are for the dry-run meshes. Wires together every substrate:
data pipeline (prefetched), pjit'd train step with the sharding rules, AdamW,
async checkpointing (the paper's flusher/queues), gradient compression on
multi-pod meshes, and restart/resume.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --preset smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import Prefetcher, SyntheticLM, make_global_batch
from repro.distributed.sharding import data_spec, param_specs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim import AdamWState, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation slices (HBM stash / N)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg, max_seq=max(args.seq, 128))
    mesh = make_host_mesh()

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    p_specs = param_specs(params, mesh)
    sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, sh(p_specs))
    opt_specs = AdamWState(step=P(), m=p_specs, v=p_specs,
                           master=p_specs if opt.master is not None else None)
    opt = jax.device_put(opt, sh(opt_specs))

    step_fn = jax.jit(
        make_train_step(cfg, mesh, peak_lr=args.lr, total_steps=args.steps,
                        microbatches=args.microbatches),
        donate_argnums=(0, 1))

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            start_step, (params, opt) = ckpt.restore(
                (params, opt), shardings=(sh(p_specs), sh(opt_specs)))
            start_step += 1
            print(f"resumed from step {start_step - 1}")

    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)
    dspec = data_spec(mesh, args.batch)
    it = Prefetcher(
        ({"step": s, **data.batch(s)} for s in range(start_step, args.steps)),
        depth=4)

    def add_modality_stubs(raw, s):
        """Precomputed frontend stand-ins (assignment: frontends are stubs)."""
        rng_np = np.random.default_rng((args.seed << 16) ^ s)
        if cfg.encoder_layers:
            raw["enc_frames"] = rng_np.normal(
                size=(args.batch, cfg.encoder_seq, cfg.d_model)
            ).astype(np.float32)
        if cfg.vis_tokens:
            raw["vis_embeds"] = rng_np.normal(
                size=(args.batch, cfg.vis_tokens, cfg.d_model)
            ).astype(np.float32)
            raw["positions"] = np.broadcast_to(
                np.arange(args.seq, dtype=np.int32)[None, None, :],
                (args.batch, 3, args.seq)).copy()
        return raw

    t0 = time.time()
    losses = []
    for raw in it:
        s = raw.pop("step")
        raw = add_modality_stubs(raw, s)
        batch = make_global_batch(raw, mesh, P(dspec[0] if len(dspec) else None,
                                               None))
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["ce"]))
        if ckpt and (s + 1) % args.ckpt_every == 0:
            ckpt.save_async(s, (params, opt))
        if (s + 1) % args.log_every == 0 or s == args.steps - 1:
            dt = time.time() - t0
            tok_s = (s - start_step + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {s + 1:5d}  ce={losses[-1]:.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  tok/s={tok_s:,.0f}")
    if ckpt:
        ckpt.save_async(args.steps - 1, (params, opt))
        ckpt.drain()
        print("ckpt stats:", ckpt.stats)
        ckpt.close()
    it.close()
    if len(losses) > 10:
        a, b = float(np.mean(losses[:5])), float(np.mean(losses[-5:]))
        print(f"loss first5={a:.4f} last5={b:.4f} ({'DOWN' if b < a else 'UP'})")
    return losses


if __name__ == "__main__":
    main()

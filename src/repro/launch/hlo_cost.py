"""Loop-aware cost extraction from post-SPMD HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a scan over
80 layers reports 1/80th of the real FLOPs — and the CPU backend's
"bytes accessed" reflects CPU fusion decisions, not TPU ones. The dry-run
needs structural truth, so we parse the partitioned HLO ourselves:

  * computation graph (ENTRY, while bodies/conditions, fusion calls),
  * per-while trip counts (the `constant(N)` in the condition region —
    jax scans always lower to 0..N counted loops),
  * multiplicity roll-up: cost(instr) x prod(trip counts of enclosing loops),
  * FLOPs from `dot` ops: 2 x |output| x prod(contracting dims)   (MXU work;
    elementwise VPU flops are intentionally excluded, documented),
  * collective bytes from all-reduce/all-gather/reduce-scatter/all-to-all/
    collective-permute payload (per-device operand bytes in the partitioned
    module — the per-chip link-roofline numerator),
  * memory-proxy bytes: every materialized tensor's output bytes + dot and
    collective operand reads (fusion bodies count once via the fusion's
    output) — an upper-bound proxy for HBM traffic that is backend-fusion
    independent *at the granularity XLA materialized buffers*.

All shapes in the partitioned module are per-device, so every total this
module returns is per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "iota"}


def _dims(dims_s: str) -> list[int]:
    return [int(d) for d in dims_s.split(",") if d]


def _shapes_bytes(type_s: str) -> int:
    return sum(
        (int(np_prod(_dims(dims))) * _DTYPE_BYTES[dt])
        for dt, dims in _SHAPE_RE.findall(type_s))


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


@dataclass
class Instr:
    name: str
    type_s: str
    opcode: str
    operands: list[str]
    attrs: str
    raw: str = ""

    @property
    def out_bytes(self) -> int:
        return _shapes_bytes(self.type_s)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)       # instr name -> type_s

    @property
    def root(self) -> "Instr | None":
        return self.instrs[-1] if self.instrs else None


_INSTR_RE = re.compile(r"^(?:ROOT )?%?([\w.\-]+) = (.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_ATTR_CALL_RE = re.compile(
    r"(body|condition|calls|to_apply|branch_computations)=\{?%?([\w.\-]+)")


def _split_rhs(rhs: str):
    """rhs = '<type> <opcode>(<operands>)<attrs>' -> (type, opcode, operands, attrs)."""
    if rhs.startswith("("):                      # tuple type: match parens
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_s, rest = rhs[:i + 1], rhs[i + 2:]
    else:
        sp = rhs.index(" ")
        type_s, rest = rhs[:sp], rhs[sp + 1:]
    par = rest.index("(")
    opcode = rest[:par]
    depth = 0
    for j in range(par, len(rest)):
        depth += rest[j] == "("
        depth -= rest[j] == ")"
        if depth == 0:
            break
    operand_s, attrs = rest[par + 1:j], rest[j + 1:]
    operands = re.findall(r"%([\w.\-]+)", operand_s)
    return type_s, opcode, operands, attrs


def parse_hlo(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m or " = " not in line:
            continue
        name, rhs = m.group(1), m.group(2)
        try:
            type_s, opcode, operands, attrs = _split_rhs(rhs)
        except ValueError:
            continue
        cur.instrs.append(Instr(name, type_s, opcode, operands, attrs, rhs))
        cur.shapes[name] = type_s
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Trip count of a jax-lowered counted loop: the max integer constant in
    the condition region (the `i < N` bound of a 0-based counted scan)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_dims = []
    for dt, dims in _SHAPE_RE.findall(ins.type_s):
        out_dims = _dims(dims)
        break
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 2.0 * np_prod(out_dims)          # degenerate dot
    lhs_shape_s = comp.shapes.get(ins.operands[0], "")
    lhs_dims = []
    for dt, dims in _SHAPE_RE.findall(lhs_shape_s):
        lhs_dims = _dims(dims)
        break
    contract = 1
    for d in _dims(m.group(1)):
        if d < len(lhs_dims):
            contract *= lhs_dims[d]
    return 2.0 * np_prod(out_dims) * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    """convolution: 2 x |out| x prod(kernel spatial) x in_channels/groups."""
    out_dims = []
    for dt, dims in _SHAPE_RE.findall(ins.type_s):
        out_dims = _dims(dims)
        break
    if len(ins.operands) < 2:
        return 2.0 * np_prod(out_dims)
    rhs_shape_s = comp.shapes.get(ins.operands[1], "")
    k_dims = []
    for dt, dims in _SHAPE_RE.findall(rhs_shape_s):
        k_dims = _dims(dims)
        break
    groups = 1
    mg = re.search(r"feature_group_count=(\d+)", ins.attrs)
    if mg:
        groups = int(mg.group(1))
    return 2.0 * np_prod(out_dims) * np_prod(k_dims[:-1]) / max(groups, 1) \
        if k_dims else 2.0 * np_prod(out_dims)


def analyze(text: str) -> dict:
    """Returns per-device totals: flops, collective bytes (by op), memory
    proxy bytes, loop info."""
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- multiplicity roll-up over the call graph -------------------------
    mult: dict[str, float] = defaultdict(float)
    fusion_called: set[str] = set()
    loops: list[tuple[str, int]] = []

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for ins in comp.instrs:
            if ins.opcode == "while":
                refs = dict(_ATTR_CALL_RE.findall(ins.attrs))
                trip = _trip_count(comps, refs.get("condition", ""))
                loops.append((ins.name, trip))
                if "body" in refs:
                    visit(refs["body"], m * trip)
                if "condition" in refs:
                    visit(refs["condition"], m * trip)
            else:
                for kind, ref in _ATTR_CALL_RE.findall(ins.attrs):
                    if kind in ("calls", "to_apply", "branch_computations"):
                        fusion_called.add(ref)
                        visit(ref, m)

    visit(entry, 1.0)

    def _inplace_update_bytes(opn: Instr, c: Computation) -> float | None:
        """In-place buffer updates write only their slice: DUS writes the
        update operand; scatter writes the updates operand."""
        if opn.opcode == "dynamic-update-slice" and len(opn.operands) >= 2:
            return _shapes_bytes(c.shapes.get(opn.operands[1], ""))
        if opn.opcode == "scatter" and len(opn.operands) >= 3:
            return _shapes_bytes(c.shapes.get(opn.operands[2], ""))
        return None

    def _materialized_bytes(ins: Instr, comp: Computation) -> float:
        """Output bytes an op physically WRITES on TPU. dynamic-update-slice
        and scatter (scan residual stacking, KV-cache updates) write only
        the update slice in place — including fusions whose root is a
        convert/bitcast/copy chain over one (the CPU backend's bf16
        emulation interposes a whole-buffer convert that TPU never does)."""
        direct = _inplace_update_bytes(ins, comp)
        if direct is not None:
            return direct
        if ins.opcode == "fusion":
            refs = dict(_ATTR_CALL_RE.findall(ins.attrs))
            called = comps.get(refs.get("calls", ""))
            if called and called.root is not None:
                r = called.root
                hops = 0
                while r is not None and hops < 4 and \
                        r.opcode in ("convert", "bitcast", "copy"):
                    nxt = None
                    if r.operands:
                        for cand in called.instrs:
                            if cand.name == r.operands[0]:
                                nxt = cand
                                break
                    r = nxt
                    hops += 1
                if r is not None:
                    b = _inplace_update_bytes(r, called)
                    if b is not None:
                        return b
        return float(ins.out_bytes)

    flops = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_n = {k: 0 for k in _COLLECTIVES}
    mem = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, comp)
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                b = ins.out_bytes
                # all-gather output already includes the gather factor;
                # all-reduce payload = operand size (== output size).
                coll[base] += m * b
                coll_n[base] += int(m)
                mem += m * b
            if name in fusion_called:
                continue                      # fusion bodies: count fusion out
            if ins.opcode in _SKIP_BYTES_OPS:
                continue
            mem += m * _materialized_bytes(ins, comp)
            if ins.opcode == "dot":           # matmul reads both operands
                for op in ins.operands[:2]:
                    mem += m * _shapes_bytes(comp.shapes.get(op, ""))

    return {
        "flops": flops,
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "collective_counts": coll_n,
        "collective_total": int(sum(coll.values())),
        "memory_bytes": mem,
        "loops": loops,
        "n_computations": len(comps),
    }

"""Production mesh builders.

Functions, not module-level constants: importing this module must never touch
jax device state (device count is locked at first backend init, and only the
dry-run forces 512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ``data`` = FSDP + DP + EP, ``model`` = TP/SP, ``pod`` = cross-pod
    DP (gradient all-reduce on slow links; see distributed/collectives.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU: 1 device) — smoke tests and examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))

"""Step functions (train / prefill / decode) bound to a config + mesh.

These are the units the dry-run lowers and the drivers jit. Everything is
pure; distribution comes from in/out shardings (see distributed/sharding.py)
plus the shard_map inside ``moe_ffn``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw_update, cosine_schedule

LB_COEF = 0.01       # MoE load-balance aux weight (switch-transformer default)
Z_COEF = 1e-3        # router z-loss weight


def make_loss_fn(cfg: ModelConfig, mesh=None, remat: bool = True):
    def loss_fn(params, batch):
        hidden, aux = T.forward(
            params, batch["tokens"], cfg,
            positions=batch.get("positions"),
            enc_frames=batch.get("enc_frames"),
            vis_embeds=batch.get("vis_embeds"),
            mesh=mesh, remat=remat)
        ce = T.lm_loss(params, cfg, hidden, batch["labels"],
                       batch.get("mask"), mesh=mesh)
        total = ce + LB_COEF * aux["lb"] + Z_COEF * aux["z"]
        return total, {"ce": ce, "lb": aux["lb"], "z": aux["z"]}
    return loss_fn


def make_train_step(cfg: ModelConfig, mesh=None, *, remat: bool = True,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, weight_decay: float = 0.1,
                    microbatches: int = 1):
    """``microbatches`` > 1 scans gradient accumulation over batch slices:
    the per-slice activation stash shrinks by the same factor (the HBM-fit
    lever for the biggest train cells — see EXPERIMENTS.md §Perf), wire
    bytes and FLOPs are unchanged."""
    loss_fn = make_loss_fn(cfg, mesh, remat)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"ce": jnp.zeros((), jnp.float32),
                  "lb": jnp.zeros((), jnp.float32),
                  "z": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        else:
            (_, metrics), grads = grads_of(params, batch)
        lr = cosine_schedule(opt_state.step, peak_lr=peak_lr, warmup=warmup,
                             total=total_steps)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int, mesh=None):
    def prefill_step(params, batch):
        return T.prefill(params, batch["tokens"], cfg, max_seq,
                         positions=batch.get("positions"),
                         enc_frames=batch.get("enc_frames"),
                         vis_embeds=batch.get("vis_embeds"), mesh=mesh)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, *, sample: bool = False,
                     temperature: float = 1.0):
    def decode_step(params, cache, batch):
        logits, cache = T.decode_step(params, batch["tokens"], cache, cfg,
                                      positions=batch.get("positions"),
                                      mesh=mesh)
        if sample:
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return tok[:, None], cache
        return logits, cache
    return decode_step

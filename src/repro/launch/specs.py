"""ShapeDtypeStruct stand-ins for every model input — the dry-run feed.

``input_specs(arch, shape)`` returns (kwargs for the step fn, batch specs)
without allocating anything. Modality frontends are STUBS per the assignment:
whisper gets precomputed conv frames, qwen2-vl gets precomputed patch
embeddings + (t, h, w) M-RoPE position ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_config
from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
        "mask": SDS((b, s), jnp.float32),
    }
    if cfg.encoder_layers:
        out["enc_frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        out["vis_embeds"] = SDS((b, cfg.vis_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        out["positions"] = SDS((b, 3, s), jnp.int32)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.encoder_layers:
        out["enc_frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        out["vis_embeds"] = SDS((b, cfg.vis_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        out["positions"] = SDS((b, 3, s), jnp.int32)
    return out


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    out = {"tokens": SDS((b, 1), jnp.int32)}
    if cfg.mrope_sections:
        out["positions"] = SDS((b, 3, 1), jnp.int32)
    return out


def params_specs(cfg: ModelConfig):
    """eval_shape of init_params — no allocation."""
    from repro.models.transformer import init_params
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def cache_specs_struct(cfg: ModelConfig, batch: int, max_seq: int):
    from repro.models.transformer import init_decode_cache
    return jax.eval_shape(lambda: init_decode_cache(cfg, batch, max_seq))


def input_specs(arch: str, shape: ShapeConfig) -> dict:
    """All step-fn inputs for one (arch x shape) cell, as ShapeDtypeStructs."""
    cfg = get_config(arch)
    if shape.mode == "train":
        return {"params": params_specs(cfg),
                "batch": train_batch_specs(cfg, shape)}
    if shape.mode == "prefill":
        return {"params": params_specs(cfg),
                "batch": prefill_batch_specs(cfg, shape)}
    return {"params": params_specs(cfg),
            "cache": cache_specs_struct(cfg, shape.global_batch, shape.seq_len),
            "batch": decode_batch_specs(cfg, shape)}

"""Serving driver: continuous batching over the paged KV pool.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --preset smoke \
      --requests 12 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models.transformer import init_params
from repro.serving import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--preset", default="smoke", choices=["smoke"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--num-sets", type=int, default=16)
    ap.add_argument("--set-size", type=int, default=4)
    ap.add_argument("--no-flusher", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      page_size=args.page, num_sets=args.num_sets,
                      set_size=args.set_size,
                      use_flusher=not args.no_flusher)
    rng = np.random.default_rng(args.seed)
    rids = []
    for _ in range(args.requests):
        n = int(rng.integers(4, 48))
        rids.append(eng.submit([int(x) for x in rng.integers(1, cfg.vocab, n)],
                               max_new=args.max_new))
    t0 = time.time()
    eng.run(max_steps=5000)
    dt = time.time() - t0
    done = sum(eng.result(r).state == "done" for r in rids)
    toks = sum(len(eng.result(r).out) for r in rids)
    print(f"{done}/{len(rids)} done, {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")
    print("pool stats:", eng.stats())
    eng.close()
    return eng


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, and extract the roofline terms from the compiled artifact.

No data is allocated: inputs are ShapeDtypeStructs, parameters are
eval_shape'd. Success proves the sharding config is coherent (no mismatched
specs, no unsupported collectives, per-device buffers fit); the printed
memory/cost analysis feeds EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # 40 cells x 2 meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single   # roofline table mesh
"""
import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cells, get_config, ARCH_IDS
from repro.distributed.sharding import cache_specs, data_spec, param_specs
from repro.launch import specs as S
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.optim import AdamWState, adamw_init

# v5e-class hardware constants (per chip) — §Roofline.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

# The CPU backend canonicalizes bf16 -> f32, so every byte count in the
# compiled HLO is 2x what the SAME program moves on TPU (which keeps bf16).
# Bulk traffic (weights, activations, grads, KV, MoE payloads) is bf16 by
# declaration; the f32 remainder (optimizer moments, softmax internals) is a
# small, fused fraction. §Roofline reports TPU-dtype bytes = raw * 0.5 and
# keeps the raw number alongside.
BF16_CANONICALIZATION_CORRECTION = 0.5

def _opt_specs_like(p_specs, opt_struct):
    master = p_specs if opt_struct.master is not None else None
    return AdamWState(step=P(), m=p_specs, v=p_specs, master=master)


def build_cell(arch: str, shape_name: str, mesh, *, remat: bool = True,
               cfg_overrides: dict | None = None, microbatches: int = 1):
    """Returns (jitted_fn, example_args tuple of ShapeDtypeStructs)."""
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    # serving layout for decode: stationary TP weights (no per-token FSDP AG)
    p_specs = param_specs(S.params_specs(cfg), mesh,
                          fsdp=(shape.mode != "decode"))
    dspec = data_spec(mesh, shape.global_batch)
    ins = S.input_specs(arch, shape)

    def batch_specs(batch, dp):
        out = {}
        for k, v in batch.items():
            if k == "positions" and v.ndim == 3:
                out[k] = P(dp[0] if len(dp) else None, None, None)
            elif v.ndim >= 2:
                out[k] = P(dp[0] if len(dp) else None,
                           *([None] * (v.ndim - 1)))
            else:
                out[k] = P()
        return out

    bspecs = batch_specs(ins["batch"], dspec)
    sh = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    if shape.mode == "train":
        step = make_train_step(cfg, mesh, remat=remat,
                               microbatches=microbatches)
        opt_struct = jax.eval_shape(adamw_init, ins["params"])
        opt_specs = _opt_specs_like(p_specs, opt_struct)
        fn = jax.jit(step,
                     in_shardings=(sh(p_specs), sh(opt_specs), sh(bspecs)),
                     out_shardings=(sh(p_specs), sh(opt_specs), None),
                     donate_argnums=(0, 1))
        args = (ins["params"], opt_struct, ins["batch"])
    elif shape.mode == "prefill":
        step = make_prefill_step(cfg, shape.seq_len, mesh)
        cache_struct = S.cache_specs_struct(cfg, shape.global_batch,
                                            shape.seq_len)
        c_specs = cache_specs(cache_struct, mesh, shape.global_batch)
        fn = jax.jit(step,
                     in_shardings=(sh(p_specs), sh(bspecs)),
                     out_shardings=(None, sh(c_specs)))
        args = (ins["params"], ins["batch"])
    else:
        step = make_decode_step(cfg, mesh)
        c_specs = cache_specs(ins["cache"], mesh, shape.global_batch)
        fn = jax.jit(step,
                     in_shardings=(sh(p_specs), sh(c_specs), sh(bspecs)),
                     out_shardings=(None, sh(c_specs)),
                     donate_argnums=(1,))
        args = (ins["params"], ins["cache"], ins["batch"])
    return fn, args, cfg, shape


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             out_dir: Path | None = None, remat: bool = True,
             verbose: bool = True, cfg_overrides: dict | None = None,
             tag: str = "", microbatches: int = 1) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh:
        fn, args, cfg, shape = build_cell(arch, shape_name, mesh, remat=remat,
                                          cfg_overrides=cfg_overrides,
                                          microbatches=microbatches)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:       # CPU backend may not implement it
            mem_d = {"error": str(e)}
        hlo = compiled.as_text()
        struct = hlo_analyze(hlo)    # loop-aware: flops/bytes x trip counts

    corr = BF16_CANONICALIZATION_CORRECTION
    flops = float(struct["flops"])              # per-device (partitioned HLO)
    bytes_raw = float(struct["memory_bytes"])
    bytes_acc = bytes_raw * corr                # TPU-dtype bytes
    coll = {"bytes": {k: int(v * corr)
                      for k, v in struct["collective_bytes"].items()},
            "counts": struct["collective_counts"],
            "total_bytes": int(struct["collective_total"] * corr),
            "raw_f32_total_bytes": struct["collective_total"]}
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW

    # useful-FLOPs yardstick
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tok = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tok
    elif shape.mode == "prefill":
        tok = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tok
    else:
        tok = shape.global_batch
        model_flops = 2 * n_active * tok
    model_flops_per_dev = model_flops / n_chips

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips, "mode": shape.mode, "tag": tag,
        "overrides": cfg_overrides or {},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "bytes_per_device_raw_f32": bytes_raw,
        "collectives": coll,
        "memory": mem_d,
        "loops": struct["loops"],
        "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops_per_device": model_flops_per_dev,
        "useful_flop_ratio": (model_flops_per_dev / flops) if flops else None,
    }
    if verbose:
        r = res["roofline"]
        print(f"[{arch} x {shape_name} x {mesh_kind}] chips={n_chips} "
              f"compile={t_compile:.0f}s flops/dev={flops:.3e} "
              f"bytes/dev={bytes_acc:.3e} coll/dev={coll['total_bytes']:.3e}B "
              f"| compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']} "
              f"| useful={res['useful_flop_ratio'] and round(res['useful_flop_ratio'], 3)}")
        if mem_d.get("peak_bytes"):
            print(f"    peak={mem_d['peak_bytes']/2**30:.2f} GiB/dev "
                  f"args={mem_d['argument_bytes']/2**30:.2f} GiB/dev")
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        (out_dir / f"{arch}_{shape_name}_{mesh_kind}{suffix}.json").write_text(
            json.dumps(res, indent=1, default=float))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch in ARCH_IDS:
            for shp in cells(arch):
                todo.extend((arch, shp, m) for m in meshes)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch, shp, m in todo:
        try:
            run_cell(arch, shp, m, out_dir=out_dir,
                     remat=not args.no_remat)
        except Exception as e:
            failures.append((arch, shp, m, repr(e)[:300]))
            print(f"FAIL [{arch} x {shp} x {m}]: {e!r}"[:500])
    print(f"\n{len(todo) - len(failures)}/{len(todo)} cells passed")
    if failures:
        for f in failures:
            print("  FAIL", *f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Distributed-optimization tricks: compressed cross-pod gradient reduction.

At 1000+ node scale the inter-pod links (DCN or long ICI hops) are the
gradient-allreduce bottleneck: the intra-pod reduction runs at full ICI
bandwidth while the pod axis crawls. The standard trick — int8 gradient
compression with error feedback — is applied ONLY to the pod axis:

    within-pod: full-precision psum over ("data",)        (fast links)
    cross-pod:  quantize int8 (per-row scale) + error feedback,
                all_gather over "pod" + local dequant-sum  (slow links)

Bytes on the slow links drop ~2x for bf16 grads (int8 payload + f16-scale
sidecar vs a bf16 ring all-reduce) and 4x vs f32, at a quantization error
that error feedback folds into the next step (Seide et al., 1-bit SGD
lineage). Used by ``launch/train.py`` under ``--compress-grads``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh


class CompressionState(NamedTuple):
    """Error-feedback residual, one leaf per gradient leaf."""

    residual: Any


def init_compression(grads: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


def _quantize(x: jax.Array):
    """Symmetric per-tensor-row int8. x: f32 (..., d)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ef_leaf(g: jax.Array, res: jax.Array, axis: str):
    """Error-feedback compressed psum of one leaf over ``axis``."""
    x = g.astype(jnp.float32) + res
    flat = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    q, scale = _quantize(flat)
    new_res = (flat - _dequantize(q, scale)).reshape(x.shape)
    # all_gather int8 + local dequant-sum == lossless-after-quantization AR
    qg = jax.lax.all_gather(q, axis)                 # (pods, rows, d)
    sg = jax.lax.all_gather(scale, axis)
    summed = (qg.astype(jnp.float32) * sg).sum(axis=0)
    return summed.reshape(x.shape).astype(g.dtype), new_res


def cross_pod_grad_reduce(grads: Any, state: CompressionState, mesh: Mesh,
                          *, data_axis: str = "data", pod_axis: str = "pod",
                          compress: bool = True):
    """Mean-reduce grads over (pod, data): full precision within a pod,
    int8 + error feedback across pods. Returns (grads, new_state).

    Call inside shard_map (or any SPMD context) where grads are replicated
    per (pod, data) shard — i.e. after jax.grad over the local batch.
    """
    n_pod = mesh.shape.get(pod_axis, 1)
    n_data = mesh.shape.get(data_axis, 1)

    def leaf(g, r):
        g = jax.lax.psum(g, data_axis)
        if n_pod == 1:
            return g / n_data, r
        if not compress:
            return jax.lax.psum(g, pod_axis) / (n_data * n_pod), r
        s, new_r = _ef_leaf(g, r, pod_axis)
        return s / (n_data * n_pod), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    new_grads = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return new_grads, CompressionState(residual=new_res)

"""Sharding rules: parameter/cache pytrees -> PartitionSpec trees.

Strategy (DESIGN.md §4):
  * FSDP (ZeRO-3) over the ``data`` axis — and over ``("pod", "data")`` on the
    multi-pod mesh — on the *non*-TP dimension of every matmul weight;
  * tensor parallelism over ``model``: attention heads / d_ff / d_inner /
    vocab;
  * MoE expert dim over ``data`` (EP), d_ff over ``model`` — matching the
    shard_map specs inside ``moe_ffn``;
  * small vectors (norms, biases, A_log, ...) replicated.

Rules are path-based so they survive arbitrary nesting (stacked blocks add a
leading ``n_blocks`` dim -> every spec gets a ``None`` prepended when the
leaf has one more dim than its rule).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    """Logical roles of mesh axes. fsdp may span several physical axes."""

    fsdp: tuple[str, ...] = ("data",)
    tp: str = "model"
    ep: str = "data"

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "MeshAxes":
        if "pod" in mesh.shape:
            return cls(fsdp=("pod", "data"))
        return cls()


# (path regex, spec builder). First match wins. ``F`` = fsdp axes, ``T`` = tp.
def _rules(ax: MeshAxes):
    F, T = ax.fsdp, ax.tp
    E = ax.ep
    return [
        (r"embed$", P(T, F)),                     # (V, D): vocab over TP
        (r"lm_head$", P(F, T)),
        (r"\b(wq|wk|wv)$", P(F, T)),              # (D, H*hd)
        (r"\bwo$", P(T, F)),                      # (H*hd, D)
        (r"\b(w_gate|w_up)$", P(F, T)),           # dense mlp (D, F)
        (r"\bw_down$", P(T, F)),                  # (F, D)
        (r"moe/router$", P()),                    # (D, E) small
        (r"moe/(w_gate|w_up)$", P(E, None, T)),   # (E, D, F)
        (r"moe/w_down$", P(E, T, None)),          # (E, F, D)
        (r"\b(in_x|in_z|in_dt)$", P(F, T)),       # mamba: d_inner/heads over TP
        (r"\bin_bc$", P(F, None)),                # shared B/C: replicated cols
        (r"\bconv_x_w$", P(None, T)),
        (r"\bconv_x_b$", P(T)),
        (r"\bout_proj$", P(T, F)),                # (d_inner, D)
        (r"", P()),                               # norms, scalars, the rest
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path_s: str, ndim: int, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path_s):
            if len(spec) > ndim:      # rule for a 2D weight hit a 1D leaf etc.
                spec = P(*spec[-ndim:]) if ndim else P()
            pad = ndim - len(spec)
            return P(*([None] * pad), *spec) if pad else spec
    return P()


def _fix_divisibility(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop (or shrink) axis assignments that don't divide the dim evenly
    (e.g. whisper's vocab 51865 can't shard 16 ways)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        size = 1
        for a in axes:
            n = mesh.shape[a]
            if shape[i] % (size * n) == 0:
                kept.append(a)
                size *= n
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """PartitionSpec pytree for a model parameter pytree.

    ``fsdp=False`` (serving layout): weights stay TP-sharded over ``model``
    but REPLICATED over the data axes — no per-step weight all-gather on the
    decode critical path (§Perf: 6.5 GB/token saved on gemma2 decode_32k).
    Training keeps ZeRO-3 FSDP (weights resident 1/(data*pod), gathered per
    layer inside the scan)."""
    ax = MeshAxes.for_mesh(mesh)
    rules = _rules(ax)

    def leaf_spec(path, leaf):
        spec = _spec_for(_path_str(path), leaf.ndim, rules)
        if not fsdp:
            spec = P(*[None if entry is not None and
                       set(entry if isinstance(entry, tuple) else (entry,))
                       <= set(ax.fsdp) else entry
                       for entry in spec])
        return _fix_divisibility(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def data_spec(mesh: Mesh, batch: int) -> P:
    """Token batch spec: batch over every data-parallel axis that divides."""
    ax = MeshAxes.for_mesh(mesh)
    dp = [a for a in ax.fsdp]
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if batch % size == 0 and size > 1:
        return P(tuple(dp))
    if batch % mesh.shape[dp[-1]] == 0:
        return P(dp[-1])
    return P()


def cache_specs(cache: Any, mesh: Mesh, batch: int) -> Any:
    """Decode-cache specs. Rank-based rules over stacked leaves:

      (nb, B, S, KV, hd)  attn KV      -> batch over dp, seq over tp
      (nb, B, S)          kpos         -> same
      (nb, B, H, N, hd)   ssm state    -> batch over dp, heads over tp
      (nb, B, cw-1, C)    conv state   -> batch over dp, channels over tp
      (B,)                lengths      -> replicated

    For global_batch == 1 (long_500k) the KV sequence dim takes every mesh
    axis instead — all 256/512 chips cooperate on one sequence
    (flash-decoding-style sequence parallelism, GSPMD inserts the combine).
    """
    ax = MeshAxes.for_mesh(mesh)
    dp = tuple(ax.fsdp)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = ax.tp
    tp_size = mesh.shape[tp]
    batch_ax = dp if (batch % dp_size == 0 and dp_size > 1) else None

    def leaf_spec(path, leaf):
        name = _path_str(path)
        nd = leaf.ndim
        if name.endswith("lengths"):
            return P()
        seq_ax: Any = tp
        if batch_ax is None:
            seq_ax = (*dp, tp)
        if re.search(r"(^|/)(k|v)$", name) and nd == 5:
            s = leaf.shape[2]
            if s % (tp_size if batch_ax is not None else dp_size * tp_size) == 0:
                return P(None, batch_ax, seq_ax, None, None)
            return P(None, batch_ax, None, None, None)
        if name.endswith("kpos") and nd == 3:
            s = leaf.shape[2]
            if s % (tp_size if batch_ax is not None else dp_size * tp_size) == 0:
                return P(None, batch_ax, seq_ax)
            return P(None, batch_ax, None)
        if name.endswith("ssm") and nd == 5:
            h = leaf.shape[2]
            return P(None, batch_ax, tp if h % tp_size == 0 else None, None, None)
        if name.endswith("conv_x") and nd == 4:
            c = leaf.shape[3]
            return P(None, batch_ax, None, tp if c % tp_size == 0 else None)
        if name.endswith("conv_bc") and nd == 4:
            return P(None, batch_ax, None, None)
        if re.search(r"cross", name) and nd == 5:
            return P(None, batch_ax, None, None, None)
        # fallback: batch over dp when a dim matches
        return P(*[batch_ax if leaf.shape[i] == batch and i < 2 else None
                   for i in range(nd)])

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def shape_shardings(specs: Any, mesh: Mesh) -> Any:
    """Attach NamedShardings to a spec tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))

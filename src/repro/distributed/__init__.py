from .sharding import (MeshAxes, cache_specs, data_spec, param_specs,
                       shape_shardings)
from .collectives import (CompressionState, cross_pod_grad_reduce,
                          init_compression)

__all__ = ["MeshAxes", "cache_specs", "data_spec", "param_specs",
           "shape_shardings", "CompressionState", "cross_pod_grad_reduce",
           "init_compression"]

"""AdamW with global-norm clipping, cosine schedule, optional f32 master copy.

Pure JAX, pytree-shaped like the params; optimizer state inherits the params'
shardings (same tree structure -> same PartitionSpecs), so FSDP shards the
moments automatically (ZeRO-style).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Optional[Any] = None     # f32 weights when params are bf16


def adamw_init(params: Any, master_fp32: bool = True) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = None
    if master_fp32 and any(p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      master=master)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def cosine_schedule(step: jax.Array, *, peak_lr: float, warmup: int,
                    total: int, floor: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: jax.Array | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, pm):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + eps)
        base = pm if pm is not None else p.astype(jnp.float32)
        decay = weight_decay if p.ndim >= 2 else 0.0   # no decay on norms
        new_master = base - lr * (update + decay * base)
        return m_new, v_new, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    flat_pm = (treedef.flatten_up_to(state.master)
               if state.master is not None else [None] * len(flat_p))
    out = [upd(g, m, v, p, pm)
           for g, m, v, p, pm in zip(flat_g, flat_m, flat_v, flat_p, flat_pm)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_masters = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                              new_masters, params)
    new_master = new_masters if state.master is not None else None
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, AdamWState(step, new_m, new_v, new_master), metrics

"""Array layout subsystem (core/raid.py): stripe mapping algebra, the RAID-5
parity state machine (RMW / full-stripe coalescing / catch-up), degraded
mode, rebuild traffic, and the end-to-end ArraySim/ShardedArraySim
integration."""
import numpy as np
import pytest

from repro.core.gc_sim import FTL, ArraySim, SSDParams, Workload
from repro.core.raid import (JBODLayout, Raid0Layout, Raid5Layout,
                             RebuildSource, StripeMap, layout_from_name)
from repro.core.sharded import ShardedArraySim
from repro.core.workloads import (OP_READ, OP_REBUILD, OP_TRIM, OP_WRITE, Op)

SMALL = SSDParams(capacity_pages=4096)


# ---------------------------------------------------------------------------
# StripeMap: pure address algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,group,parity", [
    (6, 3, True), (18, 6, True), (12, 12, True),
    (6, 3, False), (18, 6, False), (8, 4, False),
])
def test_stripe_map_is_a_bijection(n, group, parity):
    sm = StripeMap(n, group, parity)
    seen = set()
    for l in range(sm.data_members() * 40):
        ssd, r = sm.locate(l)
        assert 0 <= ssd < n and r >= 0
        assert (ssd, r) not in seen          # no two logical pages collide
        seen.add((ssd, r))
        g, rr, i = sm.row_of(l)
        assert sm.logical(g, rr, i) == l     # row_of/logical are inverses
        assert g * group <= ssd < (g + 1) * group   # stays in its group


def test_stripe_map_rows_use_distinct_members():
    sm = StripeMap(18, 6, parity=True)
    for g in range(sm.n_groups):
        for r in range(40):
            members = [ssd for ssd, _, _ in sm.row_members(g, r)]
            assert len(set(members)) == 6    # d data + 1 parity, all distinct
            assert sm.parity_member(g, r) in members
    # parity rotates over every member of the group
    assert {sm.parity_member(0, r) % 6 for r in range(6)} == set(range(6))


def test_stripe_map_rejects_bad_shapes():
    with pytest.raises(ValueError):
        StripeMap(10, 6, parity=True)        # group doesn't divide n
    with pytest.raises(ValueError):
        StripeMap(4, 2, parity=True)         # RAID-5 needs >= 3 members


# ---------------------------------------------------------------------------
# RAID-5 planner: parity state machine
# ---------------------------------------------------------------------------

def _planner(n=18, group=6, w=1, degraded=0, rebuild=False, rows=128):
    return Raid5Layout(stripe_width=w, group=group, degraded=degraded,
                       rebuild=rebuild).make_planner(n, rows)


def test_small_write_is_two_reads_two_writes():
    pl = _planner()
    plan, detached = pl.plan(Op(37, False))
    assert detached is None
    reads, writes = plan.phases
    assert len(reads) == 2 and all(k == OP_READ for _, _, k in reads)
    assert len(writes) == 2 and all(k == OP_WRITE for _, _, k in writes)
    assert pl.stats["rmw_ops"] == 1 and pl.stats["parity_writes"] == 1
    # the four children hit exactly two SSDs (data member + parity member)
    ssds = {s for s, _, _ in reads} | {s for s, _, _ in writes}
    assert len(ssds) == 2


def test_sequential_run_coalesces_into_full_stripes():
    pl = _planner()
    d = pl.smap.d
    for l in range(4 * d):                   # four rows, one page at a time
        pl.plan(Op(l, False))
    st = pl.stats
    assert st["rmw_ops"] == 1                # only the very first write
    assert st["full_stripe_rows"] == 4
    # steady state: d data writes + 1 parity per row (plus the one RMW)
    assert st["parity_writes"] == 4 + 1
    assert st["child_reads"] == 2            # just the first RMW's reads
    # long-run parity WA approaches (d+1)/d
    assert st["child_writes"] / st["logical_writes"] < 1.5


def test_full_width_aligned_write_skips_rmw_immediately():
    pl = _planner(w=5)                       # stripe_width == d
    plan, _ = pl.plan(Op(0, False))
    assert len(plan.phases) == 1             # no read phase
    assert len(plan.phases[0]) == 6          # 5 data + parity
    assert pl.stats["full_stripe_rows"] == 1 and pl.stats["rmw_ops"] == 0


def test_broken_run_gets_catchup_parity_plan():
    pl = _planner()
    d = pl.smap.d
    base = 3 * d                             # row 3: half-write then abandon
    for l in range(base, base + 2):
        pl.plan(Op(l, False))
    # the second write deferred its parity (continued run from row start)
    assert pl.stats["deferred_writes"] >= 1
    flushed = pl.flush()
    assert len(flushed) == 1
    catchup = flushed[0]
    assert not catchup.measured
    # reads the d-2 unwritten data pages, then writes the parity page
    assert [len(p) for p in catchup.phases] == [d - 2, 1]
    assert pl.stats["catchup_rows"] == 1


def test_eviction_emits_detached_catchup():
    import repro.core.raid as raid
    pl = _planner()
    d = pl.smap.d
    # open a deferred row with an ascending 2-write run at row 0
    pl.plan(Op(0, False))
    pl.plan(Op(1, False))
    # start > _MAX_RUNS distinct runs elsewhere to evict the first
    detached_seen = []
    for j in range(raid._MAX_RUNS + 4):
        lba = (10 + 2 * j) * d + 2           # never contiguous, never row 0
        _, det = pl.plan(Op(lba, False))
        if det:
            detached_seen.extend(det)
    assert detached_seen, "evicting an open run must emit catch-up parity"
    assert all(not p.measured for p in detached_seen)


def test_run_collision_preserves_catchup_parity():
    """Regression: a run keyed at the same next-expected page as an existing
    run (re-write of the run's last page, converging cursors) used to clobber
    that run's state, silently dropping its open deferred row — the row's
    parity was never written."""
    pl = _planner()
    pl.plan(Op(0, False))
    pl.plan(Op(1, False))                    # run keyed at 2, row 0 deferred
    _, detached = pl.plan(Op(1, False))      # new run collides at key 2
    assert detached, "displaced run's open row must emit catch-up parity"
    assert all(not p.measured for p in detached)
    assert pl.stats["catchup_rows"] == 1


def test_degraded_read_reconstructs_from_survivors():
    pl = _planner(group=6, degraded=1)
    sm = pl.smap
    dead = 5                                 # last member of group 0
    hit = miss = None
    for l in range(200):
        ssd, _ = sm.locate(l)
        g = ssd // 6
        if g == 0 and ssd == dead and hit is None:
            hit = l
        elif g == 0 and ssd != dead and miss is None:
            miss = l
        if hit is not None and miss is not None:
            break
    # read of a live page: one child
    plan, _ = pl.plan(Op(miss, True))
    assert [len(p) for p in plan.phases] == [1]
    # read of a dead page: all 5 survivors of the row
    plan, _ = pl.plan(Op(hit, True))
    assert [len(p) for p in plan.phases] == [5]
    assert {s for s, _, _ in plan.phases[0]}.isdisjoint({dead})
    assert pl.stats["degraded_reads"] == 1


def test_degraded_write_variants():
    pl = _planner(group=6, degraded=1)
    sm = pl.smap
    dead_local = 5
    # classify logical pages of group 0 by their row's dead-member role
    target_dead = parity_dead = normal = None
    for l in range(400):
        g, r, i = sm.row_of(l)
        if g != 0:
            continue
        ssd = sm.data_member(g, r, i)
        dead_ssd = g * 6 + dead_local
        p_dead = sm.parity_member(g, r) == dead_ssd
        if ssd == dead_ssd:
            target_dead = target_dead if target_dead is not None else l
        elif p_dead:
            parity_dead = parity_dead if parity_dead is not None else l
        else:
            normal = normal if normal is not None else l
        if None not in (target_dead, parity_dead, normal):
            break
    # normal RMW still works when both data target and parity are live
    plan, _ = pl.plan(Op(normal, False))
    assert [len(p) for p in plan.phases] == [2, 2]
    # parity on the dead member: plain data write, no parity upkeep
    plan, _ = pl.plan(Op(parity_dead, False))
    assert [len(p) for p in plan.phases] == [1]
    assert plan.phases[0][0][2] == OP_WRITE
    # data target on the dead member: reconstruct parity from the d-1
    # untouched pages, write parity only (the lost write lands in parity)
    plan, _ = pl.plan(Op(target_dead, False))
    assert [len(p) for p in plan.phases] == [4, 1]
    assert plan.phases[1][0][0] == sm.parity_member(*sm.row_of(target_dead)[:2])


def test_rebuild_plans_read_survivors_write_spare():
    pl = _planner(group=6, degraded=1, rebuild=True, rows=64)
    src = RebuildSource()
    op = src.next_op(0.0)
    assert op.kind == OP_REBUILD
    plan, det = pl.plan(op)
    assert det is None and not plan.measured
    reads, writes = plan.phases
    assert len(reads) == 5 and len(writes) == 1
    dead = {5, 11, 17}
    assert {s for s, _, _ in reads}.isdisjoint(dead)
    assert writes[0][0] in dead and writes[0][2] == OP_WRITE
    # the counter walks every group and wraps rows
    targets = set()
    for _ in range(3 * 64 * 3):
        p, _ = pl.plan(src.next_op(0.0))
        targets.add(p.phases[1][0][0])
    assert targets == dead


def test_trim_plan_invalidates_without_parity():
    pl = _planner()
    plan, _ = pl.plan(Op(7, False, kind=OP_TRIM))
    assert [len(p) for p in plan.phases] == [1]
    assert plan.phases[0][0][2] == OP_TRIM
    assert pl.stats["trims"] == 1 and pl.stats["parity_writes"] == 0
    # the skipped parity update is a counted modeling gap, not a silent one
    assert pl.stats["trim_parity_skipped"] == 1


def test_trim_parity_skipped_surfaces_in_results():
    """RAID-5 TRIMs skip the parity update (mapping-only cost model); the
    skip count must surface end-to-end as ArrayResults.trim_parity_skipped
    (and stay zero when parity is dead on the trimmed row or on layouts
    without parity)."""
    wl = Workload(w_total=48, qd_per_ssd=32, n_streams=6, trim_frac=0.3)
    r = ArraySim(6, SMALL, 0.6, wl, seed=2, layout=Raid5Layout(group=6)
                 ).run(4000)
    assert r.trims > 0
    # planner-side count (at plan time) tracks the FTL-side trims (at
    # service time) up to in-flight boundary effects
    assert r.trim_parity_skipped > 0
    r0 = ArraySim(6, SMALL, 0.6, wl, seed=2,
                  layout=Raid0Layout(stripe_width=2, group=6)).run(2000)
    assert r0.trim_parity_skipped == 0


def test_layout_spec_validation():
    Raid0Layout(group=6).make_planner(18, 64)       # valid shape
    with pytest.raises(ValueError):
        # degraded RAID-0 is data loss, not a scenario
        from repro.core.raid import _Raid0Planner
        from repro.core.raid import StripeMap as SM
        _Raid0Planner(SM(18, 6, False), 64, 4, degraded=1)
    with pytest.raises(ValueError):
        Raid5Layout(group=7).make_planner(18, 64)   # 7 doesn't divide 18
    with pytest.raises(ValueError):
        layout_from_name("raid6")
    with pytest.raises(TypeError):
        ArraySim(6, SMALL, 0.6, layout="raid5")     # spec object required
    assert isinstance(layout_from_name("raid5", group=6), Raid5Layout)


# ---------------------------------------------------------------------------
# XOR reconstruction property (hypothesis)
# ---------------------------------------------------------------------------

def _apply_writes_with_shadow(pl, script, ftls=None, ftl_params=None):
    """Drive the planner with a write/trim script, maintaining a shadow
    value store with XOR parity exactly as the emitted plans dictate, and
    optionally pushing every member page write through real FTLs with GC
    interleaved. Returns (data shadow {lba: value}, member shadow
    {(ssd, mlba): value})."""
    sm = pl.smap
    data: dict[int, int] = {}
    member: dict[tuple[int, int], int] = {}

    def member_write(ssd, mlba):
        if ftls is not None:
            ftl = ftls[ssd]
            ftl.user_write(mlba)
            while ftl.need_gc() and not ftl.gc_satisfied():
                ftl.gc_reclaim_one()

    def apply_plan(plan, targets):
        # member values before this plan's writes (for the RMW delta)
        old_vals = {loc: member.get(loc, 0) for loc in targets}
        reads = {(ssd, mlba) for phase in plan.phases[:-1]
                 for ssd, mlba, kind in phase if kind == OP_READ}
        for phase in plan.phases:
            for ssd, mlba, kind in phase:
                if kind == OP_TRIM:
                    if ftls is not None:
                        ftls[ssd].trim(mlba)
                    continue
                if kind != OP_WRITE:
                    continue
                member_write(ssd, mlba)
                if (ssd, mlba) in targets:
                    member[(ssd, mlba)] = targets[(ssd, mlba)]
                elif (ssd, mlba) in reads:
                    # RMW: delta against the STORED parity, exactly as the
                    # controller computes it — if a deferred parity write
                    # was ever silently dropped, the staleness propagates
                    # and the reconstruction check below fails
                    acc = member.get((ssd, mlba), 0)
                    for loc, newv in targets.items():
                        acc ^= old_vals[loc] ^ newv
                    member[(ssd, mlba)] = acc
                else:
                    # full-stripe close / catch-up: recompute from the data
                    # (the controller holds the run's partial parity and
                    # reads the rest — same resulting value)
                    g = ssd // sm.group
                    acc = 0
                    for i in range(sm.d):
                        acc ^= data.get(sm.logical(g, mlba, i), 0)
                    member[(ssd, mlba)] = acc

    for lba, value, trim in script:
        if trim:
            plan, detached = pl.plan(Op(lba, False, kind=OP_TRIM))
            # trim drops the data (parity intentionally not updated)
            apply_plan(plan, {})
            for d in detached or ():
                apply_plan(d, {})
            data.pop(lba, None)
            member.pop(sm.locate(lba), None)
            continue
        plan, detached = pl.plan(Op(lba, False))
        for d in detached or ():
            apply_plan(d, {})            # catch-up parity BEFORE the new op
        data[lba] = value
        apply_plan(plan, {sm.locate(lba): value})
    for d in pl.flush():
        apply_plan(d, {})
    return data, member


_XOR_N, _XOR_GROUP, _XOR_ROWS = 6, 3, 64
_XOR_PARAMS = SSDParams(capacity_pages=512, pages_per_block=16,
                        gc_low_blocks=3, gc_high_blocks=5)
_XOR_DATA_PAGES = (_XOR_N // _XOR_GROUP) * (_XOR_GROUP - 1) * _XOR_ROWS


def _check_xor_script(script):
    """After ANY interleaving of writes (random and sequential, any stripe),
    XOR of the surviving members of every touched row must equal the lost
    member's page — for every possible lost member — while member FTLs run
    real GC underneath."""
    pl = Raid5Layout(group=_XOR_GROUP).make_planner(_XOR_N, _XOR_ROWS)
    sm = pl.smap
    rng = np.random.default_rng(0)
    ftls = [FTL(_XOR_PARAMS, rng) for _ in range(_XOR_N)]
    for f in ftls:
        f.prefill(_XOR_ROWS / _XOR_PARAMS.capacity_pages, churn=False)
    data, member = _apply_writes_with_shadow(pl, script, ftls=ftls)
    # every written member page still resolves through its FTL after GC
    for (ssd, mlba) in member:
        assert ftls[ssd].lba_loc[mlba] >= 0
    # reconstruction: for every touched row and every lost member,
    # XOR of the survivors equals the lost page
    touched = {sm.row_of(l)[:2] for l in data}
    for g, r in touched:
        if any(t and sm.row_of(l)[:2] == (g, r) for l, _, t in script):
            continue                      # parity is stale by design on TRIM
        vals = {}
        for ssd, mlba, is_par in sm.row_members(g, r):
            if is_par:
                vals[ssd] = member.get((ssd, mlba), 0)
            else:
                # data value by logical address (0 if never written)
                loc_i = next(i for i in range(sm.d)
                             if sm.data_member(g, r, i) == ssd)
                vals[ssd] = data.get(sm.logical(g, r, loc_i), 0)
        total = 0
        for v in vals.values():
            total ^= v
        assert total == 0, f"row {(g, r)} parity inconsistent"
        for lost, v in vals.items():
            acc = 0
            for o, ov in vals.items():
                if o != lost:
                    acc ^= ov
            assert acc == v


def test_xor_reconstruction_deterministic():
    """Fixed scripts covering the planner's branch space: pure sequential
    (full-stripe closes), pure random (RMW), broken runs mid-row
    (flush/catch-up parity), trims, and a heavy mixed churn."""
    rng = np.random.default_rng(3)
    d = _XOR_GROUP - 1
    scripts = [
        [(l, l + 1, False) for l in range(8 * d)],           # sequential
        [(0, 5, False), (1, 6, False), (2 * d + 1, 9, False)],  # broken run
        [(int(rng.integers(_XOR_DATA_PAGES)),
          int(rng.integers(1, 2**30)),
          bool(rng.random() < 0.15)) for _ in range(200)],   # random + trim
        [(l % _XOR_DATA_PAGES, l * 7 + 1, False)
         for l in range(300)],                               # wrapping seq
        [(0, 3, False), (1, 4, False), (1, 5, False),        # run collision:
         (2, 6, False), (7, 8, False)],                      # rewrite of the
                                                             # run's last page
    ]
    for script in scripts:
        _check_xor_script(script)


def test_xor_reconstruction_property():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    script_st = st.lists(
        st.tuples(st.integers(0, _XOR_DATA_PAGES - 1),
                  st.integers(1, 2**30),
                  st.booleans()),
        min_size=1, max_size=120)

    @settings(max_examples=20, deadline=None)
    @given(script=script_st)
    def check(script):
        _check_xor_script(script)

    check()


# ---------------------------------------------------------------------------
# End-to-end: ArraySim / ShardedArraySim integration
# ---------------------------------------------------------------------------

def test_raid5_small_writes_have_parity_wa_two():
    r = ArraySim(6, SMALL, 0.6, Workload(w_total=96, qd_per_ssd=64,
                                         n_streams=6), seed=1,
                 layout=Raid5Layout(group=6)).run(4000)
    assert r.layout == "raid5"
    assert r.parity_wa == pytest.approx(2.0, abs=0.05)
    assert r.array_wa == pytest.approx(r.parity_wa * r.gc_wa)
    assert r.rmw_ops > 0 and r.full_stripe_rows == 0
    assert r.stripe_stall_p99 > 0.0
    assert r.p50_latency <= r.p95_latency <= r.p99_latency


def test_raid5_sequential_coalescing_lowers_parity_wa():
    uni = ArraySim(6, SMALL, 0.6, Workload(w_total=96, qd_per_ssd=64,
                                           n_streams=6), seed=1,
                   layout=Raid5Layout(group=6)).run(4000)
    seq = ArraySim(6, SMALL, 0.6, Workload(w_total=96, qd_per_ssd=64,
                                           n_streams=6, scenario="sequential",
                                           seq_streams=4), seed=1,
                   layout=Raid5Layout(group=6)).run(4000)
    assert seq.full_stripe_rows > 0
    assert seq.parity_wa < uni.parity_wa * 0.75
    # (d+1)/d = 1.2 for group=6 plus first-row RMW noise
    assert seq.parity_wa == pytest.approx(1.2, abs=0.1)


def test_raid0_fans_out_and_tracks_stall():
    r = ArraySim(6, SMALL, 0.6, Workload(w_total=96, qd_per_ssd=64,
                                         n_streams=6), seed=1,
                 layout=Raid0Layout(stripe_width=4, group=6)).run(4000)
    assert r.layout == "raid0"
    assert r.parity_wa == 1.0                # no parity
    assert r.stripe_stall_p99 > 0.0          # but stripes still synchronize
    assert r.iops > 0


def test_degraded_raid5_runs_and_reconstructs():
    # pure reads: the degraded comparison is strictly directional there
    # (reconstruction fans 1 read into 5; degraded WRITES can actually get
    # cheaper — parity-dead rows skip the RMW — so a mixed workload is not)
    wl = Workload(w_total=96, qd_per_ssd=64, n_streams=6, read_frac=1.0)
    healthy = ArraySim(6, SMALL, 0.6, wl, seed=1,
                       layout=Raid5Layout(group=6)).run(4000)
    degraded = ArraySim(6, SMALL, 0.6, wl, seed=1,
                        layout=Raid5Layout(group=6, degraded=1)).run(4000)
    assert degraded.degraded_reads > 0
    assert degraded.iops < healthy.iops      # reconstruction costs throughput
    # a mixed workload still reconstructs
    mixed = ArraySim(6, SMALL, 0.6,
                     Workload(w_total=96, qd_per_ssd=64, n_streams=6,
                              read_frac=0.5), seed=1,
                     layout=Raid5Layout(group=6, degraded=1)).run(4000)
    assert mixed.degraded_reads > 0


def test_rebuild_traffic_competes_with_foreground():
    wl = Workload(w_total=96, qd_per_ssd=64, n_streams=6, read_frac=0.5)
    base = ArraySim(6, SMALL, 0.6, wl, seed=1,
                    layout=Raid5Layout(group=6, degraded=1)).run(4000)
    reb = ArraySim(6, SMALL, 0.6, wl, seed=1,
                   layout=Raid5Layout(group=6, degraded=1,
                                      rebuild=True)).run(4000)
    assert reb.rebuild_rows > 0
    # the spare (dead member, index 5) serves rebuild writes — it is idle
    # without the rebuild tenant
    assert base.per_ssd_iops[5] == 0.0
    assert reb.per_ssd_iops[5] > 0.0
    # rebuild traffic is background load, NOT parity amplification: the
    # foreground WA split must not move when the rebuild tenant turns on
    assert reb.parity_wa == pytest.approx(base.parity_wa, rel=0.05)


def test_degraded_trim_on_dead_member_does_not_stall():
    """Regression: a TRIM whose only target page lives on the failed member
    used to produce an empty plan that never completed, leaking the stream's
    window slot until every stream stalled and the run returned garbage."""
    r = ArraySim(6, SMALL, 0.6,
                 Workload(w_total=48, qd_per_ssd=16, n_streams=6,
                          trim_frac=0.3), seed=2,
                 layout=Raid5Layout(group=6, degraded=1)).run(3000)
    assert r.iops > 0.0
    assert r.trims > 0


def test_layout_run_zero_ops_is_noop():
    r = ArraySim(6, SMALL, 0.6, Workload(w_total=8, qd_per_ssd=4,
                                         n_streams=2), seed=0,
                 layout=Raid5Layout(group=6)).run(0)
    assert r.events == 0 and r.iops == 0.0


def test_layout_runs_are_deterministic():
    kw = dict(ssd=SMALL, occupancy=0.6,
              workload=Workload(w_total=96, qd_per_ssd=32, n_streams=6))
    a = ArraySim(6, seed=11, layout=Raid5Layout(group=6), **kw).run(3000)
    b = ArraySim(6, seed=11, layout=Raid5Layout(group=6), **kw).run(3000)
    assert a.iops == b.iops and a.p99_latency == b.p99_latency
    assert a.stripe_stall_p99 == b.stripe_stall_p99
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)


def test_sharded_raid5_serial_equals_parallel():
    """Stripe-group partitioning: the worker-process path must be
    bit-identical to the same decomposition run in-process."""
    wl = Workload(w_total=12 * 16, qd_per_ssd=16, n_streams=12)
    lay = Raid5Layout(group=6)
    a = ShardedArraySim(12, SMALL, 0.6, wl, seed=5, n_shards=2,
                        parallel=True, layout=lay).run(6000)
    b = ShardedArraySim(12, SMALL, 0.6, wl, seed=5, n_shards=2,
                        parallel=False, layout=lay).run(6000)
    assert a.iops == b.iops
    assert a.p99_latency == b.p99_latency
    assert a.stripe_stall_p99 == b.stripe_stall_p99
    assert a.parity_wa == b.parity_wa
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    np.testing.assert_array_equal(a.gc_pause_frac, b.gc_pause_frac)


def test_sharded_respects_stripe_groups():
    wl = Workload(w_total=64, qd_per_ssd=16, n_streams=4)
    s = ShardedArraySim(12, SMALL, 0.6, wl, n_shards=5,
                        layout=Raid5Layout(group=6))
    assert s.sizes == [6, 6]                 # whole groups only
    with pytest.raises(ValueError):
        ShardedArraySim(10, SMALL, 0.6, wl, layout=Raid5Layout(group=6))
    # ungrouped RAID-5 couples the whole array -> one shard
    assert ShardedArraySim(6, SMALL, 0.6, wl,
                           layout=Raid5Layout()).sizes == [6]


def test_jbod_layout_pins_pr2_golden():
    """Passing JBODLayout explicitly must reproduce the PR 2 golden — the
    fast path is untouched by the layout subsystem."""
    from tests.test_golden_determinism import GOLDEN_ARRAY_UNIFORM, P
    r = ArraySim(3, P, 0.6, Workload(w_total=96, qd_per_ssd=32, n_streams=3),
                 seed=42, layout=JBODLayout()).run(6000)
    assert r.iops == GOLDEN_ARRAY_UNIFORM["iops"]
    assert r.p99_latency == GOLDEN_ARRAY_UNIFORM["p99"]
    assert [float(x) for x in r.per_ssd_iops] == GOLDEN_ARRAY_UNIFORM["per_ssd"]
    assert r.layout == "jbod" and r.parity_wa == 1.0


# ---------------------------------------------------------------------------
# TRIM groundwork
# ---------------------------------------------------------------------------

def test_ftl_trim_invalidates_mapping():
    rng = np.random.default_rng(0)
    ftl = FTL(SMALL, rng)
    ftl.prefill(0.5, churn=False)
    lba = 123
    loc = ftl.lba_loc[lba]
    assert loc >= 0
    before = ftl.valid_count[loc // SMALL.pages_per_block]
    ftl.trim(lba)
    assert ftl.lba_loc[lba] == -1
    assert ftl.page_lba[loc] == -1
    assert ftl.valid_count[loc // SMALL.pages_per_block] == before - 1
    assert ftl.trims == 1
    ftl.trim(lba)                            # idempotent on unmapped LBAs
    assert ftl.trims == 1
    ftl.user_write(lba)                      # re-mapping works
    assert ftl.lba_loc[lba] >= 0


def test_trim_aware_gc_lowers_write_amplification():
    """The arXiv:1208.1794 story: trimming invalidates pages before GC can
    copy them, so GC-WA drops."""
    was = []
    for trim in (False, True):
        rng = np.random.default_rng(1)
        ftl = FTL(SMALL, rng)
        ftl.prefill(0.8)
        for _ in range(20000):
            lba = int(rng.integers(ftl.live_lbas))
            if trim and rng.random() < 0.3:
                ftl.trim(lba)
            else:
                ftl.user_write(lba)
            while ftl.need_gc() and not ftl.gc_satisfied():
                ftl.gc_reclaim_one()
        was.append((ftl.writes + ftl.gc_copies) / max(ftl.writes, 1))
    assert was[1] < was[0]


def test_trim_frac_emits_trims_without_perturbing_at_zero():
    from repro.core.workloads import UniformSource
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    plain = UniformSource(1000, rng_a, read_frac=0.3)
    zero = UniformSource(1000, rng_b, read_frac=0.3, trim_frac=0.0)
    ops_a = [plain.next_op(0.0) for _ in range(500)]
    ops_b = [zero.next_op(0.0) for _ in range(500)]
    assert ops_a == ops_b                    # no extra RNG draw at 0.0
    src = UniformSource(1000, np.random.default_rng(8), trim_frac=0.25)
    ops = [src.next_op(0.0) for _ in range(2000)]
    trims = [o for o in ops if o.kind == OP_TRIM]
    assert 0.15 < len(trims) / len(ops) < 0.35
    assert all(o.op_kind() == OP_TRIM and not o.is_read for o in trims)


def test_trim_flows_through_array_sim():
    r = ArraySim(2, SMALL, 0.7,
                 Workload(w_total=64, qd_per_ssd=32, trim_frac=0.3),
                 seed=3).run(8000)
    assert r.trims > 0
    base = ArraySim(2, SMALL, 0.7,
                    Workload(w_total=64, qd_per_ssd=32), seed=3).run(8000)
    assert base.trims == 0
    assert r.gc_wa < base.gc_wa              # trim-aware GC-WA measurable


@pytest.mark.slow
def test_full_raid_sweep_checks_pass(tmp_path):
    """Nightly: the full 18-SSD JBOD/RAID-0/RAID-5 sweep (the committed
    BENCH_raid.json tier) must pass every built-in check — parity WA > 1 on
    RAID-5 small writes, sequential coalescing lowering it, stripe stall
    rising under active GC, degraded mode costing throughput."""
    import json
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "BENCH_raid.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.raid_sweep", "--out", str(out)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out.read_text())
    assert payload["all_checks_pass"]
    assert payload["n_ssds"] >= 18 and len(payload["qd_sweep"]) >= 3


def test_op_kind_resolution_back_compat():
    assert Op(5, True).op_kind() == OP_READ
    assert Op(5, False).op_kind() == OP_WRITE
    assert Op(5, False, kind=OP_TRIM).op_kind() == OP_TRIM
    assert Op(5, False).kind == -1           # default stays AUTO
    # positional construction used across the codebase still works
    lba, is_read = Op(9, True)[:2]
    assert (lba, is_read) == (9, True)

"""Docs-consistency: the root README's artifact index must cover every
committed benchmark artifact (the front door may not silently rot as PRs
add BENCH files)."""
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _artifacts():
    return sorted(p.name for p in ROOT.glob("BENCH_*")
                  if p.suffix in (".json", ".jsonl"))


def test_readme_exists_with_required_sections():
    readme = ROOT / "README.md"
    assert readme.exists(), "repo front door missing: README.md"
    text = readme.read_text()
    for heading in ("## Quickstart", "## Architecture map",
                    "## Benchmark artifacts", "## Determinism contract"):
        assert heading in text, f"README.md lost its '{heading}' section"
    assert "benchmarks/README.md" in text


def test_every_committed_bench_artifact_is_indexed():
    arts = _artifacts()
    assert arts, "no BENCH_* artifacts at the repo root?"
    text = (ROOT / "README.md").read_text()
    missing = [a for a in arts if a not in text]
    assert not missing, (
        f"committed artifacts absent from the README index: {missing} — "
        "add a row to the 'Benchmark artifacts' table")


def test_index_rows_point_at_real_producer_modules():
    """Each producer named in the index table is a real benchmarks/ module
    (catches renames that would orphan a table row)."""
    import re
    text = (ROOT / "README.md").read_text()
    block = text.split("## Benchmark artifacts")[1].split("\n## ")[0]
    rows = re.findall(r"^\| `(BENCH_[\w.]+)` \| `(\w+)` \|", block,
                      flags=re.M)
    assert rows, "no artifact rows parsed from the index table"
    for artifact, producer in rows:
        assert (ROOT / "benchmarks" / f"{producer}.py").exists(), (
            f"README row for {artifact} names producer '{producer}' but "
            f"benchmarks/{producer}.py does not exist")

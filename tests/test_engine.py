"""Shared discrete-event engine: loop ordering, latency stats, and the
multi-slot NCQ device model (service overlap + GC preemption)."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.engine import DeviceModel, EventLoop, LatencyRecorder


def test_event_loop_orders_by_time_then_fifo():
    loop = EventLoop()
    order = []
    loop.at(2.0, lambda: order.append("b"))
    loop.at(1.0, lambda: order.append("a"))
    loop.at(2.0, lambda: order.append("c"))     # same time: FIFO
    while loop.step():
        pass
    assert order == ["a", "b", "c"]
    assert loop.now == 2.0


def test_event_loop_schedule_is_relative():
    loop = EventLoop()
    times = []
    loop.at(1.0, lambda: loop.schedule(0.5, lambda: times.append(loop.now)))
    while loop.step():
        pass
    assert times == [1.5]


def test_latency_recorder_percentiles():
    rec = LatencyRecorder()
    for v in range(1, 101):
        rec.record(float(v))
    s = rec.summary()
    assert s.n == 100
    assert s.mean == pytest.approx(50.5)
    assert s.p50 == pytest.approx(50.5)
    assert s.p95 <= s.p99 <= 100.0
    rec.reset()
    assert rec.summary().n == 0


class FakeFTL:
    def __init__(self):
        self.gc_needed = False

    def need_gc(self):
        return self.gc_needed


class FakeServer:
    """Duck-typed SSDServer: params + FTL + GC episode + accounting."""

    def __init__(self, channels=2, device_slots=4, gc_len=5.0):
        self.p = SimpleNamespace(channels=channels, device_slots=device_slots)
        self.ftl = FakeFTL()
        self.in_gc = False
        self.gc_time = 0.0
        self.busy_time = 0.0
        self._gc_len = gc_len

    def gc_episode_time(self):
        self.ftl.gc_needed = False
        return self._gc_len


def _device(server, reqs, dt=1.0):
    loop = EventLoop()
    pending = list(reqs)
    done = []
    dev = DeviceModel(loop, server,
                      pull=lambda: pending.pop(0) if pending else None,
                      service_time=lambda r: dt,
                      on_done=lambda r: done.append((r, loop.now)))
    return loop, dev, done


def test_channels_service_concurrently():
    """4 unit-time requests on 2 channels finish at t=1,1,2,2 — makespan 2,
    not 4 (the old fluid model had no service overlap at all)."""
    server = FakeServer(channels=2, device_slots=4)
    loop, dev, done = _device(server, ["a", "b", "c", "d"])
    dev.kick()
    while loop.step():
        pass
    assert [t for _, t in done] == [1.0, 1.0, 2.0, 2.0]
    assert server.busy_time == pytest.approx(4.0)   # channel-seconds


def test_queue_depth_bounds_overlap():
    """With only one request ever outstanding, channels cannot overlap:
    throughput degrades to 1/t_op — queue depth is a real lever."""
    server = FakeServer(channels=4, device_slots=8)
    loop = EventLoop()
    done = []
    backlog = ["a", "b", "c"]
    holder = []

    def pull():
        # closed loop with window 1: refill only after completion
        if holder and backlog is not None:
            return holder.pop()
        return None

    dev = DeviceModel(loop, server, pull=pull,
                      service_time=lambda r: 1.0,
                      on_done=lambda r: (done.append((r, loop.now)),
                                         holder.append(backlog.pop(0))
                                         if backlog else None))
    holder.append("first")
    dev.kick()
    while loop.step():
        pass
    assert [t for _, t in done] == [1.0, 2.0, 3.0, 4.0]


def test_ncq_admission_cap():
    server = FakeServer(channels=1, device_slots=2)
    loop, dev, done = _device(server, list("abcdef"))
    dev.kick()
    assert dev.occupancy == 2          # device_slots, not the whole backlog
    while loop.step():
        pass
    assert len(done) == 6


def test_gc_preempts_all_channels():
    """GC waits for in-flight ops to drain, then blocks every channel for the
    whole episode; queued requests resume afterwards."""
    server = FakeServer(channels=2, device_slots=8, gc_len=5.0)
    loop, dev, done = _device(server, list("abcd"))
    dev.kick()                          # a, b in service
    server.ftl.gc_needed = True         # trips while channels busy
    while loop.step():
        pass
    times = [t for _, t in done]
    assert times[:2] == [1.0, 1.0]      # in-flight ops drain first
    assert times[2:] == [7.0, 7.0]      # 1 (drain) + 5 (episode) + 1 (service)
    assert server.gc_time == pytest.approx(5.0)
    # episode charged on all channels
    assert server.busy_time == pytest.approx(4.0 + 5.0 * 2)


def test_gc_runs_even_with_empty_queue():
    server = FakeServer(channels=2, device_slots=4, gc_len=3.0)
    server.ftl.gc_needed = True
    loop, dev, done = _device(server, [])
    dev.kick()
    while loop.step():
        pass
    assert server.gc_time == pytest.approx(3.0)
    assert not dev.in_gc

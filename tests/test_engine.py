"""Shared discrete-event engine: loop ordering, latency stats, and the
multi-slot NCQ device model (service overlap + GC preemption), plus the
slotted-record fast path (payload events, free-list reuse, stop-flag run,
cached latency summaries, batch admission/offer)."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.engine import DeviceModel, EventLoop, LatencyRecorder, \
    MeasurementWindow


def test_event_loop_orders_by_time_then_fifo():
    loop = EventLoop()
    order = []
    loop.at(2.0, lambda: order.append("b"))
    loop.at(1.0, lambda: order.append("a"))
    loop.at(2.0, lambda: order.append("c"))     # same time: FIFO
    while loop.step():
        pass
    assert order == ["a", "b", "c"]
    assert loop.now == 2.0


def test_event_loop_schedule_is_relative():
    loop = EventLoop()
    times = []
    loop.at(1.0, lambda: loop.schedule(0.5, lambda: times.append(loop.now)))
    while loop.step():
        pass
    assert times == [1.5]


def test_payload_events_no_closures():
    """call/call_at dispatch handler(payload): the hot path schedules bound
    methods + payload records, never per-event lambdas."""
    loop = EventLoop()
    got = []
    loop.call_at(1.0, got.append, "a")
    loop.call(2.0, got.append, "b")      # relative: fires at 2.0
    loop.call_at(1.5, got.append, "c")
    while loop.step():
        pass
    assert got == ["a", "c", "b"]
    assert loop.processed == 3


def test_event_slot_free_list_reuse():
    """Slots recycle: a schedule/dispatch steady state must not grow the
    record arrays beyond the peak number of simultaneously pending events."""
    loop = EventLoop()
    state = {"n": 0}

    def tick(payload):
        state["n"] += 1
        if state["n"] < 500:
            loop.call(1.0, tick, payload)

    loop.call(1.0, tick, ())
    loop.run()
    assert state["n"] == 500
    assert len(loop._handlers) == 1      # one pending event at any time
    assert loop._free == [0]


def test_stop_ends_run_after_current_handler():
    loop = EventLoop()
    got = []

    def handler(x):
        got.append(x)
        if x == 2:
            loop.stop()
        got.append(("post", x))          # handler still finishes

    for i in range(5):
        loop.call_at(float(i), handler, i)
    n = loop.run()
    assert n == 3                        # events 0,1,2 ran; 3,4 did not
    assert got[-1] == ("post", 2)
    assert loop.run() == 2               # resumes with the remaining events


def test_measurement_window_target_stops_loop():
    loop = EventLoop()
    mw = MeasurementWindow(loop, warmup=2, on_begin=lambda: None, target=5)
    done = []

    def complete(i):
        done.append(i)
        mw.note_completion(t_issue=0.0)

    for i in range(10):
        loop.call_at(float(i), complete, i)
    loop.run()
    assert len(done) == 5                # stopped at the target, not the heap
    assert mw.measuring and len(mw.latency) == 3   # completions 3,4,5


def test_latency_recorder_percentiles():
    rec = LatencyRecorder()
    for v in range(1, 101):
        rec.record(float(v))
    s = rec.summary()
    assert s.n == 100
    assert s.mean == pytest.approx(50.5)
    assert s.p50 == pytest.approx(50.5)
    assert s.p95 <= s.p99 <= 100.0
    rec.reset()
    assert rec.summary().n == 0


def test_latency_summary_cached_no_rescan(monkeypatch):
    """Repeated summary() calls must not rescan the sample buffer: the
    percentile pass runs once per dirty state, and record() invalidates."""
    import repro.core.engine as engine_mod
    calls = {"n": 0}
    real = np.percentile

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(engine_mod.np, "percentile", counting)
    rec = LatencyRecorder()
    for v in range(100):
        rec.record(float(v))
    s1 = rec.summary()
    s2 = rec.summary()
    s3 = rec.summary()
    assert calls["n"] == 1 and s1 is s2 is s3
    rec.record(1000.0)                   # invalidates the cache
    s4 = rec.summary()
    assert calls["n"] == 2 and s4.n == 101
    rec.reset()
    assert rec.summary().n == 0 and calls["n"] == 2   # empty: no percentile


def test_latency_recorder_buffer_growth():
    """The float64 buffer doubles past its preallocated capacity without
    losing samples."""
    rec = LatencyRecorder(capacity=16)
    for v in range(1000):
        rec.record(float(v))
    assert len(rec) == 1000
    vals = rec.values()
    assert vals.dtype == np.float64 and vals.shape == (1000,)
    np.testing.assert_array_equal(vals, np.arange(1000.0))
    assert rec.summary().p50 == pytest.approx(499.5)


class FakeFTL:
    def __init__(self):
        self.gc_needed = False

    def need_gc(self):
        return self.gc_needed


class FakeServer:
    """Duck-typed SSDServer: params + FTL + GC episode + accounting."""

    def __init__(self, channels=2, device_slots=4, gc_len=5.0):
        self.p = SimpleNamespace(channels=channels, device_slots=device_slots)
        self.ftl = FakeFTL()
        self.in_gc = False
        self.gc_time = 0.0
        self.busy_time = 0.0
        self._gc_len = gc_len

    def gc_episode_time(self):
        self.ftl.gc_needed = False
        return self._gc_len


def _device(server, reqs, dt=1.0):
    loop = EventLoop()
    pending = list(reqs)
    done = []
    dev = DeviceModel(loop, server,
                      pull=lambda: pending.pop(0) if pending else None,
                      service_time=lambda r: dt,
                      on_done=lambda r: done.append((r, loop.now)))
    return loop, dev, done


def test_channels_service_concurrently():
    """4 unit-time requests on 2 channels finish at t=1,1,2,2 — makespan 2,
    not 4 (the old fluid model had no service overlap at all)."""
    server = FakeServer(channels=2, device_slots=4)
    loop, dev, done = _device(server, ["a", "b", "c", "d"])
    dev.kick()
    while loop.step():
        pass
    assert [t for _, t in done] == [1.0, 1.0, 2.0, 2.0]
    assert server.busy_time == pytest.approx(4.0)   # channel-seconds


def test_queue_depth_bounds_overlap():
    """With only one request ever outstanding, channels cannot overlap:
    throughput degrades to 1/t_op — queue depth is a real lever."""
    server = FakeServer(channels=4, device_slots=8)
    loop = EventLoop()
    done = []
    backlog = ["a", "b", "c"]
    holder = []

    def pull():
        # closed loop with window 1: refill only after completion
        if holder and backlog is not None:
            return holder.pop()
        return None

    dev = DeviceModel(loop, server, pull=pull,
                      service_time=lambda r: 1.0,
                      on_done=lambda r: (done.append((r, loop.now)),
                                         holder.append(backlog.pop(0))
                                         if backlog else None))
    holder.append("first")
    dev.kick()
    while loop.step():
        pass
    assert [t for _, t in done] == [1.0, 2.0, 3.0, 4.0]


def test_ncq_admission_cap():
    server = FakeServer(channels=1, device_slots=2)
    loop, dev, done = _device(server, list("abcdef"))
    dev.kick()
    assert dev.occupancy == 2          # device_slots, not the whole backlog
    while loop.step():
        pass
    assert len(done) == 6


def test_gc_preempts_all_channels():
    """GC waits for in-flight ops to drain, then blocks every channel for the
    whole episode; queued requests resume afterwards."""
    server = FakeServer(channels=2, device_slots=8, gc_len=5.0)
    loop, dev, done = _device(server, list("abcd"))
    dev.kick()                          # a, b in service
    server.ftl.gc_needed = True         # trips while channels busy
    while loop.step():
        pass
    times = [t for _, t in done]
    assert times[:2] == [1.0, 1.0]      # in-flight ops drain first
    assert times[2:] == [7.0, 7.0]      # 1 (drain) + 5 (episode) + 1 (service)
    assert server.gc_time == pytest.approx(5.0)
    # episode charged on all channels
    assert server.busy_time == pytest.approx(4.0 + 5.0 * 2)


def test_gc_runs_even_with_empty_queue():
    server = FakeServer(channels=2, device_slots=4, gc_len=3.0)
    server.ftl.gc_needed = True
    loop, dev, done = _device(server, [])
    dev.kick()
    while loop.step():
        pass
    assert server.gc_time == pytest.approx(3.0)
    assert not dev.in_gc


def test_offer_fast_path_matches_kick():
    """offer() (zero-backlog direct admission) must produce the same service
    schedule as append-to-host-queue + kick(): same completion times, same
    NCQ cap, False once the NCQ is full."""
    server = FakeServer(channels=2, device_slots=4)
    loop = EventLoop()
    done = []
    dev = DeviceModel(loop, server, pull=lambda: None,
                      service_time=lambda r: 1.0,
                      on_done=lambda r: done.append((r, loop.now)))
    assert all(dev.offer(r) for r in "abcd")       # fills the 4 NCQ slots
    assert dev.offer("e") is False                 # NCQ full
    assert dev.occupancy == 4
    while loop.step():
        pass
    assert [t for _, t in done] == [1.0, 1.0, 2.0, 2.0]   # as via kick()
    assert server.busy_time == pytest.approx(4.0)


def test_offer_defers_to_gc():
    """offer during a pending-GC drain admits but must not start service."""
    server = FakeServer(channels=2, device_slots=8, gc_len=5.0)
    loop = EventLoop()
    done = []
    dev = DeviceModel(loop, server, pull=lambda: None,
                      service_time=lambda r: 1.0,
                      on_done=lambda r: done.append((r, loop.now)))
    dev.offer("a")
    dev.offer("b")                      # both in service
    server.ftl.gc_needed = True
    assert dev.offer("c")               # admitted, service blocked by GC
    assert dev.in_service == 2 and len(dev.admitted) == 1
    while loop.step():
        pass
    times = [t for _, t in done]
    assert times[:2] == [1.0, 1.0]
    assert times[2] == 7.0              # drain(1) + episode(5) + service(1)


def test_kick_skips_pull_when_backlog_empty():
    """With a backlog container attached, kick() must not call pull() while
    the backlog is empty (the per-completion fast path)."""
    server = FakeServer(channels=1, device_slots=2)
    loop = EventLoop()
    backlog = []
    pulls = {"n": 0}

    def pull():
        pulls["n"] += 1
        return backlog.pop(0) if backlog else None

    dev = DeviceModel(loop, server, pull=pull, service_time=lambda r: 1.0,
                      on_done=lambda r: None, backlog=backlog)
    dev.kick()
    assert pulls["n"] == 0              # empty backlog: pull never called
    backlog.append("a")
    dev.kick()
    assert pulls["n"] >= 1
    while loop.step():
        pass
    assert server.busy_time == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Calendar-queue far-bucket edges (two-level near/far structure)
# ---------------------------------------------------------------------------

def _calibrate_loop(dt=1e-3):
    """Drive enough scheduling deltas through an EventLoop to trip width
    self-calibration (``_CALIB_SAMPLES`` positive deltas)."""
    from repro.core.engine import _CALIB_SAMPLES
    loop = EventLoop()
    for k in range(_CALIB_SAMPLES + 1):
        loop.at((k + 1) * dt, lambda: None)
    assert loop._inv_w > 0.0            # calibrated
    return loop


def test_far_bucket_events_far_beyond_near_window():
    """Events scheduled far beyond the current near window land in far
    buckets and still dispatch in exact (time, seq) order."""
    loop = _calibrate_loop()
    order = []
    # far-future events, deliberately out of order, spanning many buckets
    for t in (5.0, 0.5, 50.0, 2.0, 0.9, 50.0):
        loop.at(t, lambda t=t: order.append((t, loop.now)))
    assert loop._far                    # at least one far bucket exists
    while loop.step():
        pass
    assert [t for t, _ in order] == [0.5, 0.9, 2.0, 5.0, 50.0, 50.0]
    assert all(t == now for t, now in order)
    assert not loop._far and not loop._bheap    # fully drained


def test_far_bucket_width_calibration_deterministic():
    """Two loops fed identical event streams must calibrate to the same
    bucket width and the same calendar shape (the determinism contract:
    calendar shape is a pure function of the event stream)."""
    def feed(loop):
        # irregular but fixed deltas, then a far-future burst
        t = 0.0
        for k in range(200):
            t += 1e-4 * (1 + (k * 7) % 13)
            loop.at(t, lambda: None)
        for k in range(50):
            loop.at(10.0 + k * 1e-3, lambda: None)
    a, b = EventLoop(), EventLoop()
    feed(a)
    feed(b)
    assert a._inv_w == b._inv_w and a._inv_w > 0.0
    assert a._cur == b._cur
    assert sorted(a._far) == sorted(b._far)
    assert [len(a._far[i]) for i in sorted(a._far)] == \
           [len(b._far[i]) for i in sorted(b._far)]
    na, nb = 0, 0
    while a.step():
        na += 1
    while b.step():
        nb += 1
    assert na == nb == 250
    assert a.now == b.now


def test_far_bucket_drain_order_same_time_bursts():
    """A burst of same-time events inside one far bucket drains FIFO (the
    seq tie-break survives the bucket's deferred sort), interleaved exactly
    with distinct-time events in the same bucket."""
    loop = _calibrate_loop()
    width = 1.0 / loop._inv_w
    # pick a time safely inside a single far bucket
    base = (loop._cur + 10) * width + 0.25 * width
    order = []
    for k in range(8):
        loop.at(base, lambda k=k: order.append(("burst", k)))
    loop.at(base + 0.1 * width, lambda: order.append(("later", 0)))
    loop.at(base - 0.1 * width, lambda: order.append(("earlier", 0)))
    for k in range(8, 16):
        loop.at(base, lambda k=k: order.append(("burst", k)))
    while loop.step():
        pass
    assert order[0] == ("earlier", 0)
    assert order[-1] == ("later", 0)
    assert [k for tag, k in order if tag == "burst"] == list(range(16))


def test_far_bucket_insert_after_promotion_stays_exact():
    """A handler scheduling into the already-promoted current bucket must
    insort into the live near list, not a stale far bucket."""
    loop = _calibrate_loop()
    width = 1.0 / loop._inv_w
    base = (loop._cur + 5) * width + 0.2 * width
    order = []

    def chain():
        order.append("first")
        # same bucket, later time — near list is the promoted bucket now
        loop.at(base + 0.3 * width, lambda: order.append("chained"))

    loop.at(base, chain)
    loop.at(base + 0.5 * width, lambda: order.append("tail"))
    while loop.step():
        pass
    assert order == ["first", "chained", "tail"]

"""Threaded IOExecutor regressions (no hypothesis needed — these must run in
the minimal tier-1 environment).

The big one: ``DualQueue.pop_next`` fires the ``refill`` callback inline, and
workers call ``pop_next`` while holding the per-device condition lock. A
refill callback that re-enters ``IOExecutor.submit`` on the same device used
to self-deadlock on the non-reentrant lock; the executor now defers the
callback until the lock is released.
"""
import threading

from repro.core.io_queues import HIGH, LOW, IOExecutor, IORequest


def test_refill_callback_can_resubmit_same_device():
    """A stale discard triggers refill; the refill submits replacement work to
    the SAME device. Pre-fix this deadlocked (drain timed out)."""
    done = []
    ex = IOExecutor(1, lambda dev, payload: done.append(payload),
                    max_inflight=2, reserved=0)
    refilled = threading.Event()

    def refill():
        if not refilled.is_set():        # one replacement is enough
            refilled.set()
            assert ex.submit(0, IORequest(payload="refilled", priority=LOW))

    ex.set_refill(0, refill)
    ex.submit(0, IORequest(payload="stale", priority=LOW,
                           is_stale=lambda p: True))
    assert refilled.wait(10.0), "refill callback never ran (deadlock?)"
    assert ex.drain(10.0)
    ex.shutdown()
    assert done == ["refilled"]
    assert ex.stats(0).discarded_stale == 1


def test_refill_runs_even_when_queue_drains_empty():
    """pop_next returning None after discarding stales must still trigger the
    deferred refill (the executor cannot sit in cv.wait on work the refill
    would produce)."""
    done = []
    ex = IOExecutor(1, lambda dev, payload: done.append(payload),
                    max_inflight=1, reserved=0)
    calls = []
    ex.set_refill(0, lambda: calls.append(1))
    for i in range(3):
        ex.submit(0, IORequest(payload=i, priority=LOW, is_stale=lambda p: True))
    assert ex.drain(10.0)
    ex.shutdown()
    assert done == []
    assert calls, "refill was recorded but never invoked"


def test_on_complete_can_resubmit_same_device():
    """Completion callbacks run outside the device lock, so chained
    submissions (the SAFS follow-on pattern) are safe under the executor."""
    done = []
    ex = IOExecutor(1, lambda dev, payload: done.append(payload),
                    max_inflight=1, reserved=0)
    chained = threading.Event()

    def chain(_payload):
        if not chained.is_set():
            chained.set()
            ex.submit(0, IORequest(payload="second", priority=HIGH))

    ex.submit(0, IORequest(payload="first", priority=LOW, on_complete=chain))
    assert chained.wait(10.0)
    assert ex.drain(10.0)
    ex.shutdown()
    assert done == ["first", "second"]

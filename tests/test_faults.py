"""Fault injection + host-side resilience (core/faults.py, ISSUE 7).

Three layers of guarantees:

* ``faults=None`` is BYTE-IDENTICAL to the pre-fault path on every run loop
  (fast JBOD, layout, qos, SAFS) — pinned against goldens captured
  immediately before the fault wiring landed.
* A faulted run is deterministic, and serial == sharded stays bit-identical
  with a ``FaultPolicy`` attached (fault domains are single devices, so
  ``slice_policy`` remaps them per shard without changing the decomposition).
* The defenses do what they claim: bounded retries, crash -> degraded ->
  rebuild -> heal, hedges fire and win, the detector quarantines the slow
  member, the SAFS flusher defers (never drops) writebacks to sick devices.
"""
import pytest

from repro.core.faults import Crash, FailSlow, FaultInjector, FaultPolicy, \
    MediaError, RetryPolicy, merge_fault_stats, slice_policy
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.raid import Raid0Layout, Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.sharded import ShardedArraySim, ShardedSAFSSim

from test_golden_determinism import GOLDEN_ARRAY_UNIFORM

P = SSDParams(capacity_pages=4096)


# ---------------------------------------------------------------------------
# validation: conflicting/out-of-range knobs fail fast with named errors
# ---------------------------------------------------------------------------

class TestValidation:
    def test_crash_on_jbod_rejected(self):
        pol = FaultPolicy(events=(Crash(device=0, at_time=0.01),))
        with pytest.raises(ValueError, match="jbod.*no parity"):
            ArraySim(3, P, 0.6, Workload(), faults=pol)

    def test_crash_on_raid0_rejected(self):
        pol = FaultPolicy(events=(Crash(device=0, at_time=0.01),))
        with pytest.raises(ValueError, match="raid0.*no parity"):
            ArraySim(3, P, 0.6, Workload(), faults=pol,
                     layout=Raid0Layout(group=3))

    def test_crash_device_out_of_range(self):
        pol = FaultPolicy(events=(Crash(device=6, at_time=0.01),))
        with pytest.raises(ValueError, match="Crash.device=6.*n_ssds=6"):
            ArraySim(6, P, 0.6, Workload(), faults=pol,
                     layout=Raid5Layout(group=3))

    def test_crash_plus_static_degraded_rejected(self):
        pol = FaultPolicy(events=(Crash(device=0, at_time=0.01),))
        with pytest.raises(ValueError, match="degraded=1"):
            ArraySim(6, P, 0.6, Workload(), faults=pol,
                     layout=Raid5Layout(group=3, degraded=1))

    def test_double_crash_rejected(self):
        pol = FaultPolicy(events=(Crash(device=0, at_time=0.01),
                                  Crash(device=1, at_time=0.02)))
        with pytest.raises(ValueError, match="correlated failures"):
            ArraySim(6, P, 0.6, Workload(), faults=pol,
                     layout=Raid5Layout(group=3))

    def test_crash_allowed_on_safs(self):
        # layout-less SAFS array: crash = spare swap + flusher deferral
        pol = FaultPolicy(events=(Crash(device=1, at_time=0.01),))
        SAFSSim(n_ssds=3, ssd=P, occupancy=0.6,
                workload=SAFSWorkload(concurrency=16), seed=0, faults=pol)

    @pytest.mark.parametrize("pol, match", [
        (FaultPolicy(events=(FailSlow(device=9),)), "FailSlow.device=9"),
        (FaultPolicy(events=(FailSlow(device=0, slow_factor=0.5),)),
         "slow_factor"),
        (FaultPolicy(events=(FailSlow(device=0, duration=0.0),)),
         "duration"),
        (FaultPolicy(events=(MediaError(read_ber=1.5),)), "read_ber"),
        (FaultPolicy(events=(MediaError(read_ber=1e-4, device=7),)),
         "MediaError.device=7"),
        (FaultPolicy(retry=RetryPolicy(max_retries=-1)), "max_retries"),
        (FaultPolicy(retry=RetryPolicy(backoff=0.0)), "backoff"),
        (FaultPolicy(retry=RetryPolicy(backoff_mult=0.5)), "backoff_mult"),
        (FaultPolicy(retry=RetryPolicy(timeout=-1.0)), "timeout"),
        (FaultPolicy(hedge_after=-1e-3), "hedge_after"),
        (FaultPolicy(quarantine_qd=0), "quarantine_qd"),
        (FaultPolicy(detect_alpha=0.0), "detect_alpha"),
        (FaultPolicy(detect_ratio=2.0, detect_release=2.5),
         "detect_release"),
    ])
    def test_knob_ranges(self, pol, match):
        with pytest.raises(ValueError, match=match):
            ArraySim(3, P, 0.6, Workload(), faults=pol)

    def test_non_policy_and_unknown_event_rejected(self):
        with pytest.raises(TypeError, match="FaultPolicy"):
            ArraySim(3, P, 0.6, Workload(), faults={"events": ()})
        with pytest.raises(TypeError, match="unknown fault event"):
            ArraySim(3, P, 0.6, Workload(),
                     faults=FaultPolicy(events=("flaky",)))


# ---------------------------------------------------------------------------
# faults=None byte-identity: goldens captured before the fault wiring
# ---------------------------------------------------------------------------

class TestFaultsOffIdentity:
    def test_fast_loop_matches_golden(self):
        r = ArraySim(3, P, 0.6, Workload(w_total=96, qd_per_ssd=32,
                                         n_streams=3),
                     seed=42, faults=None).run(6000)
        assert r.iops == GOLDEN_ARRAY_UNIFORM["iops"]
        assert r.p99_latency == GOLDEN_ARRAY_UNIFORM["p99"]
        assert r.faults is None

    def test_qos_loop_matches_golden(self):
        qos = QosPolicy(tenants=(TenantSpec(tenant=0, weight=2.0,
                                            read_frac=0.5),
                                 TenantSpec(tenant=1, weight=1.0)))
        r = ArraySim(3, P, 0.6, Workload(w_total=48, qd_per_ssd=16,
                                         n_streams=2),
                     seed=11, qos=qos, faults=None).run(4000)
        assert r.iops == 45865.839675457
        assert r.p99_latency == 0.004920958800186732
        assert r.faults is None

    def test_layout_loop_steered_matches_golden(self):
        from repro.core.gc_coord import StaggeredGc
        r = ArraySim(6, P, 0.6,
                     Workload(w_total=48, qd_per_ssd=16, n_streams=4,
                              read_frac=0.7),
                     seed=5, layout=Raid5Layout(group=3),
                     gc=StaggeredGc(max_concurrent=1, scope="group",
                                    steer=True),
                     faults=None).run(5000)
        assert r.iops == 62404.307295619474
        assert r.p99_latency == 0.0027993318160597566
        assert r.steered_reads == 161
        assert r.faults is None

    def test_layout_loop_degraded_matches_golden(self):
        r = ArraySim(6, P, 0.6,
                     Workload(w_total=48, qd_per_ssd=16, n_streams=4,
                              read_frac=0.5),
                     seed=9,
                     layout=Raid5Layout(group=3, degraded=1, rebuild=True),
                     faults=None).run(4000)
        assert r.iops == 49404.28568339584
        assert r.p99_latency == 0.004262525239262362
        assert r.rebuild_rows == 367
        assert r.degraded_reads == 655
        assert r.faults is None

    def test_safs_matches_golden(self):
        s = SAFSSim(n_ssds=3, ssd=P, occupancy=0.6,
                    workload=SAFSWorkload(concurrency=48, read_frac=0.3),
                    cache_frac=0.1, seed=3, faults=None)
        r = s.run(3000)
        assert r.app_iops == 151868.9155721029
        assert r.p99_latency == 0.003824150957049485
        assert r.flush_writes == 1262
        assert r.ssd_reads == 808
        assert r.demand_writes == 731
        assert r.faults is None


# ---------------------------------------------------------------------------
# determinism + sharded bit-identity with faults ON
# ---------------------------------------------------------------------------

FAULTY = FaultPolicy(
    events=(FailSlow(device=1, onset=0.0, slow_factor=4.0),
            MediaError(read_ber=5e-3),
            Crash(device=4, at_time=0.02)),
    retry=RetryPolicy(max_retries=2, backoff=50e-6),
    hedge_after=2e-3, detect=True, detect_min_samples=16, detect_every=16,
    quarantine_qd=8)


class TestFaultedDeterminism:
    def _run(self):
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=4, read_frac=0.6)
        return ArraySim(6, P, 0.6, wl, seed=7, layout=Raid5Layout(group=3),
                        faults=FAULTY).run(4000)

    def test_same_seed_same_bytes(self):
        a, b = self._run(), self._run()
        assert a.iops == b.iops
        assert a.p99_latency == b.p99_latency
        assert a.faults == b.faults
        assert a.faults["crashes"] == 1

    def test_sharded_array_serial_equals_parallel(self):
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=4, read_frac=0.6)
        kw = dict(layout=Raid5Layout(group=3), faults=FAULTY, seed=7,
                  n_shards=2)
        a = ShardedArraySim(6, P, 0.6, wl, parallel=False, **kw).run(3000)
        b = ShardedArraySim(6, P, 0.6, wl, parallel=True, **kw).run(3000)
        assert a.iops == b.iops
        assert a.p99_latency == b.p99_latency
        assert a.faults == b.faults
        # the per-shard remap really injected: the crash landed in shard 2
        assert a.faults["crashes"] == 1
        assert a.faults["media_errors"] > 0

    def test_sharded_safs_serial_equals_parallel(self):
        pol = FaultPolicy(events=(FailSlow(device=0, slow_factor=4.0),
                                  MediaError(read_ber=5e-3),
                                  Crash(device=3, at_time=0.01)),
                          detect=True, detect_min_samples=16,
                          detect_every=16)
        wl = SAFSWorkload(concurrency=32, read_frac=0.5)
        kw = dict(workload=wl, cache_frac=0.1, seed=5, n_shards=2,
                  faults=pol)
        a = ShardedSAFSSim(4, P, 0.6, parallel=False, **kw).run(3000)
        b = ShardedSAFSSim(4, P, 0.6, parallel=True, **kw).run(3000)
        assert a.app_iops == b.app_iops
        assert a.p99_latency == b.p99_latency
        assert a.faults == b.faults
        assert a.faults["crashes"] == 1

    def test_sharded_safs_qos_still_refused(self):
        with pytest.raises(NotImplementedError, match="QoS"):
            ShardedSAFSSim(4, P, qos=QosPolicy(
                tenants=(TenantSpec(tenant=0, weight=1.0),)))
        # trace replay is sharded now, but the trace array is mandatory
        with pytest.raises(ValueError, match="trace"):
            ShardedSAFSSim(4, P, workload=SAFSWorkload(scenario="trace"))


# ---------------------------------------------------------------------------
# slice/merge helpers
# ---------------------------------------------------------------------------

class TestSliceMerge:
    def test_slice_policy_remaps_and_drops(self):
        sub = slice_policy(FAULTY, 3, 6)
        kinds = [type(e).__name__ for e in sub.events]
        # FailSlow(1) is outside [3, 6); MediaError(-1) ships everywhere;
        # Crash(4) remaps to local device 1
        assert kinds == ["MediaError", "Crash"]
        assert sub.events[1].device == 1
        assert sub.hedge_after == FAULTY.hedge_after
        assert sub.detect == FAULTY.detect

    def test_merge_fault_stats(self):
        assert merge_fault_stats([]) is None
        assert merge_fault_stats([None, None]) is None
        a = FaultInjector(FaultPolicy(), 1, 0).stats
        b = dict(a)
        a = dict(a)
        a.update(media_errors=3, retries=2, max_attempts=1,
                 detect_latency_s=0.5, quarantine_time_s=0.1)
        b.update(media_errors=1, max_attempts=4, crash_at=0.2,
                 rebuild_completed_at=0.9, data_at_risk_s=0.7,
                 detect_latency_s=0.2, quarantine_time_s=0.2)
        m = merge_fault_stats([a, None, b])
        assert m["media_errors"] == 4
        assert m["retries"] == 2
        assert m["max_attempts"] == 4
        assert m["crash_at"] == 0.2
        assert m["data_at_risk_s"] == 0.7
        assert m["detect_latency_s"] == 0.2      # earliest detection wins
        assert m["quarantine_time_s"] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# defense behavior
# ---------------------------------------------------------------------------

class TestDefenses:
    def test_media_retries_bounded_and_accounted(self):
        pol = FaultPolicy(events=(MediaError(read_ber=0.05),),
                          retry=RetryPolicy(max_retries=2, backoff=50e-6))
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=3, read_frac=0.7)
        r = ArraySim(3, P, 0.6, wl, seed=1, faults=pol).run(4000)
        f = r.faults
        assert f["media_errors"] > 0
        assert 0 < f["retries"] <= f["media_errors"]
        assert f["max_attempts"] <= pol.retry.max_retries + 1
        assert r.iops > 0          # no op wedged on an exhausted retry

    def test_retry_timeout_abandons_early(self):
        # timeout smaller than the first backoff: every failed read gives
        # up immediately instead of retrying
        pol = FaultPolicy(events=(MediaError(read_ber=0.05),),
                          retry=RetryPolicy(max_retries=3, backoff=1e-3,
                                            timeout=1e-6))
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=3, read_frac=0.7)
        r = ArraySim(3, P, 0.6, wl, seed=1, faults=pol).run(4000)
        f = r.faults
        assert f["media_errors"] > 0
        assert f["retries"] == 0
        assert f["timeouts"] == f["media_errors"]

    def test_detector_quarantines_slow_member(self):
        pol = FaultPolicy(events=(FailSlow(device=0, onset=0.0,
                                           slow_factor=8.0),),
                          detect=True, detect_min_samples=16,
                          detect_every=16, quarantine_qd=4)
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=3, read_frac=0.5)
        r = ArraySim(3, P, 0.6, wl, seed=2, faults=pol).run(4000)
        f = r.faults
        assert f["fail_slow_episodes"] == 1
        assert f["quarantines"] >= 1
        assert f["false_quarantines"] == 0
        assert f["detect_latency_s"] >= 0.0
        assert f["quarantine_time_s"] > 0.0

    def test_hedged_reads_fire_and_win(self):
        pol = FaultPolicy(events=(FailSlow(device=0, onset=0.0,
                                           slow_factor=8.0),),
                          hedge_after=1e-3)
        wl = Workload(w_total=48, qd_per_ssd=16, n_streams=6, read_frac=1.0)
        r = ArraySim(6, P, 0.6, wl, seed=0, layout=Raid5Layout(group=6),
                     faults=pol).run(4000)
        f = r.faults
        assert f["hedged_reads"] > 0
        assert 0 < f["hedge_wins"] <= f["hedged_reads"]

    def test_crash_degrades_rebuilds_heals(self):
        ssd = SSDParams(capacity_pages=2048)
        pol = FaultPolicy(events=(Crash(device=1, at_time=0.05),))
        wl = Workload(w_total=42, qd_per_ssd=32, n_streams=6, read_frac=0.5)
        r = ArraySim(6, ssd, 0.5, wl, seed=0, layout=Raid5Layout(group=6),
                     faults=pol).run(30000)
        f = r.faults
        assert f["crashes"] == 1
        assert f["crash_at"] == pytest.approx(0.05)
        # the group planned degraded between crash and heal...
        assert r.degraded_reads > 0
        # ...the rebuild tenant ran and finished...
        assert r.rebuild_rows > 0
        assert f["rebuild_completed_at"] > f["crash_at"]
        assert f["data_at_risk_s"] == pytest.approx(
            f["rebuild_completed_at"] - f["crash_at"])
        # ...and rebuild stops once healed (rows bounded by one pass)
        assert r.rebuild_rows <= 2 * 2048

    def test_crash_on_qos_loop(self):
        ssd = SSDParams(capacity_pages=2048)
        pol = FaultPolicy(events=(Crash(device=1, at_time=0.05),))
        qos = QosPolicy(tenants=(TenantSpec(tenant=0, weight=2.0,
                                            read_frac=0.5),
                                 TenantSpec(tenant=1, weight=1.0)))
        r = ArraySim(6, ssd, 0.5, Workload(w_total=42, qd_per_ssd=32),
                     seed=0, layout=Raid5Layout(group=6), qos=qos,
                     faults=pol).run(30000)
        f = r.faults
        assert f["crashes"] == 1
        assert f["rebuild_completed_at"] > f["crash_at"]
        assert r.tenant_stats is not None

    def test_safs_crash_defers_writebacks(self):
        pol = FaultPolicy(events=(Crash(device=1, at_time=0.005),))
        s = SAFSSim(n_ssds=3, ssd=P, occupancy=0.6,
                    workload=SAFSWorkload(concurrency=48, read_frac=0.3),
                    cache_frac=0.1, seed=3, faults=pol)
        r = s.run(3000)
        assert r.faults["crashes"] == 1
        assert r.faults["flush_deferred"] > 0
        assert r.app_iops > 0

    def test_safs_media_retries_bounded(self):
        pol = FaultPolicy(events=(MediaError(read_ber=0.05),),
                          retry=RetryPolicy(max_retries=2, backoff=50e-6))
        s = SAFSSim(n_ssds=3, ssd=P, occupancy=0.6,
                    workload=SAFSWorkload(concurrency=48, read_frac=0.5),
                    cache_frac=0.1, seed=3, faults=pol)
        r = s.run(3000)
        f = r.faults
        assert f["media_errors"] > 0
        assert f["max_attempts"] <= 3


# ---------------------------------------------------------------------------
# property: the retry/backoff schedule is pure, deterministic, and bounded
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:           # pragma: no cover - requirements-dev.txt
    given = None


if given is not None:
    @given(max_retries=st.integers(min_value=0, max_value=8),
           backoff=st.floats(min_value=1e-6, max_value=1e-2),
           mult=st.floats(min_value=1.0, max_value=4.0),
           timeout=st.one_of(st.just(0.0),
                             st.floats(min_value=1e-5, max_value=1e-1)),
           service=st.floats(min_value=1e-6, max_value=1e-2),
           seed=st.integers(min_value=0, max_value=2**31))
    @settings(max_examples=200, deadline=None)
    def test_retry_schedule_property(max_retries, backoff, mult, timeout,
                                     service, seed):
        pol = FaultPolicy(retry=RetryPolicy(max_retries=max_retries,
                                            backoff=backoff,
                                            backoff_mult=mult,
                                            timeout=timeout))

        def chain():
            """Walk one op's worst-case retry chain (every attempt
            fails)."""
            inj = FaultInjector(pol, 2, seed)
            t_issue, now = 0.0, service
            delays = []
            attempt = 0
            while True:
                retry, delay = inj.retry_decision(attempt, t_issue, now)
                if not retry:
                    break
                delays.append(delay)
                now += delay + service
                attempt += 1
            return delays, inj.stats

        d1, s1 = chain()
        d2, s2 = chain()
        assert d1 == d2 and s1 == s2             # deterministic
        assert len(d1) <= max_retries            # bounded re-issues
        assert s1["max_attempts"] <= max_retries + 1
        assert all(b <= a for a, b in zip(d1[1:], d1))   # non-decreasing
        for k, d in enumerate(d1):
            assert d == pytest.approx(backoff * mult ** k)
        if timeout > 0.0:
            # every scheduled retry fit the op budget at decision time
            elapsed = service
            for d in d1:
                assert elapsed + d <= timeout
                elapsed += d + service


# ---------------------------------------------------------------------------
# nightly: the full fault-injection acceptance sweep
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_faults_sweep_full_tier(tmp_path):
    """Nightly: the full 18-SSD faults sweep (the committed BENCH_faults.json
    tier) must pass every built-in check — hedging + quarantine cutting read
    p99 and un-starving peers, the mid-run crash rebuilding with bounded
    foreground p99, retries bounded, the faulted path deterministic."""
    import json
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "BENCH_faults.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.faults_sweep",
         "--out", str(out)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out.read_text())
    assert payload["all_checks_pass"]
    assert payload["n_ssds"] >= 18
    fs = payload["fail_slow"]
    assert fs["defended"]["mean"]["p99_ms"] \
        < fs["no_defense"]["mean"]["p99_ms"]
    assert all(row["faults"]["rebuild_completed_at"] >= 0.0
               for row in payload["crash_rebuild"]["crash"]["seeds"])

"""Array-level GC coordination (core/gc_coord.py): policy units, golden
byte-identity of gc=None / ReactiveGc, staggered lease semantics, idle-GC
triggering, steering, QoS/RAID composition, and the sharded merge of the
new counters."""
import numpy as np
import pytest

from repro.core.engine import EventLoop
from repro.core.gc_coord import (IdleGc, ReactiveGc, StaggeredGc,
                                 gc_policy_from_name)
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.raid import Raid5Layout
from repro.core.sharded import ShardedArraySim

from test_golden_determinism import GOLDEN_ARRAY_UNIFORM, GOLDEN_RAID5, P

SMALL = SSDParams(capacity_pages=4096)
WL3 = Workload(w_total=96, qd_per_ssd=32, n_streams=3)


# ---------------------------------------------------------------------------
# policy specs
# ---------------------------------------------------------------------------

def test_policies_frozen_hashable_picklable():
    import pickle
    for pol in (ReactiveGc(), StaggeredGc(max_concurrent=2, scope="group"),
                IdleGc(watermark=20, qd_idle=1), ReactiveGc(steer=True)):
        assert pickle.loads(pickle.dumps(pol)) == pol
        hash(pol)
        with pytest.raises(Exception):
            pol.max_concurrent = 9   # frozen

    assert ReactiveGc().name == "reactive"
    assert StaggeredGc().name == "staggered"
    assert IdleGc().name == "idle"
    assert gc_policy_from_name("staggered", max_concurrent=3) \
        == StaggeredGc(max_concurrent=3)
    with pytest.raises(ValueError):
        gc_policy_from_name("nope")


def test_bad_policy_rejected():
    with pytest.raises(TypeError):
        ArraySim(2, SMALL, 0.6, WL3, gc="staggered")
    with pytest.raises(ValueError):
        # bad scope surfaces at coordinator build
        StaggeredGc(scope="rack").make_coordinator(4, EventLoop())


# ---------------------------------------------------------------------------
# lease accounting (coordinator unit tests on stub devices)
# ---------------------------------------------------------------------------

class _StubFtl:
    def __init__(self, free=20, low=12):
        self.free_blocks = list(range(free))
        self._gc_low = low

    def need_gc(self):
        return len(self.free_blocks) <= self._gc_low

    def gc_satisfied(self):
        return True


class _StubServer:
    def __init__(self, free=20):
        self.ftl = _StubFtl(free)


class _StubDev:
    """Just enough of DeviceModel for GcCoordinator.gate()."""

    def __init__(self, dev_id, free=20):
        self.dev_id = dev_id
        self.server = _StubServer(free)
        self.in_service = 0
        self.gc_granted = False
        self.started = 0
        self.kicked = 0

    def _start_gc(self):
        self.started += 1

    def kick(self):
        self.kicked += 1


def _coord(policy, n, unit=1):
    loop = EventLoop()
    c = policy.make_coordinator(n, loop, unit)
    devs = [_StubDev(i) for i in range(n)]
    for i, d in enumerate(devs):
        c.attach(d, i)
    return c, devs, loop


def test_staggered_lease_accounting():
    c, devs, loop = _coord(StaggeredGc(max_concurrent=1, early_blocks=0), 3)
    for d in devs:
        d.server.ftl.free_blocks = list(range(10))   # all need GC
    assert c.gate(devs[0]) is True                   # first grab wins
    assert devs[0].gc_granted and devs[0].started == 1
    assert c.gate(devs[1]) is False                  # deferred, keeps serving
    assert c.gate(devs[2]) is False
    assert c.active == [1] and list(c.waiting[0]) == [1, 2]
    assert c.gate(devs[1]) is False                  # no duplicate enqueue
    assert list(c.waiting[0]) == [1, 2]
    assert c.gc_busy == [True, True, True]           # all in-or-about-to-enter

    c.on_gc_start(devs[0], dt=1e-3)
    loop.now = 5e-3
    c.on_gc_end(devs[0])                             # FIFO handover -> dev 1
    assert not devs[0].gc_granted
    assert devs[1].gc_granted and devs[1].started == 1
    assert devs[2].started == 0 and list(c.waiting[0]) == [2]
    assert len(c.wait_rec) == 1                      # dev 1's wait recorded
    assert c.wait_rec.values()[0] == pytest.approx(5e-3)


def test_staggered_hard_floor_override():
    pol = StaggeredGc(max_concurrent=1, floor_blocks=4, early_blocks=0)
    c, devs, loop = _coord(pol, 2)
    devs[0].server.ftl.free_blocks = list(range(10))
    devs[1].server.ftl.free_blocks = list(range(10))
    assert c.gate(devs[0]) is True
    assert c.gate(devs[1]) is False                  # lease taken
    devs[1].server.ftl.free_blocks = list(range(4))  # at the floor
    assert c.gate(devs[1]) is True                   # forced through
    assert devs[1].started == 1
    assert c.forced == 1
    assert c.active == [2]                           # override exceeds the cap


def test_staggered_group_scope_domains():
    pol = StaggeredGc(max_concurrent=1, scope="group", early_blocks=0)
    c, devs, loop = _coord(pol, 4, unit=2)
    assert c.dom == [0, 0, 1, 1]
    for d in devs:
        d.server.ftl.free_blocks = list(range(10))
    assert c.gate(devs[0]) is True                   # group 0 lease
    assert c.gate(devs[2]) is True                   # group 1 lease (separate)
    assert c.gate(devs[1]) is False                  # group 0 full
    assert c.active == [1, 1]


def test_staggered_early_trigger_takes_free_lease():
    pol = StaggeredGc(max_concurrent=1, early_blocks=2)
    c, devs, loop = _coord(pol, 2)
    f = devs[0].server.ftl
    f.free_blocks = list(range(14))                  # low(12) + 2: early zone
    f.gc_satisfied = lambda: False
    assert not f.need_gc()
    assert c.gate(devs[0]) is True                   # proactive grant
    assert devs[0].started == 1
    # a second device in the early zone defers silently (lease busy, no
    # reactive pressure -> not queued)
    g = devs[1].server.ftl
    g.free_blocks = list(range(14))
    g.gc_satisfied = lambda: False
    assert c.gate(devs[1]) is False
    assert not c.waiting[0]


def test_reactive_gate_never_defers():
    c, devs, loop = _coord(ReactiveGc(), 3)
    for d in devs:
        d.server.ftl.free_blocks = list(range(5))
    assert all(c.gate(d) for d in devs)
    assert all(d.started == 1 for d in devs)


def test_overlap_integral():
    c, devs, loop = _coord(ReactiveGc(), 2)
    loop.now = 0.0
    c.on_gc_start(devs[0], dt=1.0)
    loop.now = 1.0
    c.on_gc_start(devs[1], dt=1.0)                   # 2 in GC from t=1
    loop.now = 3.0
    c.on_gc_end(devs[0])                             # overlap [1, 3] = 2.0
    loop.now = 4.0
    c.on_gc_end(devs[1])
    c.finalize(4.0)
    assert c.window_stats(4.0)["gc_overlap_frac"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# golden byte-identity: gc=None == ReactiveGc == historical goldens
# ---------------------------------------------------------------------------

def test_reactive_reproduces_golden_uniform():
    """ReactiveGc (and the whole coordinator plumbing) may not perturb the
    fast path: the PR 2 golden must reproduce byte-for-byte."""
    for gc in (None, ReactiveGc()):
        sim = ArraySim(3, P, 0.6, WL3, seed=42, gc=gc)
        r = sim.run(6000)
        assert r.iops == GOLDEN_ARRAY_UNIFORM["iops"]
        assert r.p99_latency == GOLDEN_ARRAY_UNIFORM["p99"]
        assert r.sim_time == GOLDEN_ARRAY_UNIFORM["sim_time"]
        assert sum(s.ftl.writes for s in sim.ssds) \
            == GOLDEN_ARRAY_UNIFORM["writes"]
        assert sum(s.ftl.gc_copies for s in sim.ssds) \
            == GOLDEN_ARRAY_UNIFORM["gc_copies"]
        assert [float(x) for x in r.per_ssd_iops] \
            == GOLDEN_ARRAY_UNIFORM["per_ssd"]


def test_reactive_reproduces_golden_raid5():
    """Same identity through the layout loop (planner + coordination)."""
    wl = Workload(w_total=96, qd_per_ssd=32, n_streams=6, read_frac=0.3)
    for gc in (None, ReactiveGc()):
        r = ArraySim(6, P, 0.6, wl, seed=7, layout=Raid5Layout(group=6),
                     gc=gc).run(5000)
        for k, want in GOLDEN_RAID5.items():
            assert getattr(r, k) == want, f"{k} (gc={gc})"
        assert r.steered_reads == 0


def test_reactive_coordination_block_populated():
    r = ArraySim(3, P, 0.6, WL3, seed=42, gc=ReactiveGc()).run(6000)
    assert r.gc_policy == "reactive"
    assert r.gc_starts > 0
    assert r.gc_forced == 0
    assert r.stagger_wait_mean == 0.0        # reactive never waits
    assert r.idle_gc_frac == 0.0
    assert 0.0 < r.util_min <= r.util.min() + 1e-12
    # gc=None leaves the defaults
    r0 = ArraySim(3, P, 0.6, WL3, seed=42).run(6000)
    assert r0.gc_starts == 0 and r0.gc_overlap_frac == 0.0
    assert r0.util_min == pytest.approx(float(r0.util.min()))


# ---------------------------------------------------------------------------
# end-to-end policy behavior
# ---------------------------------------------------------------------------

def test_staggered_array_scope_kills_overlap():
    """k=1 array-wide: at most one member in GC at any instant, so the
    overlap integral is zero unless the hard floor forces through."""
    wl = Workload(w_total=64, qd_per_ssd=16, n_streams=4)
    r_re = ArraySim(4, SMALL, 0.6, wl, seed=5, gc=ReactiveGc()).run(8000)
    r_st = ArraySim(4, SMALL, 0.6, wl, seed=5,
                    gc=StaggeredGc(max_concurrent=1)).run(8000)
    assert r_re.gc_overlap_frac > 0.0
    if r_st.gc_forced == 0:
        assert r_st.gc_overlap_frac == 0.0
    assert r_st.gc_overlap_frac < r_re.gc_overlap_frac
    assert len(ArraySim(4, SMALL, 0.6, wl, seed=5).run(0).per_ssd_iops) == 4


def test_staggered_records_waits_and_makes_progress():
    wl = Workload(w_total=64, qd_per_ssd=16, n_streams=4)
    sim = ArraySim(4, SMALL, 0.6, wl, seed=5,
                   gc=StaggeredGc(max_concurrent=1, early_blocks=0))
    r = sim.run(8000)
    assert r.iops > 0
    assert r.stagger_wait_p99 >= r.stagger_wait_mean > 0.0
    assert sim.last_gc_wait is not None and sim.last_gc_wait.size > 0
    # every device kept collecting (no member starved of GC)
    assert all(s.ftl.erases > 0 for s in sim.ssds)
    # the hard floor held: no device ever ran out of free blocks
    assert all(s.ftl.n_free_blocks > 0 for s in sim.ssds)


def test_idle_gc_triggers_in_idle_windows():
    """Bursty load: IdleGc moves collection into the OFF windows (all GC
    time is idle-attributed) and cuts the p99 the reactive pauses caused."""
    wl = Workload(w_total=64, qd_per_ssd=32, n_streams=2, scenario="bursty",
                  burst_on=2e-3, burst_off=4e-3)
    r_re = ArraySim(2, SMALL, 0.6, wl, seed=3, gc=ReactiveGc()).run(4000)
    r_id = ArraySim(2, SMALL, 0.6, wl, seed=3,
                    gc=IdleGc(watermark=24)).run(4000)
    assert r_id.idle_gc_frac > 0.9
    assert r_re.idle_gc_frac == 0.0
    assert r_id.gc_starts > r_re.gc_starts        # many small steps
    assert r_id.p99_latency < r_re.p99_latency


def test_idle_probe_preconditions():
    """The idle probe only fires on a truly idle device below the watermark
    with sealed blocks to reclaim."""
    pol = IdleGc(watermark=24, qd_idle=0)
    c, devs, loop = _coord(pol, 1)
    d = devs[0]
    started = []
    d._start_idle_gc = lambda blocks: started.append(blocks)
    d.admitted = []
    f = d.server.ftl
    f.free_blocks = list(range(20))                  # below watermark
    f.seal_fifo = [1, 2, 3]
    c.idle_probe(d)
    assert started == [pol.step_blocks]              # fires
    d.in_service = 1
    c.idle_probe(d)                                  # busy -> no
    d.in_service = 0
    d.admitted = [object()]
    c.idle_probe(d)                                  # queued work -> no
    d.admitted = []
    f.free_blocks = list(range(30))                  # above watermark -> no
    c.idle_probe(d)
    f.free_blocks = list(range(20))
    f.seal_fifo = []                                 # nothing sealed -> no
    c.idle_probe(d)
    assert started == [pol.step_blocks]
    f.seal_fifo = [1]
    d.gc_granted = True                              # already leased -> no
    c.idle_probe(d)
    assert started == [pol.step_blocks]


def test_steering_admission_cap_and_read_redirect():
    """steer=True: admission to GC-busy members is capped and RAID-5 reads
    of a GC-busy member are served by sibling reconstruction."""
    wl = Workload(w_total=96, qd_per_ssd=32, n_streams=6, read_frac=0.5)
    gc = StaggeredGc(max_concurrent=1, scope="group", steer=True, steer_qd=2)
    sim = ArraySim(6, SMALL, 0.6, wl, seed=2, layout=Raid5Layout(group=6),
                   gc=gc)
    r = sim.run(8000)
    assert r.steered_reads > 0
    assert r.iops > 0
    # steering must not break plan accounting: reads+writes balance
    assert r.child_reads > 0 and r.child_writes > 0
    r_off = ArraySim(6, SMALL, 0.6, wl, seed=2, layout=Raid5Layout(group=6),
                     gc=StaggeredGc(max_concurrent=1, scope="group")).run(8000)
    assert r_off.steered_reads == 0


def test_qos_raid5_staggered_composition():
    """QoS weighted tenants + RAID-5 + staggered coordination compose: the
    run completes, shares are enforced, and the coordination block reports
    the staggered policy."""
    pol = QosPolicy(tenants=(TenantSpec(0, weight=3.0),
                             TenantSpec(1, weight=1.0)))
    r = ArraySim(6, SMALL, 0.6, Workload(w_total=48, qd_per_ssd=48),
                 seed=3, layout=Raid5Layout(group=6), qos=pol,
                 gc=StaggeredGc(max_concurrent=1, scope="group")).run(12000)
    assert r.gc_policy == "staggered"
    assert r.gc_starts > 0
    assert r.tenant_stats is not None
    s0, s1 = r.tenant_stats[0], r.tenant_stats[1]
    assert s0.ops > s1.ops                 # weight 3 beats weight 1
    assert r.share_error < 0.15
    # reactive-vs-none identity holds under QoS too
    a = ArraySim(6, SMALL, 0.6, Workload(w_total=48, qd_per_ssd=48),
                 seed=3, layout=Raid5Layout(group=6), qos=pol).run(6000)
    b = ArraySim(6, SMALL, 0.6, Workload(w_total=48, qd_per_ssd=48),
                 seed=3, layout=Raid5Layout(group=6), qos=pol,
                 gc=ReactiveGc()).run(6000)
    assert a.iops == b.iops and a.p99_latency == b.p99_latency


# ---------------------------------------------------------------------------
# sharded: serial == parallel bit-identity for the new counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gc", [
    StaggeredGc(max_concurrent=1, scope="group"),
    StaggeredGc(max_concurrent=1, scope="group", steer=True),
    IdleGc(watermark=24),
])
def test_sharded_serial_equals_parallel_gc(gc):
    wl = Workload(w_total=48, qd_per_ssd=16, n_streams=6)
    kw = dict(layout=Raid5Layout(group=3), gc=gc, seed=5, n_shards=2)
    a = ShardedArraySim(6, SMALL, 0.6, wl, parallel=True, **kw).run(6000)
    b = ShardedArraySim(6, SMALL, 0.6, wl, parallel=False, **kw).run(6000)
    assert a.iops == b.iops
    assert a.p99_latency == b.p99_latency
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    # the coordination block merges bit-identically
    assert a.gc_policy == b.gc_policy == gc.name
    assert a.gc_overlap_frac == b.gc_overlap_frac
    assert a.stagger_wait_mean == b.stagger_wait_mean
    assert a.stagger_wait_p99 == b.stagger_wait_p99
    assert a.gc_starts == b.gc_starts > 0
    assert a.gc_forced == b.gc_forced
    assert a.idle_gc_frac == b.idle_gc_frac
    assert a.util_min == b.util_min
    assert a.steered_reads == b.steered_reads


def test_sharded_gc_merge_values():
    """Spot-check the merge arithmetic against the per-shard parts."""
    wl = Workload(w_total=48, qd_per_ssd=16, n_streams=6)
    sim = ShardedArraySim(6, SMALL, 0.6, wl, seed=5, n_shards=2,
                          layout=Raid5Layout(group=3),
                          gc=StaggeredGc(max_concurrent=1, scope="group"),
                          parallel=False)
    r = sim.run(6000)
    from repro.core.sharded import _run_shard
    parts = [_run_shard(a) for a in sim._shard_args(6000, None)]
    assert r.gc_starts == sum(p[0].gc_starts for p in parts)
    assert r.util_min == min(float(np.asarray(p[0].util).min())
                             for p in parts)
    waits = np.concatenate([p[4] for p in parts if p[4] is not None
                            and p[4].size]) \
        if any(p[4] is not None and p[4].size for p in parts) else None
    if waits is not None and waits.size:
        assert r.stagger_wait_p99 == float(np.percentile(waits, 99.0))


# ---------------------------------------------------------------------------
# nightly: the full gc-coordination acceptance sweep
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_gc_coord_sweep_full_tier(tmp_path):
    """Nightly: the full 18-SSD gc-coord sweep (the committed
    BENCH_gc_coord.json tier) must pass every built-in check — staggered
    raising util_min and cutting stripe_stall_p99 vs reactive, idle GC
    shifting collection off the busy phase, reactive matching the golden."""
    import json
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "BENCH_gc_coord.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.gc_coord_sweep",
         "--out", str(out)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out.read_text())
    assert payload["all_checks_pass"]
    assert payload["n_ssds"] >= 18
    st = payload["staggered"]
    assert st["staggered"]["mean"]["util_min"] \
        > st["reactive"]["mean"]["util_min"]


def test_sharded_rejects_array_scope_staggering():
    """An 'array'-wide lease cannot span shard processes — sharding it would
    silently become per-shard staggering; one shard is fine."""
    wl = Workload(w_total=32, qd_per_ssd=16, n_streams=4)
    with pytest.raises(ValueError, match="scope='array'"):
        ShardedArraySim(4, SMALL, 0.6, wl, n_shards=2,
                        gc=StaggeredGc(max_concurrent=1, scope="array"))
    ShardedArraySim(4, SMALL, 0.6, wl, n_shards=1,
                    gc=StaggeredGc(max_concurrent=1, scope="array"))

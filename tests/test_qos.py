"""Per-tenant QoS subsystem (core/qos.py): scheduler mechanics, weighted
fair shares, rate caps, SLO throttling under GC interference, per-tenant
telemetry, sharded merging, and the qos=None byte-identity guarantee."""
import numpy as np
import pytest

from repro.core.engine import EventLoop
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.io_queues import HIGH, LOW, IORequest
from repro.core.qos import (DeficitRoundRobin, QosPolicy, QosScheduler,
                            SloController, TenantDualQueue, TenantSpec,
                            TokenBucket, build_tenant_stats,
                            merge_tenant_stats, pool_tenant_samples)
from repro.core.raid import Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.sharded import ShardedArraySim

from test_golden_determinism import (GOLDEN_ARRAY_UNIFORM,
                                     GOLDEN_SAFS_UNIFORM, _array_counters)

P = SSDParams(capacity_pages=4096)

# window below n*qd: host queues keep headroom, so the shared window W is
# the binding constraint and the DRR sets admission shares (at W == n*qd
# parking dynamics would override the scheduler — see qos_sweep)
WL = Workload(w_total=48, qd_per_ssd=128)


def two_writers(w0: float, w1: float, **kw) -> QosPolicy:
    return QosPolicy(tenants=(TenantSpec(0, weight=w0, **kw),
                              TenantSpec(1, weight=w1)))


# ---------------------------------------------------------------------------
# scheduler building blocks
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        QosPolicy(tenants=())
    with pytest.raises(ValueError):
        QosPolicy(tenants=(TenantSpec(0), TenantSpec(0)))
    with pytest.raises(ValueError):
        QosPolicy(tenants=(TenantSpec(0, weight=0.0),))
    with pytest.raises(ValueError):
        QosPolicy(tenants=(TenantSpec(0, rate_iops=-1.0),))
    pol = two_writers(3.0, 1.0)
    assert pol.weight_share(0) == 0.75 and pol.weight_share(1) == 0.25
    assert pol.spec(1).tenant == 1
    # frozen + hashable + picklable (ships to sharded workers)
    import pickle
    assert pickle.loads(pickle.dumps(pol)) == pol
    hash(pol)


def test_qos_rejects_conflicting_workload_inputs():
    """qos= builds per-tenant sources from the specs; a caller-supplied
    source/trace or a scenario'd Workload would be silently ignored, so the
    constructor refuses the combination."""
    from repro.core.workloads import UniformSource
    pol = two_writers(1.0, 1.0)
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="source"):
        ArraySim(2, P, 0.6, WL, seed=0, qos=pol,
                 source=UniformSource(100, rng))
    with pytest.raises(ValueError, match="scenario"):
        ArraySim(2, P, 0.6, Workload(scenario="mixed"), seed=0, qos=pol)


def test_token_bucket():
    b = TokenBucket(rate=100.0, burst=2.0, now=0.0)
    assert b.eligible(0.0)
    b.take(0.0)
    b.take(0.0)
    assert not b.eligible(0.0)
    # next full token 0.01s out; refill makes it eligible again
    assert b.next_release(0.0) == pytest.approx(0.01)
    assert b.eligible(0.011)
    # burst caps accumulation
    b2 = TokenBucket(rate=100.0, burst=2.0, now=0.0)
    b2._refill(10.0)
    assert b2.tokens == 2.0


def test_drr_exact_weighted_shares():
    drr = DeficitRoundRobin([0, 1, 2], lambda t: {0: 4.0, 1: 2.0, 2: 1.0}[t])
    picks = [drr.pick(lambda t: True) for _ in range(7000)]
    counts = [picks.count(t) for t in (0, 1, 2)]
    assert counts == [4000, 2000, 1000]


def test_drr_skips_blocked_without_losing_deficit():
    drr = DeficitRoundRobin([0, 1], lambda t: 2.0)
    # tenant 0 blocked: all service goes to tenant 1
    assert [drr.pick(lambda t: t == 1) for _ in range(4)] == [1] * 4
    # nobody eligible -> None (no spin)
    assert drr.pick(lambda t: False) is None
    # tenant 0 returns and is served again
    assert 0 in {drr.pick(lambda t: True) for _ in range(4)}


def test_slo_controller_throttles_and_recovers():
    pol = QosPolicy(
        tenants=(TenantSpec(0, slo_p99=1e-3), TenantSpec(1)),
        slo_window_ops=64, slo_check_ops=16, slo_min_samples=16,
        throttle_min=0.25)
    c = SloController(pol)
    now = 0.0
    # violating latencies: throttle halves down to the floor
    for i in range(64):
        now += 1e-4
        c.note(0, 5e-3, now)
    assert c.throttle[1] == 0.25
    assert c.violations > 0
    t_thr = c.throttle_time(1, now)
    assert t_thr > 0.0
    # recovery: p99 well under the SLO -> factor doubles back to 1.0
    for i in range(256):
        now += 1e-4
        c.note(0, 1e-5, now)
    assert c.throttle[1] == 1.0
    # throttle_time stops integrating once recovered
    assert c.throttle_time(1, now + 1.0) == c.throttle_time(1, now)


def test_scheduler_rate_cap_and_release():
    pol = QosPolicy(tenants=(TenantSpec(0, rate_iops=10.0, burst=1.0),
                             TenantSpec(1)))
    s = QosScheduler(pol)
    ready = lambda t: t == 0          # only the capped tenant has work
    assert s.pick(0.0, ready) == 0    # burst token
    assert s.pick(0.0, ready) is None
    nr = s.next_release(0.0, ready)
    assert nr == pytest.approx(0.1)
    assert s.pick(nr, ready) == 0


# ---------------------------------------------------------------------------
# ArraySim integration
# ---------------------------------------------------------------------------

def test_qos_none_is_byte_identical_to_golden():
    """Explicit no-QoS golden: ``qos=None`` must keep the fast path (and the
    SAFS stack) byte-for-byte on the PR 2 goldens."""
    sim = ArraySim(3, P, 0.6, Workload(w_total=96, qd_per_ssd=32, n_streams=3),
                   seed=42, qos=None)
    r = sim.run(6000)
    got = _array_counters(sim, r)
    for k, want in GOLDEN_ARRAY_UNIFORM.items():
        if k == "per_ssd":
            continue
        assert got[k] == want, f"{k}: {got[k]!r} != golden {want!r}"
    assert r.tenant_stats is None and r.share_error == 0.0

    s = SAFSSim(n_ssds=2, ssd=P, occupancy=0.6,
                workload=SAFSWorkload(concurrency=64), cache_frac=0.1,
                seed=3, qos=None)
    rs = s.run(4000)
    assert rs.app_iops == GOLDEN_SAFS_UNIFORM["app_iops"]
    assert rs.p99_latency == GOLDEN_SAFS_UNIFORM["p99"]
    assert rs.tenant_stats is None


@pytest.mark.parametrize("w0,w1", [(1.0, 1.0), (2.0, 1.0), (4.0, 1.0)])
def test_weighted_shares_track_weights(w0, w1):
    """At saturation (window-bound), achieved tenant shares track the
    configured DRR weights within 10% relative."""
    r = ArraySim(3, P, 0.6, WL, seed=42, qos=two_writers(w0, w1)).run(8000)
    for t in (0, 1):
        st = r.tenant_stats[t]
        assert st.ops > 0
        assert abs(st.share / st.weight_share - 1.0) < 0.10, \
            f"tenant {t}: share {st.share:.3f} vs weight {st.weight_share:.3f}"
    assert r.share_error < 0.05
    total = sum(st.share for st in r.tenant_stats.values())
    assert total == pytest.approx(1.0)


def test_rate_cap_bounds_tenant_throughput():
    """A token-bucket cap holds a tenant's measured throughput at the cap
    while the uncapped tenant takes the rest of the array."""
    cap = 8000.0
    pol = QosPolicy(tenants=(TenantSpec(0, rate_iops=cap, burst=16.0),
                             TenantSpec(1)))
    r = ArraySim(3, P, 0.6, WL, seed=42, qos=pol).run(8000)
    s0, s1 = r.tenant_stats[0], r.tenant_stats[1]
    assert s0.throughput <= cap * 1.10
    assert s0.throughput >= cap * 0.5          # not starved either
    assert s1.throughput > s0.throughput       # uncapped tenant dominates


def test_slo_throttle_protects_reader_under_gc():
    """The ISSUE's protection scenario: a Zipf reader with a p99 SLO shares
    the array with a random writer whose flush traffic drives active GC.
    With the SLO set, the controller throttles the writer and the reader's
    p99 must improve vs the neutral (telemetry-only) policy."""
    reader = dict(read_frac=1.0, dist="zipf")
    base = QosPolicy(tenants=(TenantSpec(0, weight=1.0, **reader),
                              TenantSpec(1, weight=1.0)))
    slo = QosPolicy(tenants=(TenantSpec(0, weight=1.0, slo_p99=0.5e-3,
                                        **reader),
                             TenantSpec(1, weight=1.0)))
    r_base = ArraySim(3, P, 0.6, WL, seed=42, qos=base).run(10000)
    r_slo = ArraySim(3, P, 0.6, WL, seed=42, qos=slo).run(10000)
    p99_base = r_base.tenant_stats[0].p99_latency
    p99_slo = r_slo.tenant_stats[0].p99_latency
    assert r_base.tenant_stats[1].throttle_time == 0.0
    assert r_slo.tenant_stats[1].throttle_time > 0.0
    assert r_slo.tenant_stats[1].share < r_base.tenant_stats[1].share
    assert p99_slo < p99_base, \
        f"SLO throttling did not protect the reader: {p99_slo} vs {p99_base}"


def test_qos_deterministic_rerun():
    pol = two_writers(2.0, 1.0)
    a = ArraySim(3, P, 0.6, WL, seed=11, qos=pol).run(5000)
    b = ArraySim(3, P, 0.6, WL, seed=11, qos=pol).run(5000)
    assert a.iops == b.iops
    for t in (0, 1):
        assert a.tenant_stats[t].ops == b.tenant_stats[t].ops
        assert a.tenant_stats[t].p99_latency == b.tenant_stats[t].p99_latency


def test_qos_on_raid5_layout():
    """QoS composes with striped layouts: the admission loop drives the
    RAID-5 planner (RMW, parity WA) while tracking per-tenant latency."""
    pol = QosPolicy(tenants=(TenantSpec(0, weight=2.0, read_frac=0.5),
                             TenantSpec(1, weight=1.0)))
    r = ArraySim(6, P, 0.6, Workload(w_total=48, qd_per_ssd=64), seed=7,
                 layout=Raid5Layout(group=6), qos=pol).run(5000)
    assert r.layout == "raid5"
    assert r.parity_wa > 1.5                   # small writes paid the RMW
    assert r.rmw_ops > 0
    assert all(r.tenant_stats[t].ops > 0 for t in (0, 1))
    assert sum(st.ops for st in r.tenant_stats.values()) > 0


def test_qos_rebuild_runs_outside_tenant_classes():
    """The background rebuild stream coexists with QoS tenants (it keeps its
    own window and never consumes tenant tokens)."""
    pol = two_writers(1.0, 1.0)
    r = ArraySim(6, P, 0.6, Workload(w_total=32, qd_per_ssd=64), seed=3,
                 layout=Raid5Layout(group=6, degraded=1, rebuild=True),
                 qos=pol).run(3000)
    assert r.rebuild_rows > 0
    assert all(r.tenant_stats[t].ops > 0 for t in (0, 1))


# ---------------------------------------------------------------------------
# sharded merging
# ---------------------------------------------------------------------------

def test_sharded_qos_serial_equals_parallel():
    """Per-tenant stats must be bit-identical between the worker-process
    path and the same shard decomposition run in-process."""
    pol = QosPolicy(tenants=(TenantSpec(0, weight=2.0, read_frac=1.0,
                                        dist="zipf", slo_p99=1e-3),
                             TenantSpec(1, weight=1.0)))
    wl = Workload(w_total=32, qd_per_ssd=64, n_streams=4)
    a = ShardedArraySim(4, P, 0.6, wl, seed=5, n_shards=2, parallel=True,
                        qos=pol).run(6000)
    b = ShardedArraySim(4, P, 0.6, wl, seed=5, n_shards=2, parallel=False,
                        qos=pol).run(6000)
    assert a.iops == b.iops
    assert a.share_error == b.share_error
    for t in (0, 1):
        sa, sb = a.tenant_stats[t], b.tenant_stats[t]
        assert (sa.ops, sa.throughput, sa.mean_latency, sa.p50_latency,
                sa.p95_latency, sa.p99_latency, sa.throttle_time) == \
               (sb.ops, sb.throughput, sb.mean_latency, sb.p50_latency,
                sb.p95_latency, sb.p99_latency, sb.throttle_time)


def test_sharded_rate_cap_scales_to_shard_share():
    """An array-wide ``rate_iops`` cap stays array-wide under sharding:
    each shard enforces its proportional slice (regression: shipping the
    policy verbatim gave every shard the FULL cap, admitting up to
    n_shards x rate_iops)."""
    cap = 12000.0
    pol = QosPolicy(tenants=(TenantSpec(0, rate_iops=cap, burst=16.0),
                             TenantSpec(1)))
    sim = ShardedArraySim(4, P, 0.6, Workload(w_total=32, qd_per_ssd=64),
                          seed=5, n_shards=2, parallel=False, qos=pol)
    shard_pols = [a[9] for a in sim._shard_args(4000, None)]
    assert sum(p.spec(0).rate_iops for p in shard_pols) == pytest.approx(cap)
    assert all(p.spec(1).rate_iops is None for p in shard_pols)
    r = sim.run(8000)
    assert r.tenant_stats[0].throughput <= cap * 1.15
    assert r.tenant_stats[0].rate_iops == cap   # merged stats: array-wide cap


def test_merge_tenant_stats_pools_exactly():
    pol = two_writers(1.0, 1.0)
    from repro.core.engine import LatencyRecorder

    def part(lat0, lat1, ttime1):
        r0, r1 = LatencyRecorder(), LatencyRecorder()
        for v in lat0:
            r0.record(v)
        for v in lat1:
            r1.record(v)
        stats, _ = build_tenant_stats(pol, {0: r0, 1: r1}, 2.0,
                                      {1: ttime1})
        return stats

    p1 = part([1.0, 2.0], [5.0], 0.5)
    p2 = part([3.0, 4.0], [6.0, 7.0], 2.0)
    pooled = pool_tenant_samples([
        {0: np.array([1.0, 2.0]), 1: np.array([5.0])},
        {0: np.array([3.0, 4.0]), 1: np.array([6.0, 7.0])}])
    merged, share_err = merge_tenant_stats(pol, [p1, p2], pooled)
    assert merged[0].ops == 4 and merged[1].ops == 3
    assert merged[0].p50_latency == 2.5        # exact over pooled samples
    assert merged[1].throttle_time == 2.0      # worst shard
    assert merged[0].throughput == pytest.approx(4 / 2.0)
    assert share_err == pytest.approx(abs(4 / 7 - 0.5))


# ---------------------------------------------------------------------------
# SAFS integration (TenantDualQueue at the pop_next admission point)
# ---------------------------------------------------------------------------

def _req(tenant, prio=HIGH, payload=None, **kw):
    return IORequest(payload=payload, priority=prio, tenant=tenant, **kw)


def test_tenant_dual_queue_weighted_high_classes():
    loop = EventLoop()
    # small quantum so the 2:1 weighting shows within 30 pops (the DRR
    # serves one quantum's worth per class visit)
    pol = QosPolicy(tenants=(TenantSpec(0, weight=2.0), TenantSpec(1)),
                    quantum=2.0)
    q = TenantDualQueue(loop, QosScheduler(pol), max_inflight=64, reserved=2)
    for i in range(30):
        q.submit(_req(0, payload=("a", i)))
        q.submit(_req(1, payload=("b", i)))
    served = [q.pop_next().tenant for _ in range(30)]
    # 2:1 weighted interleave across the per-tenant HIGH classes
    assert served.count(0) == 20 and served.count(1) == 10


def test_tenant_dual_queue_low_discipline_and_stale():
    loop = EventLoop()
    pol = two_writers(1.0, 1.0)
    q = TenantDualQueue(loop, QosScheduler(pol), max_inflight=4, reserved=2)
    discarded = []
    q.submit(_req(0, prio=LOW, payload=0, is_stale=lambda p: True,
                  on_discard=discarded.append))
    q.submit(_req(0, prio=LOW, payload=1, is_stale=lambda p: False))
    q.submit(_req(0, prio=HIGH, payload="h"))
    # HIGH beats LOW
    assert q.pop_next().payload == "h"
    # stale LOW head is dropped (counted), next live LOW issues
    r = q.pop_next()
    assert r.payload == 1 and discarded == [0]
    assert q.stats.discarded_stale == 1
    # reserved slots: with 2 inflight of max 4 and reserved 2, LOW blocks
    q.submit(_req(0, prio=LOW, payload=2))
    assert q.pop_next() is None
    q.complete(r)
    assert q.pop_next().payload == 2   # freed below the reserve line: LOW ok
    # unknown tenant falls back to the first class instead of KeyError
    q.submit(_req(99, prio=HIGH, payload="x"))
    assert q.pop_next().payload == "x"


def test_tenant_dual_queue_rate_block_wakes():
    loop = EventLoop()
    pol = QosPolicy(tenants=(TenantSpec(0, rate_iops=10.0, burst=1.0),))
    wakes = []
    q = TenantDualQueue(loop, QosScheduler(pol), max_inflight=8, reserved=0,
                        on_rate_blocked=wakes.append)
    q.submit(_req(0, payload=0))
    q.submit(_req(0, payload=1))
    assert q.pop_next().payload == 0   # burst token
    assert q.pop_next() is None        # rate-blocked
    assert wakes and wakes[0] == pytest.approx(0.1)


def test_safs_qos_end_to_end():
    pol = QosPolicy(tenants=(TenantSpec(0, weight=2.0), TenantSpec(1)))
    sim = SAFSSim(n_ssds=2, ssd=P, occupancy=0.6,
                  workload=SAFSWorkload(concurrency=64, scenario="mixed",
                                        writer_frac=0.5),
                  cache_frac=0.1, seed=3, qos=pol)
    r = sim.run(6000)
    assert r.app_iops > 0
    assert set(r.tenant_stats) == {0, 1}
    assert all(st.ops > 0 for st in r.tenant_stats.values())
    assert sum(st.ops for st in r.tenant_stats.values()) <= r.app_ops
    # deterministic rerun
    sim2 = SAFSSim(n_ssds=2, ssd=P, occupancy=0.6,
                   workload=SAFSWorkload(concurrency=64, scenario="mixed",
                                         writer_frac=0.5),
                   cache_frac=0.1, seed=3, qos=pol)
    r2 = sim2.run(6000)
    assert r2.tenant_stats[0].p99_latency == r.tenant_stats[0].p99_latency


# ---------------------------------------------------------------------------
# nightly: the full qos acceptance sweep
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_qos_sweep_full_tier(tmp_path):
    """Nightly: the full 12-SSD qos sweep (the committed BENCH_qos.json
    tier) must pass every built-in check — shares within 10% of weights,
    SLO protection improving the reader's p99 under active GC, the writer
    actually throttled, serial == sharded per-tenant stats."""
    import json
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "BENCH_qos.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.qos_sweep", "--out", str(out)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out.read_text())
    assert payload["all_checks_pass"]
    assert payload["n_ssds"] >= 12 and len(payload["weight_sweep"]) >= 3
    sp = payload["slo_protection"]
    assert sp["qos"]["reader_p99_ms"] < sp["no_qos"]["reader_p99_ms"]

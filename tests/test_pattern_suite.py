"""Pattern-suite properties: determinism, bounds, footprint, phase budgets.

Deterministic property tests always run; the hypothesis block at the bottom
widens the same properties over random parameter spaces when hypothesis is
installed (requirements-dev.txt)."""
import numpy as np
import pytest

from repro.core.workloads import (
    PATTERNS,
    HotColdSource,
    Op,
    OpSource,
    Phase,
    PhasedScenario,
    SnakeSource,
    StridedSource,
    WriteThenReadSource,
    register_pattern,
    source_for,
)


class _WL:
    """Duck-typed workload spec (source_for reads attrs via getattr)."""

    def __init__(self, **kw):
        self.__dict__.update(kw)


NEW_PATTERNS = ("strided", "snake", "hot_cold", "write_then_read")
N_LIVE = 480


def _stream(scenario, seed, n_ops, n_live=N_LIVE, **kw):
    src = source_for(_WL(scenario=scenario, read_frac=0.3, **kw), n_live,
                     np.random.default_rng(seed))
    return [src.next_op(0.0) for _ in range(n_ops)]


# -- seed determinism / bounds ----------------------------------------------

@pytest.mark.parametrize("scenario", NEW_PATTERNS)
def test_new_sources_seed_deterministic(scenario):
    a = _stream(scenario, 7, 1000)
    b = _stream(scenario, 7, 1000)
    assert a == b


@pytest.mark.parametrize("scenario", NEW_PATTERNS)
def test_new_sources_stay_in_bounds(scenario):
    for op in _stream(scenario, 3, 2000):
        assert 0 <= op.lba < N_LIVE
        assert op.at == 0.0          # all four are closed-loop


def test_registry_covers_new_patterns():
    for scenario in NEW_PATTERNS:
        assert scenario in PATTERNS


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown workload scenario"):
        source_for(_WL(scenario="nope"), 64, np.random.default_rng(0))


def test_register_pattern_extends_dispatch():
    class _One(OpSource):
        def next_op(self, now):
            return Op(1, False)

    @register_pattern("_test_only")
    def _build(wl, n_live, rng, trace):
        return _One()

    try:
        src = source_for(_WL(scenario="_test_only"), 64,
                         np.random.default_rng(0))
        assert src.next_op(0.0).lba == 1
    finally:
        del PATTERNS["_test_only"]


# -- declared footprints -----------------------------------------------------

@pytest.mark.parametrize("n_live,stride", [
    (480, 64),    # gcd 32: naive modular cursor would visit only 15 LBAs
    (480, 7),     # coprime
    (100, 10),    # stride divides the space
    (48, 50),     # stride > n_live (folds to 2)
    (64, 64),     # stride ≡ 0 mod n_live (folds to a linear scan)
])
def test_strided_covers_whole_space(n_live, stride):
    src = StridedSource(n_live, np.random.default_rng(0), stride=stride)
    lbas = [src.next_op(0.0).lba for _ in range(n_live)]
    assert sorted(lbas) == list(range(n_live))      # each LBA exactly once
    assert src.footprint(n_live) == n_live
    # and the cycle repeats: the next n_live ops cover the space again
    lbas2 = [src.next_op(0.0).lba for _ in range(n_live)]
    assert sorted(lbas2) == list(range(n_live))


def test_snake_covers_space_and_never_repeats():
    n = 97
    src = SnakeSource(n, np.random.default_rng(0))
    lbas = [src.next_op(0.0).lba for _ in range(4 * n)]
    assert set(lbas[:n]) == set(range(n))           # first sweep covers all
    for a, b in zip(lbas, lbas[1:]):
        assert abs(a - b) == 1                      # always adjacent...
    assert lbas[n - 1] == n - 1 and lbas[n] == n - 2  # ...turns w/o repeat


def test_hot_cold_respects_declared_split():
    n, hot_frac, hot_ops = 1000, 0.1, 0.9
    src = HotColdSource(n, np.random.default_rng(5), hot_frac=hot_frac,
                        hot_ops=hot_ops)
    assert src.hot_pages == 100
    lbas = np.array([src.next_op(0.0).lba for _ in range(20000)])
    hot_share = float(np.mean(lbas < src.hot_pages))
    assert abs(hot_share - hot_ops) < 0.02          # ops skew as declared
    assert lbas.max() >= src.hot_pages              # cold zone is reached
    # the hot zone footprint is the declared slice, nothing more
    assert set(lbas[lbas < src.hot_pages]) <= set(range(src.hot_pages))


def test_write_then_read_reads_back_what_it_wrote():
    n, span = 300, 64
    src = WriteThenReadSource(n, np.random.default_rng(0), span=span)
    first = [src.next_op(0.0) for _ in range(span)]
    second = [src.next_op(0.0) for _ in range(span)]
    assert all(not op.is_read for op in first)
    assert all(op.is_read for op in second)
    assert [op.lba for op in first] == [op.lba for op in second]
    # next extent starts where the previous ended
    assert src.next_op(0.0).lba == span % n


def test_write_then_read_draws_no_rng():
    rng = np.random.default_rng(11)
    before = rng.bit_generator.state
    src = WriteThenReadSource(500, rng, span=32)
    for _ in range(200):
        src.next_op(0.0)
    assert rng.bit_generator.state == before


# -- phase boundaries --------------------------------------------------------

class _Tagged(OpSource):
    """Emits its own phase id as the LBA — leaks across boundaries are
    visible as a wrong id at a known offset."""

    def __init__(self, ident):
        self.ident = ident
        self.drawn = 0

    def next_op(self, now):
        self.drawn += 1
        return Op(self.ident, False)


def test_phased_scenario_budgets_are_exact():
    srcs = [_Tagged(i) for i in range(3)]
    sc = PhasedScenario([
        Phase("precondition", srcs[0], 10, measure=False),
        Phase("burst", srcs[1], 7, warmup=3),
        Phase("measure", srcs[2], 5),
    ])
    ids = [sc.next_op(0.0).lba for _ in range(40)]
    # exactly total_ops from each non-final phase, in order; the final
    # phase is open-ended and absorbs the closed-loop overshoot
    assert ids == [0] * 10 + [1] * 10 + [2] * 20
    assert srcs[0].drawn == 10 and srcs[1].drawn == 10 and srcs[2].drawn == 20


def test_phased_scenario_rejects_empty_and_zero_budget():
    with pytest.raises(AssertionError):
        PhasedScenario([])
    with pytest.raises(AssertionError):
        PhasedScenario([Phase("a", _Tagged(0), 0),
                        Phase("b", _Tagged(1), 5)])


def test_phased_scenario_current_phase_tracks():
    sc = PhasedScenario([Phase("a", _Tagged(0), 2), Phase("b", _Tagged(1), 2)])
    assert sc.current_phase.name == "a"
    for _ in range(3):
        sc.next_op(0.0)
    assert sc.current_phase.name == "b"


def test_run_phased_windows_do_not_leak(tmp_path):
    """Sim-level boundary check: each phase's measurement window reports
    exactly its own op budget, and per-window counters restart at the
    boundary — the write-only phase sees zero SSD fill reads, the read-only
    phase sees them, so the two windows demonstrably don't share counters.
    (Background flushes DO continue into the read phase: the flusher
    draining the burst's dirty pages is the drain phase's entire point.)"""
    from repro.core.gc_sim import SSDParams
    from repro.core.safs_sim import SAFSSim, SAFSWorkload

    P = SSDParams(capacity_pages=4096)
    sim = SAFSSim(2, P, 0.8, SAFSWorkload(concurrency=32), seed=0)
    n = sim.n_live
    rng = np.random.default_rng(1)
    phases = [
        Phase("write_burst", HotColdSource(n, rng, read_frac=0.0), 1500,
              warmup=300),
        Phase("read_drain", HotColdSource(n, rng, read_frac=1.0), 1500,
              warmup=600),
    ]
    out = sim.run_phased(phases)
    assert [name for name, _ in out] == ["write_burst", "read_drain"]
    burst, drain = out[0][1], out[1][1]
    assert burst.app_ops == 1500 and drain.app_ops == 1500
    # aligned writes fill no pages -> zero SSD reads in the write window;
    # the read window's misses do fill. Any cross-window counter leak (in
    # either direction) breaks one of the two.
    assert burst.ssd_reads == 0
    assert drain.ssd_reads > 0
    assert burst.flush_writes + burst.demand_writes > 0


# -- hypothesis widening -----------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=100, deadline=None)
    @given(n_live=st.integers(min_value=1, max_value=600),
           stride=st.integers(min_value=1, max_value=2000))
    def test_strided_coverage_property(n_live, stride):
        src = StridedSource(n_live, np.random.default_rng(0), stride=stride)
        lbas = sorted(src.next_op(0.0).lba for _ in range(n_live))
        assert lbas == list(range(n_live))

    @settings(max_examples=100, deadline=None)
    @given(n_live=st.integers(min_value=1, max_value=500),
           n_ops=st.integers(min_value=1, max_value=1500))
    def test_snake_bounds_property(n_live, n_ops):
        src = SnakeSource(n_live, np.random.default_rng(0))
        for _ in range(n_ops):
            assert 0 <= src.next_op(0.0).lba < n_live

    @settings(max_examples=100, deadline=None)
    @given(budgets=st.lists(st.integers(min_value=1, max_value=50),
                            min_size=1, max_size=6),
           extra=st.integers(min_value=0, max_value=100))
    def test_phased_budget_property(budgets, extra):
        srcs = [_Tagged(i) for i in range(len(budgets))]
        sc = PhasedScenario([Phase(str(i), s, b)
                             for i, (s, b) in enumerate(zip(srcs, budgets))])
        total = sum(budgets) + extra
        ids = [sc.next_op(0.0).lba for _ in range(total)]
        want = []
        for i, b in enumerate(budgets[:-1]):
            want += [i] * b
        want += [len(budgets) - 1] * (budgets[-1] + extra)
        assert ids == want

"""Async checkpoint manager: roundtrip, supersede/stale-discard, priority."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (32, 16)),
            "b": {"w": jax.random.normal(k, (8,)),
                  "s": jnp.asarray(seed, jnp.int32)}}


def test_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, n_targets=2)
    t = _tree(0)
    m.save_async(0, t)
    assert m.wait_for_commit(0, 30)
    step, got = m.restore(t)
    assert step == 0
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    m.close()


def test_supersede_discards_stale_writes(tmp_path):
    # a slow writer + rapid saves: early steps' chunks are discarded at the
    # queue head because newer saves superseded them (paper §3.3.2)
    m = CheckpointManager(tmp_path, n_targets=1, max_inflight=1,
                          write_delay=0.05)
    for s in range(6):
        m.save_async(s, _tree(s))
    assert m.drain(60)
    assert m.stats["discarded_stale"] > 0
    last = m.latest_step()
    assert last == 5
    _, got = m.restore(_tree(0))
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.asarray(_tree(5)["a"]))
    m.close()


def test_restore_runs_while_writes_queued(tmp_path):
    m = CheckpointManager(tmp_path, n_targets=1, max_inflight=1,
                          write_delay=0.02)
    t = _tree(1)
    m.save_async(0, t)
    assert m.wait_for_commit(0, 30)
    for s in range(1, 5):
        m.save_async(s, _tree(s))
    t0 = time.monotonic()
    step, got = m.restore(t, step=0)          # HIGH priority overtakes
    dt = time.monotonic() - t0
    assert step == 0
    # must not wait for the whole backlog (4 saves x 3 chunks x 20ms each)
    assert dt < 0.2, f"restore waited {dt}s behind low-priority writes"
    m.drain(60)
    m.close()


def test_resume_to_different_structure_fails_loud(tmp_path):
    m = CheckpointManager(tmp_path)
    m.save_async(0, _tree(0))
    assert m.wait_for_commit(0, 30)
    with pytest.raises(Exception):
        m.restore({"different": jnp.zeros(3)})
    m.close()


def test_retention(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        m.save_async(s, _tree(s))
        assert m.wait_for_commit(s, 30)
    # wait_for_commit returns the moment the new manifest lands, which is
    # *before* the worker prunes old manifests (retention runs right after
    # the rename in the same _commit); drain() returns only once that whole
    # completion finished, so the glob below can't race the pruning
    assert m.drain(30)
    manifests = sorted(p.name for p in tmp_path.glob("manifest-*.json"))
    assert manifests == ["manifest-3.json", "manifest-4.json"]
    m.close()


def test_changed_keys_filter(tmp_path):
    m = CheckpointManager(tmp_path, n_targets=2)
    t = _tree(0)
    m.save_async(0, t)
    assert m.wait_for_commit(0, 30)
    w0 = m.stats["written"]
    m.save_async(1, t, changed={"a"})          # dirty-chunk tracking
    m.drain(30)
    assert m.stats["written"] == w0 + 1
    m.close()


def test_write_barrier_orders_durability(tmp_path):
    """Paper §3.4: everything before the barrier is durable after it."""
    m = CheckpointManager(tmp_path, n_targets=2, write_delay=0.01)
    for s in range(3):
        m.save_async(s, _tree(s))
    assert m.barrier(60)
    # all surviving (non-superseded) steps are committed now
    assert m.latest_step() == 2
    committed = sorted(int(p.stem.split("-")[1])
                       for p in tmp_path.glob("manifest-*.json"))
    drained = m.stats["written"] + m.stats["discarded_stale"]
    assert drained == 3 * 3  # 3 chunks per tree, none left in flight
    assert committed[-1] == 2
    m.close()


def test_elastic_restore_reshards(tmp_path):
    """Restore onto explicit (different) shardings — elastic resume path."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = CheckpointManager(tmp_path)
    t = _tree(4)
    m.save_async(0, t)
    assert m.wait_for_commit(0, 30)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    step, got = m.restore(t, shardings=sh)
    assert step == 0
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        assert b.sharding.is_equivalent_to(NamedSharding(mesh, P()), b.ndim)
    m.close()

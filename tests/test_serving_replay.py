"""Serving-trace bridge: shim determinism, the .npz container, fleet
emission, and replay through the (sharded) array simulator."""
import numpy as np
import pytest

from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.io_queues import HIGH, LOW, IORequest
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.sharded import ShardedArraySim
from repro.core.workloads import TRACE_READ, TRACE_WRITE
from repro.serving.fleet import (PAGES_PER_SESSION_CAP, FleetConfig,
                                 run_fleet)
from repro.serving.kv_pool import PagedKVPool
from repro.serving.trace_shim import (CKPT_TENANT, LogicalClock,
                                      ServingTraceRecorder, load_trace,
                                      save_trace, stable_key_lba,
                                      trace_digest)

SMALL = SSDParams(capacity_pages=4096)

SMOKE = FleetConfig(n_targets=4, duration_s=0.2, arrival_rate=400.0,
                    pool_sets=8, set_size=8, flush_trigger=1)


# -- recorder mechanics ------------------------------------------------------


def _pool_with_recorder(n_targets=4, tenant_of=None):
    rec = ServingTraceRecorder(n_targets, tenant_of=tenant_of)
    pool = PagedKVPool(8, 8, n_targets=n_targets,
                       copy_out=lambda tag: (),
                       copy_in=lambda tag, data: None,
                       flush_trigger=0)
    rec.attach_pool(pool)
    return pool, rec


def test_recorder_captures_offload_and_fetch_with_tenants():
    pool, rec = _pool_with_recorder(tenant_of=lambda tag: tag % 3)
    for tag in (5, 6):
        pool.alloc.alloc(tag)
        pool.alloc.mark_full(tag)
        pool.note_page_full(pool.alloc.set_of(tag))
    rec.advance(1e-3)
    rec.pump()
    assert pool.alloc.stats.offloads == 2
    # evict from HBM then fetch back: a HIGH read, served synchronously
    pool.alloc.free([5])
    rec.advance(1e-3)
    pool.fetch([5])
    tr = rec.to_array()
    assert tr.shape == (3, 4)
    writes = tr[tr[:, 2] == TRACE_WRITE]
    reads = tr[tr[:, 2] == TRACE_READ]
    assert {int(r[1]) for r in writes} == {5, 6}
    assert [int(r[1]) for r in reads] == [5]
    # tenant column comes from tenant_of(tag)
    for row in tr:
        assert int(row[3]) == int(row[1]) % 3
    # clock stamped: offloads at t=1ms, fetch at t=2ms
    assert list(tr[:, 0]) == pytest.approx([1e-3, 1e-3, 2e-3])
    pool.close()


def test_recorder_counts_stale_discards_without_emitting():
    """A flush whose page was freed before reaching the queue head is
    discarded by the dual-queue staleness check: counted, never recorded."""
    pool, rec = _pool_with_recorder()
    pool.alloc.alloc(9)
    pool.alloc.mark_full(9)
    pool.note_page_full(pool.alloc.set_of(9))
    pool.alloc.free([9])               # sequence finished before the flush
    rec.pump()
    assert rec.stale_discards() == 1
    assert pool.alloc.stats.stale_discards == 1
    assert rec.to_array().shape == (0, 4)
    pool.close()


def test_recorder_high_priority_is_synchronous():
    """HIGH requests must complete inside submit() — the pool's fetch()
    blocks on a semaphore the device callback releases."""
    hits = []
    rec = ServingTraceRecorder(2)
    ex = rec._make_exec(2, lambda dev, payload: hits.append(dev))
    ex.submit(1, IORequest(payload={"op": "fetch", "tag": 3}, priority=HIGH))
    assert hits == [1]
    ex.submit(0, IORequest(payload={"op": "offload", "tag": 2},
                           priority=LOW))
    assert hits == [1]                 # LOW waits for an explicit pump
    assert ex.pump() == 1
    assert hits == [1, 0]


def test_recorder_unknown_payload_executes_but_records_nothing():
    hits = []
    rec = ServingTraceRecorder(1)
    ex = rec._make_exec(1, lambda dev, payload: hits.append(payload))
    ex.submit(0, IORequest(payload={"op": "mystery"}, priority=HIGH))
    assert hits == [{"op": "mystery"}]
    assert rec.to_array().shape == (0, 4)


def test_logical_clock_and_record_direct():
    rec = ServingTraceRecorder(2)
    rec.advance(0.5)
    rec.record_direct(17, TRACE_WRITE, tenant=4)
    tr = rec.to_array()
    assert tr.tolist() == [[0.5, 17.0, 1.0, 4.0]]
    assert isinstance(rec.clock, LogicalClock)


def test_stable_key_lba_is_process_stable():
    """Pinned values: a salted hash() here would silently break the
    emit-twice byte-identity contract across processes."""
    assert stable_key_lba("ckpt/0/layer0") == stable_key_lba("ckpt/0/layer0")
    assert stable_key_lba("a") != stable_key_lba("b")
    # float64-exact: the lba column must round-trip the int losslessly
    v = stable_key_lba("x")
    assert 0 <= v < 2 ** 52 and int(float(v)) == v


def test_attach_ckpt_records_chunk_writes(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.checkpoint.async_ckpt import CheckpointManager
    rec = ServingTraceRecorder(4)
    mgr = CheckpointManager(tmp_path, n_targets=4)
    rec.attach_ckpt(mgr)
    mgr.save_async(step=1, tree={"w": jax.numpy.zeros((4,)),
                                 "b": jax.numpy.ones((2,))})
    mgr.barrier()
    tr = rec.to_array()
    assert len(tr) == 2
    assert set(tr[:, 2]) == {float(TRACE_WRITE)}
    assert set(tr[:, 3]) == {float(CKPT_TENANT)}
    # placement was pinned to the stable hash: the recorded LBA names the
    # target that actually served the write
    assert {int(row[1]) % 4 for row in tr} == \
        {mgr._target_of(k) for k in ("w", "b")}
    assert {int(row[1]) for row in tr} == \
        {stable_key_lba("w"), stable_key_lba("b")}
    mgr.close()


# -- container ---------------------------------------------------------------


def test_save_load_round_trip(tmp_path):
    tr = np.array([[0.0, 5, 1, 0], [1.0, 6, 0, 2]], dtype=np.float64)
    p = tmp_path / "t.npz"
    save_trace(p, tr, meta={"n_targets": 4})
    back, meta = load_trace(p, with_meta=True)
    np.testing.assert_array_equal(back, tr)
    assert trace_digest(back) == trace_digest(tr)
    assert meta == {"n_targets": 4}


def test_load_rejects_future_version(tmp_path):
    p = tmp_path / "t.npz"
    np.savez(p, version=np.int64(99), trace=np.zeros((1, 4)))
    with pytest.raises(ValueError):
        load_trace(p)


def test_trace_digest_distinguishes_shape_and_content():
    a = np.zeros((2, 4))
    assert trace_digest(a) == trace_digest(a.copy())
    assert trace_digest(a) != trace_digest(np.zeros((4, 2)))
    b = a.copy()
    b[0, 0] = 1e-9
    assert trace_digest(a) != trace_digest(b)


# -- fleet -------------------------------------------------------------------


def test_fleet_same_seed_emits_byte_identical_trace():
    a = run_fleet(SMOKE, seed=11)
    b = run_fleet(SMOKE, seed=11)
    assert trace_digest(a.trace) == trace_digest(b.trace)
    assert a.tokens_total == b.tokens_total
    assert a.offloads == b.offloads and a.fetches == b.fetches


def test_fleet_different_seed_differs():
    a = run_fleet(SMOKE, seed=11)
    b = run_fleet(SMOKE, seed=12)
    assert trace_digest(a.trace) != trace_digest(b.trace)


def test_fleet_trace_is_nontrivial_and_well_formed():
    r = run_fleet(SMOKE, seed=11)
    tr = r.trace
    assert len(tr) > 0 and tr.shape[1] == 4
    assert r.offloads > 0 and r.stale_discards > 0
    assert np.all(np.diff(tr[:, 0]) >= 0)              # time-ordered
    assert set(np.unique(tr[:, 2])) <= {0.0, 1.0}
    # tenants are the two fleet classes (no checkpoint manager attached)
    assert set(np.unique(tr[:, 3])) <= {0.0, 1.0}
    # tag layout round-trips to a session id
    sids = tr[:, 1].astype(np.int64) // PAGES_PER_SESSION_CAP
    assert sids.max() < r.sessions_started


# -- replay ------------------------------------------------------------------


def _qos():
    return QosPolicy(tenants=(TenantSpec(0, 2.0, slo_p99=4e-3),
                              TenantSpec(1, 1.0)))


def test_replay_propagates_tenants_into_tenant_stats():
    r = run_fleet(SMOKE, seed=11)
    wl = Workload(scenario="trace", w_total=4 * 8, qd_per_ssd=8,
                  n_streams=4, trace_time_scale=0.05)
    res = ArraySim(4, SMALL, 0.6, wl, seed=2, trace=r.trace,
                   qos=_qos()).run(len(r.trace))
    assert set(res.tenant_stats) == {0, 1}
    assert res.tenant_stats[0].ops > 0
    # every measured completion is attributed to exactly one tenant
    assert sum(s.ops for s in res.tenant_stats.values()) == len(r.trace)
    assert res.tenant_stats[0].slo_p99 == 4e-3


def test_replay_is_deterministic():
    r = run_fleet(SMOKE, seed=11)
    wl = Workload(scenario="trace", w_total=4 * 8, qd_per_ssd=8, n_streams=4)
    runs = [ArraySim(4, SMALL, 0.6, wl, seed=2, trace=r.trace,
                     qos=_qos()).run(800) for _ in range(2)]
    assert runs[0].iops == runs[1].iops
    assert runs[0].p99_latency == runs[1].p99_latency
    assert all(runs[0].tenant_stats[t].p99_latency
               == runs[1].tenant_stats[t].p99_latency
               for t in runs[0].tenant_stats)


def test_replay_sharded_serial_equals_parallel():
    """Acceptance: the emitted trace replays bit-identically whether the
    shard decomposition runs in-process or across workers."""
    r = run_fleet(SMOKE, seed=11)
    wl = Workload(scenario="trace", w_total=4 * 8, qd_per_ssd=8, n_streams=4,
                  trace_time_scale=0.05)
    mk = lambda par: ShardedArraySim(4, SMALL, 0.6, wl, seed=2, n_shards=2,
                                     trace=r.trace, qos=_qos(), parallel=par)
    a, b = mk(False).run(len(r.trace)), mk(True).run(len(r.trace))
    assert a.iops == b.iops
    assert a.p99_latency == b.p99_latency
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    assert all(a.tenant_stats[t].p99_latency == b.tenant_stats[t].p99_latency
               and a.tenant_stats[t].ops == b.tenant_stats[t].ops
               for t in a.tenant_stats)


def test_replay_single_op_trace():
    tr = np.array([[0.0, 3, TRACE_WRITE, 0]])
    wl = Workload(scenario="trace", w_total=8, qd_per_ssd=4, n_streams=2)
    res = ArraySim(2, SMALL, 0.6, wl, seed=0, trace=tr,
                   qos=QosPolicy(tenants=(TenantSpec(0, 1.0),))).run(4)
    assert res.tenant_stats[0].ops == 4                # the one-row trace loops


def test_sharded_replay_with_empty_shard():
    """A trace touching only low devices leaves the high shard with zero
    records AND a zero op budget — its sim must be a no-op, not a crash."""
    n = 80
    tr = np.stack([np.arange(200) * 1e-5,
                   (np.arange(200) * 2) % 8,           # devices 0..7 only
                   np.ones(200), np.zeros(200)], axis=1)
    wl = Workload(scenario="trace", w_total=n * 4, qd_per_ssd=4, n_streams=n)
    res = ShardedArraySim(n, SMALL, 0.6, wl, seed=1, n_shards=4,
                          trace=tr, parallel=False).run(200)
    assert res.events > 0
    assert res.per_ssd_iops.shape == (n,)
    assert np.all(res.per_ssd_iops[40:] == 0.0)        # untouched shards


def test_sharded_replay_requires_trace_and_trivial_layout():
    wl = Workload(scenario="trace", w_total=16, qd_per_ssd=4, n_streams=4)
    with pytest.raises(ValueError):
        ShardedArraySim(4, SMALL, 0.6, wl)             # no trace given
    from repro.core.raid import Raid5Layout
    with pytest.raises(ValueError):
        ShardedArraySim(4, SMALL, 0.6, wl, trace=np.zeros((1, 4)),
                        layout=Raid5Layout(group=4))

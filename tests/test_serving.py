"""Serving engine: exactness vs dense decode, pool pressure, flusher effect."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serving import ServeEngine
from repro.serving.kv_pool import PagedAllocator

pytestmark = pytest.mark.slow  # end-to-end engine runs: nightly tier

RNG = jax.random.PRNGKey(0)


def _dense_greedy(params, cfg, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    last, cache = T.prefill(params, toks, cfg, max_seq=128)
    out = []
    t = jnp.argmax(last[:, -1, :], -1)[:, None].astype(jnp.int32)
    for _ in range(n):
        out.append(int(t[0, 0]))
        lg, cache = T.decode_step(params, t, cache, cfg)
        t = jnp.argmax(lg[:, -1, :], -1)[:, None].astype(jnp.int32)
    return out


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(get_config("tinyllama-1.1b"))
    return cfg, T.init_params(RNG, cfg)


def test_engine_matches_dense_decode(tiny_model):
    cfg, params = tiny_model
    eng = ServeEngine(cfg, params, max_batch=3, page_size=8, num_sets=16,
                      set_size=4)
    prompts = [[5, 7, 11, 13, 17], [2, 3],
               [21, 22, 23, 24, 25, 26, 27, 28, 29]]
    rids = [eng.submit(p, max_new=10) for p in prompts]
    eng.run(200)
    for rid, p in zip(rids, prompts):
        assert eng.result(rid).out == _dense_greedy(params, cfg, p, 10)
    eng.close()


def test_engine_exact_under_pool_pressure(tiny_model):
    """Preemption + offload + resume must be lossless."""
    cfg, params = tiny_model
    eng = ServeEngine(cfg, params, max_batch=4, page_size=8, num_sets=4,
                      set_size=3)
    rng = np.random.default_rng(3)
    prompts = [[int(x) for x in rng.integers(1, 250, int(rng.integers(3, 20)))]
               for _ in range(6)]
    rids = [eng.submit(p, max_new=24) for p in prompts]
    eng.run(800)
    st = eng.stats()
    assert st["preemptions"] > 0, "test must exercise the pressure path"
    assert st["offloads"] > 0 and st["fetches"] > 0
    for rid, p in zip(rids, prompts):
        r = eng.result(rid)
        assert r.state == "done"
        assert r.out == _dense_greedy(params, cfg, p, 24), f"rid{rid}"
    eng.close()


def test_engine_with_paged_kernel(tiny_model):
    """Same outputs when attention runs through the Pallas paged kernel."""
    cfg, params = tiny_model
    eng = ServeEngine(cfg, params, max_batch=2, page_size=8, num_sets=16,
                      set_size=4, use_kernel=True)
    prompts = [[5, 7, 11], [40, 41, 42, 43, 44]]
    rids = [eng.submit(p, max_new=6) for p in prompts]
    eng.run(100)
    for rid, p in zip(rids, prompts):
        assert eng.result(rid).out == _dense_greedy(params, cfg, p, 6)
    eng.close()


def test_mamba_engine(tiny_model):
    """Attention-free arch: state pages instead of KV pages."""
    cfg = reduced(get_config("mamba2-780m"))
    params = T.init_params(RNG, cfg)
    eng = ServeEngine(cfg, params, max_batch=2, page_size=8, num_sets=8,
                      set_size=2)
    p = [3, 1, 4, 1, 5, 9, 2, 6]
    rid = eng.submit(p, max_new=8)
    eng.run(100)
    assert eng.result(rid).out == _dense_greedy(params, cfg, p, 8)
    eng.close()


def test_flusher_precleaning_reduces_blocking_offloads(tiny_model):
    """The paper's claim, transplanted: background pre-cleaning turns blocking
    (dirty) evictions into instant (clean) ones."""
    cfg, params = tiny_model
    results = {}
    for use_flusher in (True, False):
        eng = ServeEngine(cfg, params, max_batch=4, page_size=8, num_sets=4,
                          set_size=3, use_flusher=use_flusher)
        rng = np.random.default_rng(5)
        prompts = [[int(x) for x in rng.integers(1, 250, 16)]
                   for _ in range(8)]
        rids = [eng.submit(p, max_new=24) for p in prompts]
        eng.run(1200)
        assert all(eng.result(r).state == "done" for r in rids)
        results[use_flusher] = eng.stats()
        eng.close()
    # pre-cleaning converts blocking (dirty) evictions into instant (clean)
    # ones: with the flusher ON, strictly more clean evictions and no more
    # blocking offload work on the critical path
    assert results[True]["offloads"] > 0
    assert results[True]["clean_evictions"] >= \
        results[False]["clean_evictions"]
    assert results[True]["blocking_offloads"] <= \
        results[False]["blocking_offloads"]


def test_allocator_never_evicts_pinned():
    a = PagedAllocator(num_sets=2, set_size=2)
    tags = []
    # fill the pool, all pinned
    t = 0
    while len(tags) < 4:
        pid, ev, _ = a.alloc(t)
        if pid is not None:
            tags.append(t)
        t += 1
        if t > 100:
            break
    # further allocation in a full-pinned set must fail, never evict
    before = dict(a.where)
    for tt in range(200, 260):
        pid, ev, _ = a.alloc(tt)
        if pid is not None:          # only possible if a set had room
            pytest.fail("alloc succeeded in fully pinned pool")
    assert dict(a.where) == before
    # unpin one -> allocation succeeds by evicting exactly that page
    a.set_pinned([tags[0]], False)
    s = a.set_of(tags[0])
    for tt in range(300, 400):
        if a.set_of(tt) == s:
            pid, ev, _ = a.alloc(tt)
            assert pid is not None and ev == tags[0]
            break

"""Property test: SealFifo vs a reference model under append/remove churn.

The reference is a plain seal-ordered list with O(n) removal — exactly what
SealFifo replaced. Under any interleaving of appends and removes (including
ones that trigger repeated tombstone compactions), length, membership,
iteration order, and head_window must match the reference.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.gc_sim import SealFifo


@st.composite
def churn_script(draw):
    """A list of operations: ('append', b) with fresh b, or ('remove', i)
    removing the i-th (mod current length) live block."""
    n_ops = draw(st.integers(min_value=1, max_value=200))
    ops = []
    next_block = 0
    n_live = 0
    for _ in range(n_ops):
        if n_live == 0 or draw(st.booleans()):
            ops.append(("append", next_block))
            next_block += 1
            n_live += 1
        else:
            ops.append(("remove", draw(st.integers(min_value=0,
                                                   max_value=10_000))))
            n_live -= 1
    return ops


@settings(max_examples=200, deadline=None)
@given(churn_script())
def test_seal_fifo_matches_reference_under_churn(ops):
    sf = SealFifo()
    ref: list[int] = []
    for op, arg in ops:
        if op == "append":
            sf.append(arg)
            ref.append(arg)
        else:
            victim = ref[arg % len(ref)]
            sf.remove(victim)
            ref.remove(victim)
        # full-state equivalence after every operation
        assert len(sf) == len(ref)
        assert list(sf) == ref
        for b in ref:
            assert b in sf
    for k in (0, 1, 2, len(ref), len(ref) + 3):
        assert sf.head_window(k) == ref[:k]


@settings(max_examples=50, deadline=None)
@given(churn_script(), st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_seal_fifo_sample_distinct_under_churn(ops, k, seed):
    import numpy as np
    sf = SealFifo()
    ref: list[int] = []
    for op, arg in ops:
        if op == "append":
            sf.append(arg)
            ref.append(arg)
        else:
            victim = ref[arg % len(ref)]
            sf.remove(victim)
            ref.remove(victim)
    if not ref:
        return
    got = sf.sample_distinct(np.random.default_rng(seed), k)
    assert len(got) == min(k, len(ref))
    assert len(set(got)) == len(got)           # distinct
    assert set(got) <= set(ref)                # only live blocks

"""Cost-model regression tests: in-place-update crediting + dtype notes."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze


def test_dus_credited_as_slice_not_buffer():
    """A scan that updates one row of a big buffer per step must charge
    row-bytes x trips, not buffer-bytes x trips."""
    n, rows, d = 64, 512, 256

    def f(buf, xs):
        def body(b, i):
            return jax.lax.dynamic_update_slice(
                b, xs[i][None], (i, jnp.int32(0))), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(n))
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((rows, d), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32)).compile()
    res = analyze(c.as_text())
    buffer_bytes = rows * d * 4
    slice_bytes = d * 4
    # full-buffer charging would be >= n * buffer_bytes = 33.5 MB
    assert res["memory_bytes"] < 0.2 * n * buffer_bytes, res["memory_bytes"]
    assert res["memory_bytes"] >= n * slice_bytes


def test_scatter_credited_as_updates():
    """The scatter itself must charge update bytes; the only buffer-sized
    cost left is XLA's defensive copy (real without donation — with
    donate_argnums it disappears on device)."""
    def f(buf, idx, upd):
        return buf.at[idx].set(upd)

    buf_bytes = 4096 * 128 * 4
    c = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((4096, 128), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.int32),
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    res = analyze(c.as_text())
    # un-credited accounting would be >= 2x buffer (copy + full scatter out)
    assert res["memory_bytes"] < 1.5 * buf_bytes, res["memory_bytes"]


def test_flops_exclude_elementwise():
    c = jax.jit(lambda x: jnp.tanh(x) * 2 + 1).lower(
        jax.ShapeDtypeStruct((1024, 1024), jnp.float32)).compile()
    res = analyze(c.as_text())
    assert res["flops"] == 0.0


def test_nested_loop_multiplicity():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    res = analyze(c.as_text())
    assert res["flops"] == pytest.approx(12 * 2 * 128 ** 3, rel=0.01)

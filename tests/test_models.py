"""Per-arch smoke tests (reduced configs) + prefill/decode == forward."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as T

RNG = jax.random.PRNGKey(0)


def _inputs(cfg, b, s):
    kw = {}
    if cfg.encoder_layers:
        kw["enc_frames"] = jax.random.normal(
            RNG, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.vis_tokens:
        kw["vis_embeds"] = jax.random.normal(RNG, (b, cfg.vis_tokens,
                                                   cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = T.init_params(RNG, cfg)
    b, s = 2, 32
    toks = jax.random.randint(RNG, (b, s), 0, cfg.vocab)
    kw = _inputs(cfg, b, s)
    logits, aux = T.forward_logits(params, toks, cfg, **kw)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    def loss_fn(p):
        hidden, aux2 = T.forward(p, toks, cfg, **kw)
        return T.lm_loss(p, cfg, hidden, toks) + 0.01 * aux2["lb"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = T.init_params(RNG, cfg)
    b, s, extra = 2, 24, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0,
                              cfg.vocab)
    kw = _inputs(cfg, b, s + extra)
    full, _ = T.forward_logits(params, toks, cfg, **kw)
    last, cache = T.prefill(params, toks[:, :s], cfg, max_seq=64, **kw)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, s - 1:s]),
                               atol=5e-3, rtol=5e-3)
    for t in range(s, s + extra):
        lg, cache = T.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t:t + 1]),
                                   atol=5e-3, rtol=5e-3)


@pytest.mark.slow
def test_sliding_window_ring_buffer_wraps():
    """danube-style SWA: decode far past the window; ring must stay correct."""
    cfg = reduced(get_config("h2o-danube-3-4b"))
    # shrink window so decode wraps it
    import dataclasses
    from repro.configs.base import LayerSpec
    cfg = dataclasses.replace(
        cfg, block=(LayerSpec(kind="attn", ffn="mlp", window=8),), n_layers=2)
    params = T.init_params(RNG, cfg)
    b, s, extra = 1, 12, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + extra), 0,
                              cfg.vocab)
    full, _ = T.forward_logits(params, toks, cfg)
    last, cache = T.prefill(params, toks[:, :s], cfg, max_seq=64)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, s - 1:s]),
                               atol=5e-3, rtol=5e-3)
    for t in range(s, s + extra):
        lg, cache = T.decode_step(params, toks[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t:t + 1]),
                                   atol=5e-3, rtol=5e-3)


@pytest.mark.slow
def test_param_count_analytics_match_actual():
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        params = T.init_params(RNG, cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.02, \
            f"{arch}: actual {actual} vs predicted {predicted}"


def test_moe_capacity_drops_are_bounded():
    """With the production capacity factor, dropped tokens reduce but do not
    zero the output."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")),
                              moe_capacity_factor=1.25)
    params = T.init_params(RNG, cfg)
    toks = jax.random.randint(RNG, (4, 64), 0, cfg.vocab)
    logits, aux = T.forward_logits(params, toks, cfg)
    assert not bool(jnp.isnan(logits).any())
    assert float(aux["lb"]) > 0


@pytest.mark.slow
def test_mamba_chunk_invariance():
    """SSD chunked scan must not depend on the chunk size."""
    from repro.models.ssm import init_mamba, mamba_chunked
    cfg = reduced(get_config("mamba2-780m"))
    p = init_mamba(RNG, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    y16 = mamba_chunked(x, p, cfg, chunk=16)
    y64 = mamba_chunked(x, p, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=2e-4,
                               rtol=2e-4)

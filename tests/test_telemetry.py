"""Telemetry subsystem (core/telemetry.py): the passive-observer contract.

The load-bearing invariant: attaching telemetry must not change the
simulation. Series sampling piggybacks on the event stream (no probe events),
probes are read-only, and no telemetry path consumes RNG — so a run with
telemetry on must be BYTE-IDENTICAL (latency samples, counters, event count,
RNG stream) to the same run with ``telemetry=None``, on every run loop.
"""
import json

import numpy as np
import pytest

from repro.core.engine import EventLoop
from repro.core.gc_coord import StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.raid import Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.sharded import ShardedArraySim, ShardedSAFSSim
from repro.core.telemetry import (ARRAY_COMPONENTS, SAFS_COMPONENTS,
                                  Telemetry, TelemetrySpec, merge_telemetry)

P = SSDParams(capacity_pages=2048)
FULL = TelemetrySpec(series_dt=2e-4, spans=True)


def _array(telemetry=None, **kw):
    base = dict(n_ssds=3, ssd=P, occupancy=0.6,
                workload=Workload(w_total=96, qd_per_ssd=16, n_streams=3),
                seed=42, telemetry=telemetry)
    base.update(kw)
    return ArraySim(**base)


def _assert_same_results(a, b):
    """Byte-identity of everything the simulation computes."""
    assert a.iops == b.iops
    assert a.mean_latency == b.mean_latency
    assert a.p50_latency == b.p50_latency
    assert a.p99_latency == b.p99_latency
    assert a.events == b.events          # no extra scheduled events
    np.testing.assert_array_equal(a.util, b.util)
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    np.testing.assert_array_equal(a.gc_pause_frac, b.gc_pause_frac)


# ---------------------------------------------------------------------------
# On/off byte-identity on every run loop
# ---------------------------------------------------------------------------

def test_fast_loop_identity():
    off, on = _array(), _array(FULL)
    ra, rb = off.run(4000), on.run(4000)
    _assert_same_results(ra, rb)
    # identical raw latency samples and identical RNG consumption
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    assert off.rng.bit_generator.state == on.rng.bit_generator.state
    assert ra.telemetry is None
    assert rb.telemetry is not None


def test_layout_loop_identity():
    kw = dict(n_ssds=6, workload=Workload(w_total=192, qd_per_ssd=16,
                                          n_streams=6),
              layout=Raid5Layout(group=6), seed=7)
    off, on = _array(**kw), _array(FULL, **kw)
    ra, rb = off.run(3000), on.run(3000)
    _assert_same_results(ra, rb)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    assert off.rng.bit_generator.state == on.rng.bit_generator.state


def test_qos_loop_identity():
    qos = QosPolicy(tenants=(TenantSpec(0, weight=2.0),
                             TenantSpec(1, weight=1.0)))
    kw = dict(n_ssds=4, workload=Workload(w_total=128, qd_per_ssd=16,
                                          n_streams=4),
              qos=qos, seed=3)
    off, on = _array(**kw), _array(FULL, **kw)
    ra, rb = off.run(3000), on.run(3000)
    _assert_same_results(ra, rb)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    # per-tenant budget groups exist for exactly the configured tenants
    assert sorted(rb.telemetry.budget["by_tenant"]) == [0, 1]


def test_safs_loop_identity():
    def mk(tel):
        return SAFSSim(n_ssds=4, ssd=P, occupancy=0.85,
                       workload=SAFSWorkload(read_frac=0.3, concurrency=128),
                       cache_frac=0.08, seed=11, telemetry=tel)
    off, on = mk(None), mk(FULL)
    ra, rb = off.run(3000), on.run(3000)
    assert ra.app_iops == rb.app_iops
    assert ra.mean_latency == rb.mean_latency
    assert ra.p99_latency == rb.p99_latency
    assert ra.events == rb.events
    assert ra.hit_rate == rb.hit_rate
    assert ra.ssd_page_writes == rb.ssd_page_writes
    np.testing.assert_array_equal(ra.util, rb.util)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    assert off.rng.bit_generator.state == on.rng.bit_generator.state
    assert rb.telemetry is not None
    assert rb.telemetry.components == SAFS_COMPONENTS


def test_staggered_gc_identity_and_episodes():
    kw = dict(gc=StaggeredGc(max_concurrent=1), seed=4)
    off, on = _array(**kw), _array(FULL, **kw)
    ra, rb = off.run(4000), on.run(4000)
    _assert_same_results(ra, rb)
    t = rb.telemetry
    # the coordinator grants one lease at a time, so episode intervals on
    # distinct devices never overlap
    eps = sorted((t0, t1, d) for d, t0, t1, _ in t.gc_episodes)
    for (a0, a1, _), (b0, _, _) in zip(eps, eps[1:]):
        assert b0 >= a1 - 1e-12


# ---------------------------------------------------------------------------
# Series / tick grid
# ---------------------------------------------------------------------------

def test_tick_grid_and_series_shape():
    res = _array(FULL).run(4000)
    t = res.telemetry
    dt = FULL.series_dt
    np.testing.assert_allclose(t.ticks,
                               np.arange(t.ticks.size) * dt, atol=0.0)
    assert t.ticks[-1] <= t.t_end
    for name in ("busy_time", "backlog", "free_blocks", "gc_active"):
        assert t.series[name].shape == (t.ticks.size, 3)
    # busy_time is cumulative within the window: non-decreasing except for
    # the single warmup-boundary reset
    busy = t.series["busy_time"]
    drops = (np.diff(busy, axis=0) < 0).any(axis=1)
    assert drops.sum() <= 1
    u = t.util_series(P.channels)
    assert u.shape == busy.shape
    assert float(u.min()) >= 0.0


def test_attach_aligns_grid_to_resumed_loop():
    loop = EventLoop()
    loop.schedule(1.05e-3, lambda: None)
    loop.run()
    tel = Telemetry(TelemetrySpec(series_dt=1e-3), 1).attach(loop)
    # first boundary is the smallest k*dt >= now, anchored at sim time 0
    assert tel.next_tick == pytest.approx(2e-3)
    assert tel.next_tick >= loop.now


def test_on_tick_samples_every_boundary():
    tel = Telemetry(TelemetrySpec(series_dt=1.0), 1)
    tel.add_series("x", lambda: [1.0])
    nxt = tel.on_tick(3.5)        # boundaries 0,1,2,3
    assert nxt == 4.0
    assert tel.next_tick == 4.0
    res = tel.finalize(3.5)
    np.testing.assert_array_equal(res.ticks, [0.0, 1.0, 2.0, 3.0])
    assert res.series["x"].shape == (4, 1)


def test_probe_toggles():
    spec = TelemetrySpec(series_dt=2e-4, probe_queues=False,
                         probe_free_blocks=False)
    t = _array(spec).run(2000).telemetry
    assert set(t.series) == {"busy_time", "gc_active"}
    assert t.budget is None          # spans off => no budget


def test_util_min_matches_legacy_exactly():
    """Satellite: ``util`` (and thus ``util_min``) is derived from the
    telemetry busy-time probe when present — bit-identical to the legacy
    per-SSD arithmetic."""
    for kw in (dict(), dict(layout=Raid5Layout(group=6), n_ssds=6,
                            workload=Workload(w_total=192, qd_per_ssd=16,
                                              n_streams=6))):
        ra = _array(**kw).run(2500)
        rb = _array(TelemetrySpec(series_dt=5e-4), **kw).run(2500)
        np.testing.assert_array_equal(ra.util, rb.util)
        assert ra.util_min == rb.util_min


# ---------------------------------------------------------------------------
# Spans / latency budget
# ---------------------------------------------------------------------------

def test_budget_sums_to_mean_latency():
    for kw in (dict(), dict(layout=Raid5Layout(group=6), n_ssds=6,
                            workload=Workload(w_total=192, qd_per_ssd=16,
                                              n_streams=6))):
        res = _array(FULL, **kw).run(3000)
        bud = res.telemetry.budget
        assert bud["n"] == 3000                     # measured ops only
        assert bud["mean_latency"] == pytest.approx(res.mean_latency,
                                                    rel=1e-12)
        assert sum(bud["mean"].values()) == pytest.approx(
            bud["mean_latency"], rel=1e-9)
        for g in list(bud["by_device"].values()) + \
                list(bud["by_tenant"].values()):
            assert sum(g["mean"].values()) == pytest.approx(
                g["mean_latency"], rel=1e-9)
        assert all(v >= 0.0 for v in bud["sums"].values())


def test_span_records_and_limit():
    res = _array(FULL).run(3000)
    t = res.telemetry
    assert t.components == ARRAY_COMPONENTS
    assert t.spans_dropped == 0
    assert len(t.spans) == 4500          # warmup 1500 + measured 3000
    for t_arr, seq, tenant, dev, nd, kind, dur, comps, m in t.spans[:100]:
        assert dur >= 0.0
        assert len(comps) == len(ARRAY_COMPONENTS)
        assert sum(comps) == pytest.approx(dur, abs=1e-15)
    # truncation: span records stop at the limit, the budget keeps counting
    lim = TelemetrySpec(series_dt=2e-4, spans=True, span_limit=100)
    t2 = _array(lim).run(3000).telemetry
    assert len(t2.spans) == 100
    assert t2.spans_dropped == 4400
    assert t2.budget["n"] == 3000


def test_safs_span_components_partition():
    res = SAFSSim(n_ssds=4, ssd=P, occupancy=0.85,
                  workload=SAFSWorkload(read_frac=0.3, concurrency=128),
                  cache_frac=0.08, seed=11, telemetry=FULL).run(3000)
    t = res.telemetry
    bud = t.budget
    assert bud["mean_latency"] == pytest.approx(res.mean_latency, rel=1e-12)
    assert sum(bud["mean"].values()) == pytest.approx(bud["mean_latency"],
                                                      rel=1e-9)
    # hit-path spans are pure-CPU: dev == -1 and only the cpu component set
    hits = [r for r in t.spans if r[3] == -1]
    assert hits
    for r in hits[:50]:
        comps = r[7]
        assert comps[1] == comps[2] == comps[3] == comps[4] == 0.0


def test_spans_compose_with_faults():
    """Spans + faults: the retry/hedge vocabulary keeps the latency budget
    exactly additive with a fault policy attached (the PR 8 mutual
    exclusivity is lifted)."""
    from repro.core.faults import FailSlow, FaultPolicy, RetryPolicy
    fp = FaultPolicy(events=(FailSlow(device=0, onset=0.01, duration=5.0,
                                      slow_factor=4.0),),
                     retry=RetryPolicy())
    with pytest.raises(TypeError, match="TelemetrySpec"):
        _array(telemetry=object())
    off = _array(faults=fp).run(3000)
    on = _array(FULL, faults=fp).run(3000)
    _assert_same_results(off, on)          # spans stay passive under faults
    bud = on.telemetry.budget
    assert bud is not None
    assert list(bud["mean"]) == list(ARRAY_COMPONENTS)
    assert "retry" in bud["mean"] and "hedge" in bud["mean"]
    assert sum(bud["mean"].values()) == pytest.approx(bud["mean_latency"],
                                                      rel=1e-9)
    assert bud["mean_latency"] == pytest.approx(on.mean_latency, rel=1e-12)


def test_hedge_component_raid5():
    """Hedged striped reads attribute their extra wait to the ``hedge``
    span component, and the budget stays additive."""
    from repro.core.faults import FailSlow, FaultPolicy
    fp = FaultPolicy(events=(FailSlow(device=0, onset=0.0, duration=10.0,
                                      slow_factor=8.0),),
                     hedge_after=0.002)
    r = ArraySim(n_ssds=3, ssd=P, occupancy=0.6,
                 workload=Workload(w_total=96, qd_per_ssd=16, n_streams=3,
                                   read_frac=0.8),
                 seed=42, layout=Raid5Layout(), faults=fp,
                 telemetry=FULL).run(3000)
    assert r.faults["hedged_reads"] > 0
    bud = r.telemetry.budget
    assert sum(bud["mean"].values()) == pytest.approx(bud["mean_latency"],
                                                      rel=1e-9)


# ---------------------------------------------------------------------------
# Sharded merge: serial == parallel bit-identical
# ---------------------------------------------------------------------------

def _assert_same_telemetry(a, b):
    assert a is not None and b is not None
    np.testing.assert_array_equal(a.ticks, b.ticks)
    assert set(a.series) == set(b.series)
    for k in a.series:
        np.testing.assert_array_equal(a.series[k], b.series[k])
        np.testing.assert_array_equal(a.final[k], b.final[k])
    assert a.spans == b.spans
    assert a.gc_episodes == b.gc_episodes
    assert a.budget == b.budget
    assert a.n_devices == b.n_devices


def test_sharded_array_serial_equals_parallel_with_telemetry():
    kw = dict(n_ssds=6, ssd=P, occupancy=0.6,
              workload=Workload(w_total=96, qd_per_ssd=16, n_streams=6),
              seed=5, n_shards=2, telemetry=FULL)
    rs = ShardedArraySim(parallel=False, **kw).run(3000)
    rp = ShardedArraySim(parallel=True, **kw).run(3000)
    assert rs.iops == rp.iops and rs.p99_latency == rp.p99_latency
    _assert_same_telemetry(rs.telemetry, rp.telemetry)
    t = rs.telemetry
    assert t.merged
    assert t.n_devices == 6
    assert t.series["busy_time"].shape[1] == 6
    # device ids in merged spans and budget are re-based to global ids
    assert all(-1 <= r[3] < 6 for r in t.spans)
    assert all(0 <= d < 6 for d in t.budget["by_device"])
    assert t.budget["merged"] and t.budget["tail_p99"] is None


def test_sharded_safs_serial_equals_parallel_with_telemetry():
    kw = dict(n_ssds=4, ssd=P, occupancy=0.8,
              workload=SAFSWorkload(read_frac=0.3, concurrency=96),
              cache_frac=0.08, seed=9, n_shards=2, telemetry=FULL)
    rs = ShardedSAFSSim(parallel=False, **kw).run(2000)
    rp = ShardedSAFSSim(parallel=True, **kw).run(2000)
    assert rs.app_iops == rp.app_iops
    assert rs.p99_latency == rp.p99_latency
    _assert_same_telemetry(rs.telemetry, rp.telemetry)
    # per-sim cache scalars become one column per shard
    assert rs.telemetry.series["cache_hits"].shape[1] == 2
    assert rs.telemetry.series["busy_time"].shape[1] == 4


def test_merge_telemetry_none_propagates():
    assert merge_telemetry([]) is None
    assert merge_telemetry([None]) is None
    r = _array(FULL).run(500)
    assert merge_telemetry([r.telemetry, None]) is None


# ---------------------------------------------------------------------------
# Trace export
# ---------------------------------------------------------------------------

def test_export_trace_chrome_json(tmp_path):
    res = _array(FULL, gc=StaggeredGc(max_concurrent=1)).run(2000)
    path = tmp_path / "trace.json"
    n = res.telemetry.export_trace(path)
    payload = json.loads(path.read_text())
    ev = payload["traceEvents"]
    assert n == len(ev)
    phases = {e["ph"] for e in ev}
    assert {"M", "X", "C"} <= phases
    ops = [e for e in ev if e["ph"] == "X" and e.get("cat") == "op"]
    gcs = [e for e in ev if e["ph"] == "X" and e.get("cat") == "gc"]
    assert len(ops) == len(res.telemetry.spans)
    assert len(gcs) == len(res.telemetry.gc_episodes)
    for e in ops[:20]:
        assert e["dur"] >= 0.0
        assert set(ARRAY_COMPONENTS) <= set(e["args"])
    # spans are sorted by (ts, seq) for stable diffs
    ts = [(e["ts"]) for e in ops]
    assert ts == sorted(ts)

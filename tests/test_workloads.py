"""Workload scenario layer: sources, gating, and simulator integration."""
import numpy as np
import pytest

from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.workloads import (OP_TRIM, TRACE_COLUMNS, TRACE_READ,
                                  TRACE_VERSION, TRACE_WRITE, BurstySource,
                                  DeleteBurstSource, MixedTenantSource,
                                  SequentialSource, TraceSource,
                                  UniformSource, ZipfSource, shard_trace,
                                  source_for)

SMALL = SSDParams(capacity_pages=8192)


def test_sequential_source_round_robins_cursors():
    rng = np.random.default_rng(0)
    src = SequentialSource(n_live=100, rng=rng, read_frac=0.0, streams=2)
    ops = [src.next_op(0.0) for _ in range(6)]
    assert [o.lba for o in ops] == [0, 50, 1, 51, 2, 52]
    assert [o.tenant for o in ops] == [0, 1, 0, 1, 0, 1]
    # wraps at the end of the space
    src2 = SequentialSource(n_live=4, rng=rng, streams=1)
    lbas = [src2.next_op(0.0).lba for _ in range(6)]
    assert lbas == [0, 1, 2, 3, 0, 1]


def test_bursty_source_defers_to_next_on_window():
    rng = np.random.default_rng(1)
    src = BurstySource(UniformSource(10, rng), on_time=1.0, off_time=1.0)
    assert src.next_op(0.5).at == 0.0          # ON window: issue now
    op = src.next_op(1.5)                      # OFF window: defer
    assert op.at == pytest.approx(2.0)
    op = src.next_op(3.7)                      # next OFF window
    assert op.at == pytest.approx(4.0)


def test_mixed_tenant_source_tags_tenants():
    rng = np.random.default_rng(2)
    reader = ZipfSource(1000, rng, read_frac=1.0, virtual_scale=2)
    writer = UniformSource(1000, rng, read_frac=0.0)
    src = MixedTenantSource(reader, writer, rng, writer_frac=0.5)
    ops = [src.next_op(0.0) for _ in range(400)]
    readers = [o for o in ops if o.tenant == 0]
    writers = [o for o in ops if o.tenant == 1]
    assert readers and writers
    assert all(o.is_read for o in readers)
    assert not any(o.is_read for o in writers)


def test_trace_source_replays_and_loops():
    trace = np.array([[0.0, 5, TRACE_WRITE],
                      [1.0, 6, TRACE_READ],
                      [2.0, 7, TRACE_WRITE]])
    src = TraceSource(trace, n_live=100)
    ops = [src.next_op(0.0) for _ in range(6)]
    assert [o.lba for o in ops] == [5, 6, 7, 5, 6, 7]
    assert [o.is_read for o in ops] == [False, True, False] * 2
    ats = [o.at for o in ops]
    assert ats[:3] == [0.0, 1.0, 2.0]
    assert ats[3] > ats[2] and ats == sorted(ats)   # loop keeps time monotone


def test_trace_source_folds_lbas():
    trace = np.array([[0.0, 1005, TRACE_WRITE]])
    assert TraceSource(trace, n_live=100).next_op(0.0).lba == 5


def test_trace_schema_constants():
    """The schema the .npz container and the docs both reference."""
    assert TRACE_VERSION == 1
    assert TRACE_COLUMNS == ("time", "lba", "op", "tenant")


def test_trace_source_tenant_column():
    trace = np.array([[0.0, 5, TRACE_WRITE, 1],
                      [1.0, 6, TRACE_READ, 0],
                      [2.0, 7, TRACE_WRITE, 2]])
    src = TraceSource(trace, n_live=100)
    assert src.has_tenants
    ops = [src.next_op(0.0) for _ in range(6)]        # two full loops
    assert [o.tenant for o in ops] == [1, 0, 2, 1, 0, 2]
    assert [o.lba for o in ops] == [5, 6, 7] * 2


def test_trace_source_three_columns_default_tenant_zero():
    """(n, 3) traces stay valid — tenant defaults to 0, op stream
    bit-identical to the 4-column equivalent with a zero tenant column."""
    t3 = np.array([[0.0, 5, TRACE_WRITE], [1.0, 6, TRACE_READ]])
    t4 = np.hstack([t3, np.zeros((2, 1))])
    a, b = TraceSource(t3, n_live=100), TraceSource(t4, n_live=100)
    assert not a.has_tenants and b.has_tenants
    for _ in range(4):
        x, y = a.next_op(0.0), b.next_op(0.0)
        assert (x.lba, x.is_read, x.at, x.tenant) == \
            (y.lba, y.is_read, y.at, y.tenant)
        assert x.tenant == 0


def test_trace_source_empty_trace():
    """Empty traces construct (an empty SHARD of a partitioned trace is
    legitimate) but refuse to produce ops."""
    src = TraceSource(np.empty((0, 4)), n_live=100)
    assert src.has_tenants
    with pytest.raises(RuntimeError):
        src.next_op(0.0)


def test_trace_source_rejects_bad_width():
    with pytest.raises(AssertionError):
        TraceSource(np.zeros((3, 2)), n_live=100)


# -- shard_trace: the sharded-replay partitioning rule -----------------------


def test_shard_trace_partitions_by_device_and_preserves_order():
    """Each record goes to the shard owning device ``lba % n_ssds``; within
    a shard the records keep their original (time) order."""
    n, sizes = 8, [3, 3, 2]
    rng = np.random.default_rng(0)
    trace = np.stack([np.arange(50) * 1e-3,
                      rng.integers(0, 10_000, size=50),
                      rng.integers(0, 2, size=50),
                      rng.integers(0, 3, size=50)], axis=1)
    parts = shard_trace(trace, n, sizes)
    assert len(parts) == 3
    assert sum(len(p) for p in parts) == 50
    lo = 0
    for part, sz in zip(parts, sizes):
        raws = trace[np.isin(trace[:, 1].astype(np.int64) % n,
                             range(lo, lo + sz))]
        # order preserved: times match the original subsequence exactly
        np.testing.assert_array_equal(part[:, 0], raws[:, 0])
        np.testing.assert_array_equal(part[:, 2:], raws[:, 2:])
        # remap: local device = global device - lo, op count per device kept
        np.testing.assert_array_equal(
            part[:, 1].astype(np.int64) % sz,
            raws[:, 1].astype(np.int64) % n - lo)
        lo += sz


def test_shard_trace_remap_matches_unsharded_device_lba():
    """The two-step fold (shard slice then per-device fold) must land every
    record on the same per-device LBA the unsharded sim computes:
    (raw // n) % live_per_ssd."""
    n, sizes, live_per_ssd = 6, [4, 2], 512
    raw = np.array([7, 6 * 900 + 4, 6 * 1200 + 5, 13, 6 * 77 + 1])
    trace = np.stack([np.arange(5.0), raw.astype(float),
                      np.ones(5), np.zeros(5)], axis=1)
    lo = 0
    for part, sz in zip(shard_trace(trace, n, sizes), sizes):
        local = part[:, 1].astype(np.int64)
        got_dev = local % sz + lo
        got_lba = (local % (live_per_ssd * sz)) // sz
        raws = raw[(raw % n >= lo) & (raw % n < lo + sz)]
        np.testing.assert_array_equal(got_dev, raws % n)
        np.testing.assert_array_equal(got_lba, (raws // n) % live_per_ssd)
        lo += sz


def test_shard_trace_empty_shard():
    """A shard owning devices no record touches gets a (0, k) slice."""
    trace = np.array([[0.0, 0, TRACE_WRITE, 0],    # device 0 only
                      [1.0, 4, TRACE_WRITE, 0]])
    parts = shard_trace(trace, 4, [2, 2])
    assert len(parts[0]) == 2
    assert parts[1].shape == (0, 4)


def test_delete_burst_source_emits_aligned_trim_runs():
    rng = np.random.default_rng(7)
    src = DeleteBurstSource(UniformSource(1024, rng), 1024, rng,
                            pages=8, every=4)
    # one cycle = 3 base ops + an 8-TRIM burst (the 4th call fires it)
    ops = [src.next_op(0.0) for _ in range(4 * 11)]
    trims = [o for o in ops if o.kind == OP_TRIM]
    base = [o for o in ops if o.kind != OP_TRIM]
    assert trims and base
    # TRIMs come in contiguous runs of `pages`, starting page-aligned
    runs, cur = [], []
    for o in ops:
        if o.kind == OP_TRIM:
            cur.append(o.lba)
        elif cur:
            runs.append(cur)
            cur = []
    if cur:
        runs.append(cur)
    assert len(runs) == 4 and len(base) == 12
    for run in runs:
        assert len(run) == 8
        assert run[0] % 8 == 0
        assert run == list(range(run[0], run[0] + 8))


def test_delete_burst_truncates_tail_extent():
    """When the aligned extent start is within `pages` of the end of the
    LBA space, the run truncates (short tail extent) instead of wrapping —
    every run stays contiguous, in-bounds, and aligned at its start."""
    rng = np.random.default_rng(0)
    n_live = 100                       # not a multiple of pages=64
    src = DeleteBurstSource(UniformSource(n_live, rng), n_live, rng,
                            pages=64, every=3)
    runs, cur = [], []
    for _ in range(300):
        o = src.next_op(0.0)
        if o.kind == OP_TRIM:
            cur.append(o.lba)
        elif cur:
            runs.append(cur)
            cur = []
    assert runs
    for run in runs:
        assert run[0] % 64 == 0
        assert run == list(range(run[0], run[0] + len(run)))   # contiguous
        assert run[-1] < n_live
    assert any(len(run) < 64 for run in runs)   # the truncated tail extent


def test_delete_burst_rng_untouched_when_disabled():
    """The delete_burst machinery draws RNG only inside its own scenario:
    every other scenario's op stream is bit-identical to before."""
    a = np.random.default_rng(9)
    b = np.random.default_rng(9)
    plain = UniformSource(512, a)
    wrapped_base = UniformSource(512, b)     # same stream, never bursts
    src = DeleteBurstSource(wrapped_base, 512, b, pages=4, every=10**9)
    for _ in range(200):
        x, y = plain.next_op(0.0), src.next_op(0.0)
        assert (x.lba, x.is_read) == (y.lba, y.is_read)


def test_array_sim_delete_burst_scenario_trims_end_to_end():
    wl = Workload(w_total=64, qd_per_ssd=32, scenario="delete_burst",
                  delete_pages=32, delete_every=64)
    r = ArraySim(2, SMALL, 0.6, wl, seed=8).run(6000)
    assert r.trims > 0
    # trim-aware GC: invalidated pages are never copied, so WA stays sane
    assert r.gc_wa >= 1.0


def test_source_for_dispatch():
    rng = np.random.default_rng(3)
    assert isinstance(source_for(Workload(), 100, rng), UniformSource)
    assert isinstance(source_for(Workload(dist="zipf"), 100, rng), ZipfSource)
    assert isinstance(source_for(Workload(scenario="sequential"), 100, rng),
                      SequentialSource)
    assert isinstance(source_for(Workload(scenario="bursty"), 100, rng),
                      BurstySource)
    assert isinstance(source_for(Workload(scenario="mixed"), 100, rng),
                      MixedTenantSource)
    with pytest.raises(ValueError):
        source_for(Workload(scenario="nope"), 100, rng)
    with pytest.raises(AssertionError):
        source_for(Workload(scenario="trace"), 100, rng)   # needs a trace


def test_array_sim_runs_bursty_scenario():
    """Open-loop lulls flow through the simulator: throughput under 50% duty
    cycle lands well below the always-on rate."""
    wl_on = Workload(w_total=64, qd_per_ssd=32)
    wl_burst = Workload(w_total=64, qd_per_ssd=32, scenario="bursty",
                        burst_on=1e-3, burst_off=1e-3)
    on = ArraySim(2, SMALL, 0.5, wl_on, seed=4).run(4000)
    burst = ArraySim(2, SMALL, 0.5, wl_burst, seed=4).run(4000)
    assert burst.iops < on.iops


def test_array_sim_runs_mixed_and_sequential(capsys):
    for scenario in ("mixed", "sequential"):
        wl = Workload(w_total=64, qd_per_ssd=32, scenario=scenario)
        r = ArraySim(2, SMALL, 0.5, wl, seed=5).run(3000)
        assert r.iops > 0
        if scenario == "mixed":
            assert r.read_iops > 0 and r.write_iops > 0


def test_array_sim_trace_replay():
    rng = np.random.default_rng(6)
    n = 4000
    trace = np.stack([np.arange(n) * 2e-5,           # 50k IOPS offered
                      rng.integers(0, 4096, size=n),
                      np.full(n, TRACE_WRITE)], axis=1)
    wl = Workload(w_total=64, qd_per_ssd=32, scenario="trace")
    r = ArraySim(2, SMALL, 0.5, wl, seed=6, trace=trace).run(2000)
    # the offered 50k rate is the ceiling (modulo measurement-window edge
    # effects), far below the >120k closed-loop capacity of two fresh-ish SSDs
    assert 0 < r.iops <= 70000

"""Dirty-page flusher policy tests (paper §3.3)."""
from collections import defaultdict


from repro.core.flusher import DirtyPageFlusher, FlushRequest, StalenessChecker


class FakeCache:
    """Scripted CacheView."""

    def __init__(self, n_devices=2):
        self.sets = defaultdict(list)   # set_idx -> [(slot, tag, score)]
        self.n_devices = n_devices

    def dirty_count(self, s):
        return len(self.sets[s])

    def flush_candidates(self, s):
        return sorted(self.sets[s], key=lambda t: -t[2])

    def device_of(self, tag):
        return tag % self.n_devices


def test_trigger_threshold():
    c = FakeCache()
    f = DirtyPageFlusher(c, 2, trigger=6, per_visit=2)
    c.sets[0] = [(i, i, i) for i in range(6)]
    f.note_write(0)                       # 6 dirty: NOT > trigger
    assert f.make_requests() == []
    c.sets[0].append((6, 6, 6))
    f.note_write(0)                       # 7 > 6: triggers
    out = f.make_requests(budget=1)
    assert len(out) == 1
    assert out[0].score_at_issue == 6     # highest score first


def test_round_robin_is_fair_but_biased_to_writers():
    c = FakeCache(n_devices=1)
    f = DirtyPageFlusher(c, 1, trigger=0, per_visit=1)
    c.sets[0] = [(i, i * 10, i) for i in range(4)]
    c.sets[1] = [(i, i * 10 + 1, i) for i in range(2)]
    f.note_write(0)
    f.note_write(1)
    reqs = f.make_requests(budget=6)
    by_set = [r.set_idx for r in reqs]
    # alternates 0,1,0,1 then drains 0 (set 0 has more dirty pages)
    assert by_set == [0, 1, 0, 1, 0, 0]


def test_per_device_pending_cap():
    c = FakeCache(n_devices=2)
    f = DirtyPageFlusher(c, 2, trigger=0, per_visit=8, max_pending_per_dev=2)
    c.sets[0] = [(i, i * 2, i) for i in range(8)]      # all device 0
    f.note_write(0)
    out = f.make_requests(budget=100)
    assert len(out) == 2                  # capped
    f.note_flush_done(out[0])
    out2 = f.make_requests(budget=100)
    assert len(out2) == 1                 # one slot freed


def test_no_double_flush_of_inflight_page():
    c = FakeCache(n_devices=1)
    f = DirtyPageFlusher(c, 1, trigger=0, per_visit=4)
    c.sets[0] = [(0, 0, 3), (1, 1, 2)]
    f.note_write(0)
    out1 = f.make_requests(budget=10)
    assert len(out1) == 2
    f.note_write(0)                       # set still dirty (not yet completed)
    assert f.make_requests(budget=10) == []


def test_staleness_checker_rules():
    chk = StalenessChecker(
        is_evicted=lambda r: r.tag == 1,
        is_clean=lambda r: r.tag == 2,
        current_score=lambda r: 5 if r.tag == 3 else 0,
        score_threshold=3,
    )
    mk = lambda tag: FlushRequest(tag=tag, set_idx=0, slot=0, device=0,
                                  score_at_issue=9)
    assert chk(mk(1))         # evicted
    assert chk(mk(2))         # cleaned
    assert not chk(mk(3))     # score 5 >= 3
    assert chk(mk(4))         # score 0 < 3


def test_saturated_gate():
    c = FakeCache(n_devices=1)
    f = DirtyPageFlusher(c, 1, trigger=0, per_visit=4, max_pending_per_dev=4)
    c.sets[0] = [(i, i, i) for i in range(4)]
    f.note_write(0)
    assert not f.saturated()
    f.make_requests(budget=100)
    assert f.saturated()

"""Property tests: the paper's policies (numpy oracle) vs the JAX SA-cache twin."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import policies, sa_cache

SET = st.integers(min_value=2, max_value=16)


@st.composite
def set_state(draw):
    ss = draw(SET)
    hits = draw(st.lists(st.integers(0, 15), min_size=ss, max_size=ss))
    clock = draw(st.integers(0, ss - 1))
    valid = draw(st.lists(st.booleans(), min_size=ss, max_size=ss))
    dirty = draw(st.lists(st.booleans(), min_size=ss, max_size=ss))
    return (np.array(hits, np.int64), clock, np.array(valid),
            np.array(dirty) & np.array(valid))


@given(set_state())
@settings(max_examples=200, deadline=None)
def test_flush_scores_match_jax_twin(state):
    hits, clock, valid, dirty = state
    ss = hits.shape[0]
    ref = policies.flush_scores(hits, clock, valid=valid)
    cache = sa_cache.CacheState(
        tags=jnp.where(jnp.asarray(valid), jnp.arange(ss, dtype=jnp.int32),
                       sa_cache.EMPTY)[None],
        hits=jnp.asarray(hits, jnp.int32)[None],
        dirty=jnp.asarray(dirty)[None],
        clock=jnp.asarray([clock], jnp.int32))
    got = np.asarray(sa_cache.flush_scores(cache))[0]
    np.testing.assert_array_equal(got, ref)


@given(set_state())
@settings(max_examples=200, deadline=None)
def test_flush_score_is_permutation_of_valid_slots(state):
    hits, clock, valid, _ = state
    fs = policies.flush_scores(hits, clock, valid=valid)
    n = int(valid.sum())
    got = sorted(fs[valid])
    # top-n scores, each exactly once; invalid slots -1
    assert got == list(range(hits.shape[0] - n, hits.shape[0]))
    assert (fs[~valid] == -1).all()


@given(set_state())
@settings(max_examples=200, deadline=None)
def test_gclock_evict_matches_argmin_distance_score(state):
    hits, clock, valid, dirty = state
    if not valid.any():
        return
    victim, new_hits, new_clock = policies.gclock_evict(
        hits, clock, valid, dirty, clean_first=False)
    if not valid.all():          # empty slot fast path
        assert not valid[victim]
        return
    ss = hits.shape[0]
    d = policies.distance_scores(hits, clock, ss)
    # sweep victim = argmin of distance score among valid (ties: first swept)
    assert d[victim] == d[valid].min()
    assert new_hits[victim] == 0
    assert new_clock == (victim + 1) % ss


@given(set_state())
@settings(max_examples=200, deadline=None)
def test_clean_first_prefers_clean_page(state):
    hits, clock, valid, dirty = state
    if not valid.any():
        return
    victim, _, _ = policies.gclock_evict(hits, clock, valid, dirty,
                                         clean_first=True)
    clean = valid & ~dirty
    if valid.all() and clean.any():
        assert clean[victim], "clean-first must never evict dirty when clean exists"


@given(set_state())
@settings(max_examples=150, deadline=None)
def test_jax_insert_victim_matches_oracle(state):
    hits, clock, valid, dirty = state
    ss = hits.shape[0]
    ref_victim, ref_hits, ref_clock = policies.gclock_evict(
        hits, clock, valid, dirty, clean_first=True)
    cache = sa_cache.CacheState(
        tags=jnp.where(jnp.asarray(valid), jnp.arange(ss, dtype=jnp.int32),
                       sa_cache.EMPTY)[None],
        hits=jnp.asarray(hits, jnp.int32)[None],
        dirty=jnp.asarray(dirty)[None],
        clock=jnp.asarray([clock], jnp.int32))
    _, _, slot, new_state = sa_cache.insert(
        cache, jnp.int32(0), jnp.int32(1000), jnp.bool_(False))
    assert int(slot) == ref_victim
    assert int(new_state.clock[0]) == ref_clock
    got_hits = np.asarray(new_state.hits[0])
    ref_after = ref_hits.copy()
    ref_after[ref_victim] = 0
    np.testing.assert_array_equal(got_hits, ref_after)


@given(st.integers(0, 1), st.integers(0, 1), st.integers(-1, 15),
       st.integers(0, 12))
@settings(max_examples=100, deadline=None)
def test_staleness_rules(evicted, cleaned, score, thresh):
    stale = policies.is_stale(evicted=bool(evicted), cleaned=bool(cleaned),
                              current_flush_score=score,
                              score_threshold=thresh)
    assert stale == (bool(evicted) or bool(cleaned) or score < thresh)


def test_lookup_bumps_hits_saturating():
    cache = sa_cache.make_cache(2, 4)
    _, _, slot, cache = sa_cache.insert(cache, jnp.int32(0), jnp.int32(7),
                                        jnp.bool_(False))
    for _ in range(20):
        hit, s2, cache = sa_cache.lookup(cache, jnp.int32(0), jnp.int32(7))
        assert bool(hit) and int(s2) == int(slot)
    assert int(cache.hits[0, slot]) == sa_cache.MAX_HITS


def test_clean_slot_ignores_reused_slot():
    cache = sa_cache.make_cache(1, 4)
    _, _, slot, cache = sa_cache.insert(cache, jnp.int32(0), jnp.int32(5),
                                        jnp.bool_(True))
    # tag replaced before the flush completion arrives
    cache = cache._replace(tags=cache.tags.at[0, slot].set(9))
    cache = sa_cache.clean_slot(cache, 0, slot, expect_tag=5)
    assert bool(cache.dirty[0, slot])     # stays dirty: flush was stale

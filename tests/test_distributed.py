"""Sharding rules, optimizer, data pipeline, HLO cost parser."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.sharding import (_fix_divisibility, data_spec,
                                        param_specs)
from repro.launch.hlo_cost import analyze
from repro.optim import adamw_init, adamw_update, cosine_schedule


def test_fix_divisibility_drops_nonfitting_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = _fix_divisibility(P("model", "data"), (51865, 384), FakeMesh())
    assert spec == P(None, "data")           # 51865 % 16 != 0; 384 % 16 == 0
    spec = _fix_divisibility(P(("pod", "data"), "model"), (64, 64),
                             type("M", (), {"shape": {"pod": 2, "data": 16,
                                                      "model": 16}})())
    assert spec == P(("pod", "data"), "model")
    del mesh


def test_param_specs_cover_all_archs():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.transformer import init_params
    for arch in ["tinyllama-1.1b", "jamba-v0.1-52b", "whisper-tiny"]:
        cfg = reduced(get_config(arch))
        params = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        specs = param_specs(params, mesh)
        assert jax.tree_util.tree_structure(specs) == \
            jax.tree_util.tree_structure(params)


def test_data_spec_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert data_spec(mesh, 8) is not None


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params, master_fp32=False)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_adamw_clip_and_metrics():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, master_fp32=False)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(g, state, params, lr=0.1, clip_norm=1.0)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_master_fp32_roundtrip():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params, master_fp32=True)
    assert state.master is not None
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    p2, s2, _ = adamw_update(g, state, params, lr=1e-4)
    # master accumulates below bf16 resolution
    assert float(jnp.abs(s2.master["w"] - 1.0).max()) > 0


def test_cosine_schedule_shape():
    s = [float(cosine_schedule(jnp.asarray(i), peak_lr=1.0, warmup=10,
                               total=100)) for i in range(100)]
    assert s[0] == 0.0 and abs(s[10] - 1.0) < 0.11
    assert s[-1] < 0.2 and all(x >= 0 for x in s)


def test_synthetic_data_deterministic():
    from repro.data import SyntheticLM
    d1 = SyntheticLM(1000, 64, 4, seed=7).batch(3)
    d2 = SyntheticLM(1000, 64, 4, seed=7).batch(3)
    np.testing.assert_array_equal(d1["tokens"], d2["tokens"])
    assert (d1["labels"][:, :-1] == d1["tokens"][:, 1:]).all()


def test_prefetcher_orders_and_closes():
    from repro.data import Prefetcher
    it = Prefetcher(iter(range(10)), depth=3)
    assert list(it) == list(range(10))
    it.close()


def test_hlo_cost_parser_counts_loops():
    def g(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return jnp.sum(out)

    c = jax.jit(jax.grad(g)).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
    res = analyze(c.as_text())
    # fwd 5x dot (2*8*64*64) + bwd 5x 2 dots
    expect = 15 * 2 * 8 * 64 * 64
    assert res["flops"] == pytest.approx(expect, rel=0.01)
    trips = sorted(t for _, t in res["loops"])
    assert trips == [5, 5]


def test_hlo_cost_parser_collectives():
    from repro.launch.hlo_cost import analyze as _an
    # single-device module: no collectives
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = _an(c.as_text())
    assert res["collective_total"] == 0
    assert res["flops"] == pytest.approx(2 * 32 ** 3)


def test_compression_error_feedback_reduces_error():
    from repro.distributed.collectives import _dequantize, _quantize
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    q, s = _quantize(x)
    err = x - _dequantize(q, s)
    assert float(jnp.abs(err).max()) <= float(s.max())
    # error feedback: quantizing (x + prev_err) recovers the residual over steps
    total = jnp.zeros_like(x)
    res = jnp.zeros_like(x)
    for _ in range(8):
        q, s = _quantize(x + res)
        dq = _dequantize(q, s)
        res = x + res - dq
        total = total + dq
    np.testing.assert_allclose(np.asarray(total / 8), np.asarray(x), atol=2e-2)

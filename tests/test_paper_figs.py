"""Fig 4 qualitative ordering, pinned fast (scaled-down SAFS config).

The paper's Fig 4 (unaligned 128 B writes, flusher on/off) claims the
flusher wins because every miss is a read-update-write and the flusher
converts application-blocking demand writebacks into background flushes.
An earlier calibration of ``benchmarks/paper_figs.fig4`` measured inside
the cache-fill transient and silently reported a *negative* uniform gain;
this test pins the steady-state ordering at a config small enough for the
tier-1 suite, so a recalibration or model change that flips the sign fails
loudly instead of drifting."""
import pytest

from repro.core.gc_sim import SSDParams
from repro.core.safs_sim import SAFSSim, SAFSWorkload

P = SSDParams(capacity_pages=4096)


def _unaligned(use_flusher: bool, dist: str, seed: int):
    sim = SAFSSim(2, P, 0.8,
                  SAFSWorkload(read_frac=0.0, dist=dist, unaligned=True,
                               concurrency=64),
                  cache_frac=0.05, use_flusher=use_flusher, seed=seed)
    # window >> cache pages (~327 here): past the fill transient that broke
    # the old fig4 calibration
    return sim.run(8000)


@pytest.mark.parametrize("seed", [0, 1])
def test_fig4_unaligned_flusher_gain_is_positive(seed):
    on = _unaligned(True, "uniform", seed)
    off = _unaligned(False, "uniform", seed)
    # the headline ordering: flusher on beats flusher off
    assert on.app_iops > off.app_iops
    # and via the paper's mechanism: fewer application-blocking demand
    # writebacks, not a hit-rate artifact
    assert on.demand_writes < off.demand_writes
    assert abs(on.hit_rate - off.hit_rate) < 0.05


def test_fig4_gain_holds_under_zipf():
    on = _unaligned(True, "zipf", 0)
    off = _unaligned(False, "zipf", 0)
    assert on.app_iops > off.app_iops
    assert on.demand_writes < off.demand_writes

"""Health monitor (core/monitor.py) + streaming metrics (core/metrics.py).

The monitor inherits telemetry's passive-observer contract: attaching it
must not change the simulation (``monitor=None`` byte-identical, monitoring
ON byte-identical, no RNG, no scheduled events), and its alert stream must
be deterministic — serial == sharded bit-identical on both sharded runners.
"""
import json

import numpy as np
import pytest

from repro.core.faults import FailSlow, FaultPolicy
from repro.core.gc_coord import ReactiveGc, StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.metrics import (EdgeLatch, Ewma, SlidingWindow, WindowDelta,
                                fast_median, peer_median)
from repro.core.monitor import (RULES, HealthMonitor, MonitorResult,
                                MonitorSpec, _rebase_cause, merge_monitor)
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.raid import Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload
from repro.core.sharded import ShardedArraySim, ShardedSAFSSim
from repro.core.telemetry import TelemetrySpec

P = SSDParams(capacity_pages=2048)
MON = MonitorSpec()


def _array(monitor=None, **kw):
    base = dict(n_ssds=3, ssd=P, occupancy=0.6,
                workload=Workload(w_total=96, qd_per_ssd=16, n_streams=3),
                seed=42, monitor=monitor)
    base.update(kw)
    return ArraySim(**base)


def _assert_same_results(a, b):
    assert a.iops == b.iops
    assert a.mean_latency == b.mean_latency
    assert a.p50_latency == b.p50_latency
    assert a.p99_latency == b.p99_latency
    assert a.events == b.events          # no extra scheduled events
    np.testing.assert_array_equal(a.util, b.util)
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)


# ---------------------------------------------------------------------------
# metrics.py primitives
# ---------------------------------------------------------------------------

def test_sliding_window_quantile_is_upper_index_pick():
    w = SlidingWindow(8)
    for x in (5.0, 1.0, 9.0, 3.0):
        w.push(x)
    a = sorted([5.0, 1.0, 9.0, 3.0])
    # same arithmetic as the pre-refactor SloController._p99
    assert w.quantile(0.99) == a[min(len(a) - 1, int(len(a) * 0.99))]
    assert w.quantile(0.5) == a[2]
    assert w.oldest() == 5.0
    assert w.count_above(4.0) == 2
    for x in range(10):
        w.push(float(x))
    assert len(w) == 8 and w.oldest() == 2.0


def test_ewma_first_sample_initialises():
    e = Ewma(0.25)
    e.update(4.0)
    assert e.value == 4.0 and e.n == 1     # no zero-bias warmup
    e.update(8.0)
    assert e.value == 4.0 + 0.25 * (8.0 - 4.0)


def test_window_delta_spans_window_pushes():
    d = WindowDelta(3)
    assert d.push(10.0) == 0.0
    assert d.push(12.0) == 2.0
    assert not d.full()
    assert d.push(15.0) == 5.0
    assert d.push(21.0) == 11.0            # 4 samples = 3 intervals
    assert d.full()
    assert d.push(22.0) == 10.0            # oldest (10.0 -> 12.0) fell off


def test_edge_latch_one_alert_per_episode():
    la = EdgeLatch(arm_ticks=3)
    assert [la.push(True) for _ in range(5)] == [False, False, True,
                                                False, False]
    la.push(False)                         # episode ends, latch clears
    assert [la.push(True) for _ in range(3)] == [False, False, True]
    assert la.active
    la.rearm()                             # warmup boundary: re-fires while
    assert la.push(True) is True           # the condition still holds


def test_fast_median_matches_numpy():
    for vals in ([3.0], [4.0, 1.0], [5.0, 2.0, 9.0],
                 [1.0, 7.0, 3.0, 3.0], list(range(11))):
        assert fast_median(vals) == float(np.median(vals))
        assert peer_median(vals) == float(np.median(vals))


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_monitor_spec_validation():
    with pytest.raises(ValueError, match="tick_dt"):
        MonitorSpec(tick_dt=0.0)
    with pytest.raises(ValueError, match="rules"):
        MonitorSpec(rules=("gc_storm", "nope"))
    with pytest.raises(TypeError, match="MonitorSpec"):
        _array(monitor=object())
    with pytest.raises(TypeError, match="MonitorSpec"):
        SAFSSim(n_ssds=2, ssd=P, monitor=object())
    with pytest.raises(TypeError, match="MonitorSpec"):
        ShardedArraySim(4, ssd=P, monitor=object())
    with pytest.raises(TypeError, match="MonitorSpec"):
        ShardedSAFSSim(4, ssd=P, monitor=object())


# ---------------------------------------------------------------------------
# alert rules on hand-built metric streams
# ---------------------------------------------------------------------------

def _drive(mon, n_ticks):
    """Walk the tick grid like the loop hook would."""
    for k in range(n_ticks):
        mon.on_tick(k * mon.dt)


def test_gc_storm_rule():
    spec = MonitorSpec(rules=("gc_storm",), gc_storm_ticks=3,
                       include_warmup=True)
    mon = HealthMonitor(spec, 4)
    state = {"gc": [False] * 4}
    mon._gc_fn = lambda: state["gc"]
    _drive(mon, 5)
    assert mon.alerts == []
    state["gc"] = [True] * 4               # storm: all devices collecting
    for k in range(5, 20):
        mon.on_tick(k * mon.dt)
    assert len(mon.alerts) == 1            # latched: one alert per episode
    t, seq, rule, dev, tenant, value, thresh, cause = mon.alerts[0]
    assert rule == "gc_storm" and dev == -1 and value == 1.0
    assert cause == "gc:4_devices"
    state["gc"] = [False] * 4
    _drive_from(mon, 20, 25)
    state["gc"] = [True] * 4               # second episode, second alert
    _drive_from(mon, 25, 40)
    assert len(mon.alerts) == 2


def _drive_from(mon, k0, k1):
    for k in range(k0, k1):
        mon.on_tick(k * mon.dt)


def test_util_skew_rule():
    spec = MonitorSpec(rules=("util_skew",), util_skew_window=4,
                       util_skew_ratio=2.0, include_warmup=True)
    mon = HealthMonitor(spec, 3)
    state = {"busy": [0.0, 0.0, 0.0]}
    mon._busy_fn = lambda: list(state["busy"])

    def step(rates):
        for i, r in enumerate(rates):
            state["busy"][i] += r
    for k in range(6):                     # balanced: no alert
        step([1.0, 1.0, 1.0])
        mon.on_tick(k * mon.dt)
    assert mon.alerts == []
    for k in range(6, 20):                 # device 2 runs 10x its peers
        step([1.0, 1.0, 10.0])
        mon.on_tick(k * mon.dt)
    assert len(mon.alerts) == 1
    t, _, rule, dev, _, value, thresh, cause = mon.alerts[0]
    assert rule == "util_skew" and dev == 2 and value > 2.0
    assert thresh == 2.0 and cause == "none"


def test_backlog_sat_rule():
    spec = MonitorSpec(rules=("backlog_sat",), backlog_frac=1.0,
                       backlog_ticks=3, include_warmup=True)
    mon = HealthMonitor(spec, 2)
    mon._qd = 16
    state = {"bl": [0, 0]}
    mon._backlog_fn = lambda: list(state["bl"])
    _drive(mon, 4)
    state["bl"] = [16, 3]                  # device 0 pinned at the bound
    _drive_from(mon, 4, 10)
    assert [a[3] for a in mon.alerts] == [0]
    assert mon.alerts[0][2] == "backlog_sat"
    assert mon.alerts[0][5] == 16.0


def test_wa_spike_rule():
    spec = MonitorSpec(rules=("wa_spike",), wa_window=4, wa_ratio=1.5,
                       wa_min_writes=1.0, include_warmup=True)
    mon = HealthMonitor(spec, 2)
    state = {"w": 0.0, "c": 0.0}
    mon._wa_fn = lambda: (state["w"], state["c"])

    def step(dw, dc):
        state["w"] += dw
        state["c"] += dc
    for k in range(8):                     # two windows at WA = 1.0
        step(10.0, 0.0)
        mon.on_tick(k * mon.dt)
    assert mon.alerts == []
    for k in range(8, 12):                 # copies spike: WA jumps to 2.0
        step(10.0, 10.0)
        mon.on_tick(k * mon.dt)
    assert len(mon.alerts) == 1
    assert mon.alerts[0][2] == "wa_spike"
    assert mon.alerts[0][5] == pytest.approx(2.0)


def test_hit_collapse_rule():
    spec = MonitorSpec(rules=("hit_collapse",), hit_window=4, hit_drop=0.5,
                       hit_min_lookups=1.0, include_warmup=True)
    mon = HealthMonitor(spec, 2)
    state = {"h": 0.0, "l": 0.0}
    mon._cache_fn = lambda: (state["h"], state["l"])

    def step(dh, dl):
        state["h"] += dh
        state["l"] += dl
    for k in range(8):                     # hit rate 0.9
        step(9.0, 10.0)
        mon.on_tick(k * mon.dt)
    assert mon.alerts == []
    for k in range(8, 12):                 # collapse to 0.1 < 0.5 * 0.9
        step(1.0, 10.0)
        mon.on_tick(k * mon.dt)
    assert len(mon.alerts) == 1
    assert mon.alerts[0][2] == "hit_collapse"
    assert mon.alerts[0][5] == pytest.approx(0.1)


def test_slo_burn_rule():
    spec = MonitorSpec(rules=("slo_burn",), slo_burn_window=16,
                       slo_burn_frac=0.5, slo_burn_min_samples=8,
                       include_warmup=True)
    mon = HealthMonitor(spec, 2)
    pol = QosPolicy(tenants=(TenantSpec(0, slo_p99=1e-3), TenantSpec(1)))
    mon.register_slo(pol)
    for i in range(8):                     # healthy latencies: no burn
        mon.note_completion(0, 5e-4, i * 1e-4)
    mon.note_completion(1, 5.0, 1e-3)      # unprotected tenant: untracked
    assert mon.alerts == []
    for i in range(12):                    # every op busts the SLO
        mon.note_completion(0, 5e-3, 1e-3 + i * 1e-4)
    assert len(mon.alerts) == 1
    t, _, rule, dev, tenant, value, thresh, cause = mon.alerts[0]
    assert rule == "slo_burn" and tenant == 0 and dev == -1
    assert value > 0.5 and thresh == 0.5


def test_root_cause_priority():
    class FakeInj:
        quarantined = [False, True]
        crashed = [False, False]

        def is_slow_now(self, i, now):
            return False

    mon = HealthMonitor(MonitorSpec(include_warmup=True), 2)
    mon._inj = FakeInj()
    mon._gc_fn = lambda: [True, True]
    # fault beats GC; device-scoped lookup only sees that device
    assert mon._root_cause(1, 0.0) == "fault:quarantined:dev1"
    assert mon._root_cause(0, 0.0) == "gc:dev0"
    assert mon._root_cause(-1, 0.0) == "fault:quarantined:dev1"
    mon._inj = None
    assert mon._root_cause(-1, 0.0) == "gc:2_devices"
    mon._gc_fn = lambda: [False, False]
    assert mon._root_cause(0, 0.0) == "none"


def test_warmup_suppression_and_rearm():
    """Alerts are suppressed until begin_measure; a pathology persisting
    across the boundary alerts on the first measured tick."""
    spec = MonitorSpec(rules=("gc_storm",), gc_storm_ticks=2)
    mon = HealthMonitor(spec, 2)
    mon._gc_fn = lambda: [True, True]
    _drive(mon, 10)                        # warmup: latched but silent
    assert mon.alerts == []
    mon.begin_measure(10 * mon.dt)
    _drive_from(mon, 10, 12)
    assert len(mon.alerts) == 1


# ---------------------------------------------------------------------------
# ON == OFF byte-identity on every run loop
# ---------------------------------------------------------------------------

def test_fast_loop_monitor_identity():
    off, on = _array(), _array(MON)
    ra, rb = off.run(4000), on.run(4000)
    _assert_same_results(ra, rb)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    assert off.rng.bit_generator.state == on.rng.bit_generator.state
    assert ra.monitor is None
    assert rb.monitor is not None


def test_layout_loop_monitor_identity():
    kw = dict(n_ssds=6, workload=Workload(w_total=192, qd_per_ssd=16,
                                          n_streams=6),
              layout=Raid5Layout(group=6), seed=7)
    off, on = _array(**kw), _array(MON, **kw)
    ra, rb = off.run(3000), on.run(3000)
    _assert_same_results(ra, rb)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)


def test_qos_loop_monitor_identity():
    qos = QosPolicy(tenants=(TenantSpec(0, weight=2.0, slo_p99=5e-3),
                             TenantSpec(1, weight=1.0)))
    kw = dict(n_ssds=4, workload=Workload(w_total=128, qd_per_ssd=16,
                                          n_streams=4),
              qos=qos, seed=3)
    off, on = _array(**kw), _array(MON, **kw)
    ra, rb = off.run(3000), on.run(3000)
    _assert_same_results(ra, rb)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)


def test_safs_loop_monitor_identity():
    def mk(mon):
        return SAFSSim(n_ssds=4, ssd=P, occupancy=0.85,
                       workload=SAFSWorkload(read_frac=0.3, concurrency=128),
                       cache_frac=0.08, seed=11, monitor=mon)
    off, on = mk(None), mk(MON)
    ra, rb = off.run(3000), on.run(3000)
    assert ra.app_iops == rb.app_iops
    assert ra.mean_latency == rb.mean_latency
    assert ra.p99_latency == rb.p99_latency
    assert ra.events == rb.events
    assert ra.hit_rate == rb.hit_rate
    np.testing.assert_array_equal(ra.util, rb.util)
    np.testing.assert_array_equal(off.last_latency, on.last_latency)
    assert off.rng.bit_generator.state == on.rng.bit_generator.state
    assert ra.monitor is None and rb.monitor is not None


def test_monitor_identity_with_faults_and_telemetry():
    """Monitor + telemetry + spans + faults all compose without perturbing
    the run, and chaining off telemetry's grid produces the same alerts
    as self-hooking."""
    fp = FaultPolicy(events=(FailSlow(device=1, onset=0.02, duration=5.0,
                                      slow_factor=4.0),))
    kw = dict(faults=fp, seed=9)
    off = _array(**kw).run(4000)
    solo = _array(MON, **kw).run(4000)
    chained = _array(MON, telemetry=TelemetrySpec(spans=True), **kw).run(4000)
    _assert_same_results(off, solo)
    _assert_same_results(off, chained)
    assert solo.monitor.alerts == chained.monitor.alerts
    assert solo.monitor.counts == chained.monitor.counts


def test_rerun_same_seed_same_alerts():
    a = _array(MON, faults=FaultPolicy(events=(
        FailSlow(device=0, onset=0.02, duration=5.0, slow_factor=4.0),)))
    b = _array(MON, faults=FaultPolicy(events=(
        FailSlow(device=0, onset=0.02, duration=5.0, slow_factor=4.0),)))
    ra, rb = a.run(4000), b.run(4000)
    assert ra.monitor.alerts == rb.monitor.alerts
    assert ra.monitor.alerts                # the scenario does alert


# ---------------------------------------------------------------------------
# sharded: serial == parallel bit-identical alert streams
# ---------------------------------------------------------------------------

def test_sharded_array_serial_equals_parallel_alerts():
    fp = FaultPolicy(events=(FailSlow(device=4, onset=0.02, duration=5.0,
                                      slow_factor=5.0),))
    kw = dict(n_ssds=6, ssd=P, occupancy=0.6,
              workload=Workload(w_total=96, qd_per_ssd=16, n_streams=6),
              seed=5, n_shards=2, faults=fp, monitor=MON)
    ser = ShardedArraySim(parallel=False, **kw).run(3000)
    par = ShardedArraySim(parallel=True, **kw).run(3000)
    assert ser.monitor is not None and ser.monitor.merged
    assert ser.monitor.alerts == par.monitor.alerts
    assert ser.monitor.counts == par.monitor.counts
    assert ser.monitor.n_devices == 6
    # the faulted device keeps its array-wide id through the merge
    assert any(a[3] == 4 or "dev4" in a[7] for a in ser.monitor.alerts)
    assert ser.iops == par.iops


def test_sharded_safs_serial_equals_parallel_alerts():
    fp = FaultPolicy(events=(FailSlow(device=2, onset=0.02, duration=5.0,
                                      slow_factor=6.0),))
    kw = dict(n_ssds=4, ssd=P, occupancy=0.85,
              workload=SAFSWorkload(read_frac=0.3, concurrency=128),
              seed=3, n_shards=2, faults=fp, monitor=MON)
    ser = ShardedSAFSSim(parallel=False, **kw).run(3000)
    par = ShardedSAFSSim(parallel=True, **kw).run(3000)
    assert ser.monitor is not None and ser.monitor.merged
    assert ser.monitor.alerts == par.monitor.alerts
    assert ser.monitor.counts == par.monitor.counts
    assert ser.app_iops == par.app_iops


def test_sharded_monitor_none_propagates():
    kw = dict(n_ssds=4, ssd=P, occupancy=0.6,
              workload=Workload(w_total=96, qd_per_ssd=16, n_streams=4),
              seed=5, n_shards=2)
    r = ShardedArraySim(parallel=False, **kw).run(2000)
    assert r.monitor is None


# ---------------------------------------------------------------------------
# merge_monitor unit behavior
# ---------------------------------------------------------------------------

def _mr(n_devices, alerts):
    counts = {}
    for a in alerts:
        counts[a[2]] = counts.get(a[2], 0) + 1
    return MonitorResult(spec=MON, n_devices=n_devices, alerts=list(alerts),
                         counts=counts)


def test_merge_monitor_rebases_and_renumbers():
    a = _mr(3, [(0.1, 0, "util_skew", 2, -1, 3.0, 2.0, "fault:fail_slow:dev2"),
                (0.4, 1, "gc_storm", -1, -1, 1.0, 1.0, "gc:3_devices")])
    b = _mr(3, [(0.2, 0, "backlog_sat", 1, -1, 16.0, 16.0, "gc:dev1")])
    m = merge_monitor([a, b])
    assert m.merged and m.n_devices == 6
    # time-ordered, seq renumbered, shard-1 devices re-based by +3
    assert [x[0] for x in m.alerts] == [0.1, 0.2, 0.4]
    assert [x[1] for x in m.alerts] == [0, 1, 2]
    assert m.alerts[1][3] == 4
    assert m.alerts[1][7] == "gc:dev4"
    assert m.alerts[0][7] == "fault:fail_slow:dev2"   # shard 0: unshifted
    assert m.counts == {"util_skew": 1, "gc_storm": 1, "backlog_sat": 1}


def test_merge_monitor_none_propagation():
    assert merge_monitor([]) is None
    assert merge_monitor([None, _mr(2, [])]) is None


def test_rebase_cause():
    assert _rebase_cause("fault:fail_slow:dev1", 4) == "fault:fail_slow:dev5"
    assert _rebase_cause("gc:dev0", 2) == "gc:dev2"
    assert _rebase_cause("gc:3_devices", 4) == "gc:3_devices"
    assert _rebase_cause("throttle:tenant1:0.5", 4) == "throttle:tenant1:0.5"
    assert _rebase_cause("none", 4) == "none"
    assert _rebase_cause("fault:fail_slow:dev1", 0) == "fault:fail_slow:dev1"


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------

def _faulted_run(telemetry=None):
    fp = FaultPolicy(events=(FailSlow(device=1, onset=0.02, duration=5.0,
                                      slow_factor=4.0),))
    return _array(MON, faults=fp, telemetry=telemetry, seed=9).run(4000)


def test_to_jsonl(tmp_path):
    r = _faulted_run()
    assert r.monitor.n_alerts > 0
    path = tmp_path / "alerts.jsonl"
    n = r.monitor.to_jsonl(path)
    lines = path.read_text().splitlines()
    assert n == len(lines) == r.monitor.n_alerts
    first = json.loads(lines[0])
    assert set(first) == {"time", "seq", "rule", "device", "tenant",
                          "value", "threshold", "cause"}
    assert first["rule"] in RULES


def test_export_trace_alert_instants(tmp_path):
    r = _faulted_run(telemetry=TelemetrySpec(spans=True))
    path = tmp_path / "trace.json"
    r.telemetry.export_trace(path, monitor=r.monitor)
    events = json.loads(path.read_text())["traceEvents"]
    instants = [e for e in events if e.get("cat") == "alert"]
    assert len(instants) == r.monitor.n_alerts
    for e in instants:
        assert e["ph"] == "i"
        assert e["name"] in RULES
        assert "cause" in e["args"]


# ---------------------------------------------------------------------------
# fault-aware GC coordination (gc_lease_skipped)
# ---------------------------------------------------------------------------

def _quarantine_run(gc):
    fp = FaultPolicy(events=(FailSlow(device=1, onset=0.02, duration=10.0,
                                      slow_factor=6.0),), detect=True)
    return _array(gc=gc, faults=fp, seed=4).run(6000)


def test_staggered_gc_skips_quarantined_member():
    r = _quarantine_run(StaggeredGc())
    assert r.faults["quarantines"] >= 1
    assert r.gc_lease_skipped > 0


def test_reactive_gc_never_defers_for_quarantine():
    """ReactiveGc grants unconditionally (it models the uncoordinated
    baseline), so the quarantine skip must not change it vs gc=None."""
    r = _quarantine_run(ReactiveGc())
    assert r.gc_lease_skipped == 0
    fp = FaultPolicy(events=(FailSlow(device=1, onset=0.02, duration=10.0,
                                      slow_factor=6.0),), detect=True)
    bare = _array(faults=fp, seed=4).run(6000)
    assert r.iops == bare.iops
    assert r.p99_latency == bare.p99_latency


def test_sharded_lease_skipped_merges():
    fp = FaultPolicy(events=(FailSlow(device=1, onset=0.02, duration=10.0,
                                      slow_factor=6.0),), detect=True)
    kw = dict(n_ssds=6, ssd=P, occupancy=0.6,
              workload=Workload(w_total=96, qd_per_ssd=16, n_streams=6),
              seed=4, n_shards=2, gc=StaggeredGc(scope="group"),
              faults=fp)
    ser = ShardedArraySim(parallel=False, **kw).run(4000)
    par = ShardedArraySim(parallel=True, **kw).run(4000)
    assert ser.gc_lease_skipped == par.gc_lease_skipped

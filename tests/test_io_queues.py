"""Dual-priority queue invariants (paper §3.2) + executor behaviour.

Only the property test needs hypothesis; the deterministic queue/executor
tests run regardless (hypothesis comes from requirements-dev.txt)."""
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.io_queues import (HIGH, LOW, DualQueue, IOExecutor, IORequest,
                                  next_action)


if given is not None:
    @given(st.integers(0, 100), st.integers(0, 10000), st.integers(0, 32),
           st.integers(0, 32), st.integers(1, 64), st.integers(0, 16))
    @settings(max_examples=300, deadline=None)
    def test_next_action_invariants(hi, lo, infh, infl, maxi, res):
        if res >= maxi:
            res = maxi - 1
        act = next_action(hi, lo, infh, infl, maxi, res)
        inflight = infh + infl
        if act == HIGH:
            assert hi > 0 and inflight < maxi
        elif act == LOW:
            # low only when no high waits AND reserved slots stay free
            assert lo > 0 and hi == 0 and inflight < maxi - res
        else:
            assert (hi == 0 or inflight >= maxi) and \
                   (lo == 0 or hi > 0 or inflight >= maxi - res)
else:
    @pytest.mark.skip(
        reason="property tests need hypothesis (requirements-dev.txt)")
    def test_next_action_invariants():
        pass


def test_high_priority_overtakes_low():
    q = DualQueue(max_inflight=4, reserved=2)
    for i in range(10):
        q.submit(IORequest(payload=("low", i), priority=LOW))
    q.submit(IORequest(payload=("high", 0), priority=HIGH))
    first = q.pop_next()
    assert first.payload[0] == "high"


def test_reserved_slots_block_low():
    q = DualQueue(max_inflight=4, reserved=2)
    for i in range(10):
        q.submit(IORequest(payload=i, priority=LOW))
    issued = []
    while (r := q.pop_next()) is not None:
        issued.append(r)
    assert len(issued) == 2          # 4 - 2 reserved
    # a HIGH request still goes through
    q.submit(IORequest(payload="h", priority=HIGH))
    assert q.pop_next().payload == "h"


def test_stale_discard_and_refill_callback():
    q = DualQueue(max_inflight=4, reserved=1)
    refills = []
    q.refill = lambda: refills.append(1)
    stale = {0: True, 1: True, 2: False}
    discarded = []
    for i in range(3):
        q.submit(IORequest(payload=i, priority=LOW,
                           is_stale=lambda p: stale[p],
                           on_discard=lambda p: discarded.append(p)))
    r = q.pop_next()
    assert r.payload == 2
    assert discarded == [0, 1]
    assert q.stats.discarded_stale == 2
    assert refills            # executor asked the cache for more work


def test_low_starvation_bounded_by_high_drain():
    """Admission-ordering pin (the discipline the QoS scheduler replaces):
    LOW issues nothing while any HIGH waits, no matter how long the LOW
    backlog — but the moment the HIGH queue drains, LOW flows again (the
    starvation is bounded by the HIGH backlog, not permanent)."""
    q = DualQueue(max_inflight=4, reserved=1)
    for i in range(6):
        q.submit(IORequest(payload=("high", i), priority=HIGH))
    for i in range(8):
        q.submit(IORequest(payload=("low", i), priority=LOW))
    issued = []
    inflight = []
    # drive the queue the way DeviceModel does: pop until None, then retire
    # the oldest in-flight request and pop again
    for _ in range(40):
        while (r := q.pop_next()) is not None:
            issued.append(r.payload)
            inflight.append(r)
        if not inflight:
            break
        q.complete(inflight.pop(0))
    # every HIGH precedes every LOW, in FIFO order within each class
    assert issued == [("high", i) for i in range(6)] + \
                     [("low", i) for i in range(8)]


def test_high_low_interleave_under_full_inflight_window():
    """With the inflight window full, a HIGH arrival overtakes the LOW
    backlog as soon as ONE slot frees; LOW resumes only when no HIGH waits
    AND the reserved slots stay free. Pins the exact interleave."""
    q = DualQueue(max_inflight=2, reserved=1)
    for i in range(3):
        q.submit(IORequest(payload=("low", i), priority=LOW))
    first = q.pop_next()
    assert first.payload == ("low", 0)        # 1 of 2 slots (reserved=1)
    assert q.pop_next() is None               # reserved slot keeps LOW out
    q.submit(IORequest(payload=("high", 0), priority=HIGH))
    second = q.pop_next()                     # HIGH may take the reserved slot
    assert second.payload == ("high", 0)
    assert q.pop_next() is None               # window full (2/2)
    q.complete(second)
    q.submit(IORequest(payload=("high", 1), priority=HIGH))
    third = q.pop_next()
    assert third.payload == ("high", 1)       # overtakes the 2 queued LOWs
    q.complete(third)
    assert q.pop_next() is None               # 1 inflight, no free non-
    q.complete(first)                         #   reserved slot for LOW
    fourth = q.pop_next()
    assert fourth.payload == ("low", 1)       # HIGH drained: LOW resumes
    assert q.pop_next() is None
    assert q.stats.issued_high == 2 and q.stats.issued_low == 2


def test_executor_runs_and_completes():
    done = []
    ex = IOExecutor(2, lambda dev, payload: done.append((dev, payload)),
                    max_inflight=2, reserved=1)
    for i in range(20):
        assert ex.submit(i % 2, IORequest(payload=i, priority=LOW))
    assert ex.drain(10.0)
    ex.shutdown()
    assert sorted(p for _, p in done) == list(range(20))


def test_executor_high_beats_backlog():
    order = []
    gate = threading.Event()

    def fn(dev, payload):
        if payload == "slow":
            gate.wait(5.0)
        order.append(payload)

    ex = IOExecutor(1, fn, max_inflight=1, reserved=0)
    ex.submit(0, IORequest(payload="slow", priority=LOW))
    time.sleep(0.05)
    for i in range(5):
        ex.submit(0, IORequest(payload=("low", i), priority=LOW))
    ex.submit(0, IORequest(payload="high", priority=HIGH))
    gate.set()
    assert ex.drain(10.0)
    ex.shutdown()
    # the high request ran before every queued low request
    assert order.index("high") == 1

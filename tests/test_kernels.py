"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flush_score import flush_scores
from repro.kernels.paged_attention import paged_attention
from repro.kernels import ref

RNG = np.random.default_rng(42)


def _sweep(cases, keep=1):
    """Full allclose sweep runs nightly; the first ``keep`` cases stay in the
    fast tier as smoke coverage."""
    return [c if i < keep else pytest.param(c, marks=pytest.mark.slow)
            for i, c in enumerate(cases)]


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


FLASH_CASES = [
    # b, sq, skv, h, kv, hd, causal, window, softcap
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),
    (1, 256, 256, 8, 8, 64, True, 64, 50.0),       # SWA + softcap (gemma2)
    (2, 64, 192, 4, 1, 128, False, 0, 0.0),        # MQA cross-shape
    (1, 100, 100, 2, 2, 32, True, 0, 0.0),         # non-multiple-of-block
    (1, 16, 144, 6, 6, 64, True, 0, 0.0),          # MHA (whisper-like)
    (3, 128, 128, 8, 4, 16, True, 32, 0.0),
]


@pytest.mark.parametrize("case", _sweep(FLASH_CASES, keep=2))
@pytest.mark.parametrize("dtype", _sweep([jnp.float32, jnp.bfloat16]))
def test_flash_attention_matches_ref(case, dtype):
    b, sq, skv, h, kv, hd, causal, window, cap = case
    q = _rand((b, sq, h, hd), dtype)
    k = _rand((b, skv, kv, hd), dtype)
    v = _rand((b, skv, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", _sweep([(32, 32), (64, 128), (128, 64)]))
def test_flash_attention_block_shape_invariance(blocks):
    bq, bkv = blocks
    q = _rand((1, 192, 4, 64), jnp.float32)
    k = _rand((1, 192, 2, 64), jnp.float32)
    v = _rand((1, 192, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_q_offset_decode_tail():
    """Chunked decode: q is a tail slice at offset into the kv history."""
    q_full = _rand((1, 64, 4, 32), jnp.float32)
    k = _rand((1, 64, 4, 32), jnp.float32)
    v = _rand((1, 64, 4, 32), jnp.float32)
    full = ref.flash_attention_ref(q_full, k, v, causal=True)
    tail = flash_attention(q_full[:, 48:], k, v, causal=True, q_offset=48,
                           block_q=16, block_kv=16, interpret=True)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 48:]),
                               atol=2e-5, rtol=2e-5)


PAGED_CASES = [
    # b, h, kv, hd, page, max_pages, pool
    (4, 8, 2, 64, 16, 8, 64),
    (2, 4, 4, 128, 32, 4, 16),
    (3, 6, 6, 32, 8, 16, 128),
    (1, 16, 8, 64, 64, 4, 8),
]


@pytest.mark.parametrize("case", _sweep(PAGED_CASES, keep=2))
@pytest.mark.parametrize("dtype", _sweep([jnp.float32, jnp.bfloat16]))
def test_paged_attention_matches_ref(case, dtype):
    b, h, kv, hd, page, maxp, pool = case
    q = _rand((b, h, hd), dtype)
    kp = _rand((pool, page, kv, hd), dtype)
    vp = _rand((pool, page, kv, hd), dtype)
    table = jnp.asarray(RNG.integers(0, pool, size=(b, maxp)), jnp.int32)
    lengths = jnp.asarray(RNG.integers(1, maxp * page, size=(b,)), jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_paged_attention_softcap():
    b, h, kv, hd, page, maxp, pool = 2, 4, 2, 32, 8, 4, 16
    q = _rand((b, h, hd), jnp.float32)
    kp = _rand((pool, page, kv, hd), jnp.float32)
    vp = _rand((pool, page, kv, hd), jnp.float32)
    table = jnp.asarray(RNG.integers(0, pool, size=(b, maxp)), jnp.int32)
    lengths = jnp.asarray([5, 30], jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths, softcap=30.0,
                          interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, table, lengths, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("ns,ss", [(100, 12), (1000, 12), (64, 7), (513, 16),
                                   (1, 12), (256, 2)])
def test_flush_scores_matches_ref(ns, ss):
    hits = jnp.asarray(RNG.integers(0, 15, size=(ns, ss)), jnp.int32)
    clock = jnp.asarray(RNG.integers(0, ss, size=(ns,)), jnp.int32)
    valid = jnp.asarray(RNG.random((ns, ss)) > 0.3)
    out = flush_scores(hits, clock, valid, block_sets=128, interpret=True)
    want = ref.flush_scores_ref(hits, clock, valid)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_flush_scores_matches_host_policies():
    """Kernel == core/policies.py (the paper's exact formulation)."""
    from repro.core import policies
    hits = RNG.integers(0, 15, size=(50, 12)).astype(np.int64)
    clock = RNG.integers(0, 12, size=(50,))
    valid = RNG.random((50, 12)) > 0.2
    out = np.asarray(flush_scores(jnp.asarray(hits, jnp.int32),
                                  jnp.asarray(clock, jnp.int32),
                                  jnp.asarray(valid), interpret=True))
    for i in range(50):
        want = policies.flush_scores(hits[i], int(clock[i]), valid=valid[i])
        np.testing.assert_array_equal(out[i], want)

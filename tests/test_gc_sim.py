"""SSD-array simulator: FTL invariants + the paper's qualitative trends."""
import numpy as np
import pytest

from repro.core.gc_sim import (FTL, ArraySim, SSDParams, Workload, ZipfSampler,
                               single_ssd_write_iops)

SMALL = SSDParams(capacity_pages=8192)


def test_ftl_mapping_invariants():
    rng = np.random.default_rng(0)
    ftl = FTL(SMALL, rng)
    ftl.prefill(0.5, churn=False)
    for _ in range(5000):
        ftl.user_write(int(rng.integers(ftl.live_lbas)))
        while ftl.need_gc() and not ftl.gc_satisfied():
            ftl.gc_reclaim_one()
    # every live LBA maps to a phys page that maps back
    live = np.flatnonzero(ftl.lba_loc >= 0)
    assert live.size == ftl.live_lbas
    phys = ftl.lba_loc[live]
    assert (ftl.page_lba[phys] == live).all()
    # valid counts consistent
    for b in range(ftl.p.n_blocks):
        base = b * ftl.p.pages_per_block
        n = (ftl.page_lba[base:base + ftl.p.pages_per_block] >= 0).sum()
        assert n == ftl.valid_count[b]


def test_write_amplification_grows_with_occupancy():
    was = []
    for occ in (0.4, 0.8):
        rng = np.random.default_rng(1)
        ftl = FTL(SMALL, rng)
        ftl.prefill(occ)
        for _ in range(20000):
            ftl.user_write(int(rng.integers(ftl.live_lbas)))
            while ftl.need_gc() and not ftl.gc_satisfied():
                ftl.gc_reclaim_one()
        was.append((ftl.writes + ftl.gc_copies) / max(ftl.writes, 1))
    assert was[1] > was[0] >= 1.0


def test_paper_trend_occupancy_lowers_iops():
    iops = [single_ssd_write_iops(occ, params=SMALL, measure_ops=12000)
            for occ in (0.4, 0.8)]
    assert iops[0] > iops[1]


def test_array_underutilization_with_bounded_window():
    """Paper Table 2/Fig 2: small outstanding window underutilizes the array."""
    small = ArraySim(4, SMALL, 0.6,
                     Workload(w_total=64, qd_per_ssd=16, n_streams=1),
                     seed=2).run(12000)
    big = ArraySim(4, SMALL, 0.6,
                   Workload(w_total=512, qd_per_ssd=128, n_streams=8),
                   seed=2).run(12000)
    assert big.iops > small.iops


def test_zipf_sampler_is_skewed_and_bounded():
    rng = np.random.default_rng(3)
    z = ZipfSampler(10**9, 0.99, rng)
    xs = np.array([z.sample() for _ in range(20000)])
    assert xs.min() >= 1 and xs.max() <= 10**9
    top = (xs <= 10).mean()
    assert top > 0.05          # heavy head


def test_zipf_workload_coalesces_more_than_uniform():
    """Hot LBAs under Zipf hit the device write buffer (pending-write
    coalescing) more often than uniform — the mechanism behind the paper's
    lower parallel-write requirement for Zipf (Fig 2)."""
    res = {}
    for dist in ("uniform", "zipf"):
        sim = ArraySim(2, SMALL, 0.6,
                       Workload(dist=dist, w_total=256, qd_per_ssd=128,
                                virtual_scale=4),
                       seed=4)
        r = sim.run(15000)
        res[dist] = r.iops
    assert res["zipf"] >= res["uniform"] * 0.9

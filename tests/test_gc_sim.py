"""SSD-array simulator: FTL invariants + the paper's qualitative trends."""
import numpy as np
import pytest

from repro.core.gc_sim import (FTL, ArraySim, SealFifo, SSDParams, Workload,
                               ZipfSampler, single_ssd_write_iops)

SMALL = SSDParams(capacity_pages=8192)


def test_ftl_mapping_invariants():
    rng = np.random.default_rng(0)
    ftl = FTL(SMALL, rng)
    ftl.prefill(0.5, churn=False)
    for _ in range(5000):
        ftl.user_write(int(rng.integers(ftl.live_lbas)))
        while ftl.need_gc() and not ftl.gc_satisfied():
            ftl.gc_reclaim_one()
    # every live LBA maps to a phys page that maps back
    live = np.flatnonzero(ftl.lba_loc >= 0)
    assert live.size == ftl.live_lbas
    phys = ftl.lba_loc[live]
    assert (ftl.page_lba[phys] == live).all()
    # valid counts consistent
    for b in range(ftl.p.n_blocks):
        base = b * ftl.p.pages_per_block
        n = (ftl.page_lba[base:base + ftl.p.pages_per_block] >= 0).sum()
        assert n == ftl.valid_count[b]


def test_write_amplification_grows_with_occupancy():
    was = []
    for occ in (0.4, 0.8):
        rng = np.random.default_rng(1)
        ftl = FTL(SMALL, rng)
        ftl.prefill(occ)
        for _ in range(20000):
            ftl.user_write(int(rng.integers(ftl.live_lbas)))
            while ftl.need_gc() and not ftl.gc_satisfied():
                ftl.gc_reclaim_one()
        was.append((ftl.writes + ftl.gc_copies) / max(ftl.writes, 1))
    assert was[1] > was[0] >= 1.0


def test_paper_trend_occupancy_lowers_iops():
    iops = [single_ssd_write_iops(occ, params=SMALL, measure_ops=12000)
            for occ in (0.4, 0.8)]
    assert iops[0] > iops[1]


def test_array_underutilization_with_bounded_window():
    """Paper Table 2/Fig 2: small outstanding window underutilizes the array."""
    small = ArraySim(4, SMALL, 0.6,
                     Workload(w_total=64, qd_per_ssd=16, n_streams=1),
                     seed=2).run(12000)
    big = ArraySim(4, SMALL, 0.6,
                   Workload(w_total=512, qd_per_ssd=128, n_streams=8),
                   seed=2).run(12000)
    assert big.iops > small.iops


def test_zipf_sampler_is_skewed_and_bounded():
    rng = np.random.default_rng(3)
    z = ZipfSampler(10**9, 0.99, rng)
    xs = np.array([z.sample() for _ in range(20000)])
    assert xs.min() >= 1 and xs.max() <= 10**9
    top = (xs <= 10).mean()
    assert top > 0.05          # heavy head


def test_seal_fifo_order_removal_and_compaction():
    sf = SealFifo()
    for b in range(10):
        sf.append(b)
    assert len(sf) == 10 and 3 in sf
    for b in (0, 2, 4, 6, 8, 1):          # > half dead: triggers compaction
        sf.remove(b)
    assert len(sf) == 4 and 0 not in sf
    assert list(sf) == [3, 5, 7, 9]       # seal order survives compaction
    assert sf.head_window(2) == [3, 5]
    sf.append(42)
    assert list(sf) == [3, 5, 7, 9, 42]


def test_seal_fifo_sample_distinct():
    """Sampled GC must be true d-choices: no duplicate candidates (sampling
    the same index twice degenerated d-choices to 1-choice)."""
    rng = np.random.default_rng(7)
    sf = SealFifo()
    for b in range(20):
        sf.append(b)
    for b in range(0, 20, 2):
        sf.remove(b)                      # leave tombstones in the backing array
    for _ in range(200):
        got = sf.sample_distinct(rng, 4)
        assert len(got) == len(set(got)) == 4
        assert all(b % 2 == 1 for b in got)
    # k >= live returns everything
    assert sorted(sf.sample_distinct(rng, 50)) == list(range(1, 20, 2))


def test_seal_fifo_heavy_churn_matches_reference():
    """Deterministic heavy append/remove churn (forces many compactions)
    against a plain-list reference: length, order, membership, and
    head_window stay equivalent. (The hypothesis version with arbitrary
    interleavings lives in test_seal_fifo_prop.py.)"""
    rng = np.random.default_rng(11)
    sf = SealFifo()
    ref: list[int] = []
    next_block = 0
    for step in range(5000):
        if not ref or rng.random() < 0.55:
            sf.append(next_block)
            ref.append(next_block)
            next_block += 1
        else:
            victim = ref[int(rng.integers(len(ref)))]
            sf.remove(victim)
            ref.remove(victim)
        if step % 97 == 0:        # periodic deep check (every step is O(n))
            assert list(sf) == ref
    assert len(sf) == len(ref)
    assert list(sf) == ref
    assert all(b in sf for b in ref)
    for k in (0, 1, 7, len(ref), len(ref) + 5):
        assert sf.head_window(k) == ref[:k]


def test_ftl_numpy_views_match_list_state():
    """The list-backed FTL still exposes numpy views for analysis; they must
    reflect the live mapping state."""
    rng = np.random.default_rng(2)
    ftl = FTL(SMALL, rng)
    ftl.prefill(0.4)
    for _ in range(2000):
        ftl.user_write(int(rng.integers(ftl.live_lbas)))
        while ftl.need_gc() and not ftl.gc_satisfied():
            ftl.gc_reclaim_one()
    assert ftl.page_lba.dtype == np.int64
    assert ftl.valid_count.sum() == ftl.live_lbas
    live = np.flatnonzero(ftl.lba_loc >= 0)
    np.testing.assert_array_equal(ftl.page_lba[ftl.lba_loc[live]], live)
    assert ftl.sealed.dtype == bool


def test_batched_prefill_matches_scalar_programs():
    """The vectorized sequential fill must leave the FTL in exactly the state
    the one-page-at-a-time loop produced."""
    for occ in (0.3, 0.5):
        fast = FTL(SMALL, np.random.default_rng(0))
        fast.prefill(occ, churn=False)
        slow = FTL(SMALL, np.random.default_rng(0))
        for lba in range(int(SMALL.capacity_pages * occ)):
            slow._program(lba)
        np.testing.assert_array_equal(fast.page_lba, slow.page_lba)
        np.testing.assert_array_equal(fast.lba_loc, slow.lba_loc)
        np.testing.assert_array_equal(fast.valid_count, slow.valid_count)
        np.testing.assert_array_equal(fast.sealed, slow.sealed)
        assert list(fast.seal_fifo) == list(slow.seal_fifo)
        assert (fast.active, fast.active_off) == (slow.active, slow.active_off)
        assert list(fast.free_blocks) == list(slow.free_blocks)


def test_program_chunk_handles_duplicates():
    """Within-batch duplicate LBAs: last occurrence wins, earlier ones land
    dead-on-arrival — identical to sequential scalar programs."""
    a = FTL(SMALL, np.random.default_rng(1))
    b = FTL(SMALL, np.random.default_rng(1))
    a.prefill(0.4, churn=False)
    b.prefill(0.4, churn=False)
    lbas = np.array([5, 9, 5, 7, 9, 9, 11], dtype=np.int64)
    a._program_chunk(lbas)
    for lba in lbas:
        b._program(int(lba))
    np.testing.assert_array_equal(a.page_lba, b.page_lba)
    np.testing.assert_array_equal(a.lba_loc, b.lba_loc)
    np.testing.assert_array_equal(a.valid_count, b.valid_count)
    assert (a.active, a.active_off) == (b.active, b.active_off)


def test_run_zero_ops_returns_immediately():
    """run(0) must terminate (regression: a falsy completion target of 0
    once disabled the stop condition and the closed loop spun forever)."""
    r = ArraySim(2, SMALL, 0.6,
                 Workload(w_total=8, qd_per_ssd=4, n_streams=2),
                 seed=0).run(0)
    assert r.events == 0
    assert r.iops == 0.0


def test_queue_depth_scales_throughput_under_gc():
    """The paper's core lever, now a real experimental variable: deeper
    per-SSD queues monotonically raise array throughput while GC is active,
    because NCQ slots overlap service and hide unsynchronized GC pauses."""
    prev = 0.0
    for qd in (1, 4, 32, 128):
        r = ArraySim(4, SMALL, 0.6,
                     Workload(w_total=4 * qd, qd_per_ssd=qd, n_streams=4),
                     seed=0).run(8000)
        assert r.iops > prev, f"qd={qd} did not improve throughput"
        assert r.p50_latency <= r.p95_latency <= r.p99_latency
        assert r.p99_latency > 0
        prev = r.iops


@pytest.mark.slow
def test_queue_depth_sweep_18_ssd_array():
    """Acceptance sweep at the paper's array scale (18 SSDs)."""
    prev = 0.0
    for qd in (1, 4, 32, 128):
        r = ArraySim(18, SMALL, 0.6,
                     Workload(w_total=18 * qd, qd_per_ssd=qd, n_streams=18),
                     seed=0).run(30000)
        assert r.iops > prev, f"qd={qd} did not improve throughput"
        prev = r.iops


def test_zipf_workload_coalesces_more_than_uniform():
    """Hot LBAs under Zipf hit the device write buffer (pending-write
    coalescing) more often than uniform — the mechanism behind the paper's
    lower parallel-write requirement for Zipf (Fig 2)."""
    res = {}
    for dist in ("uniform", "zipf"):
        sim = ArraySim(2, SMALL, 0.6,
                       Workload(dist=dist, w_total=256, qd_per_ssd=128,
                                virtual_scale=4),
                       seed=4)
        r = sim.run(15000)
        res[dist] = r.iops
    assert res["zipf"] >= res["uniform"] * 0.9

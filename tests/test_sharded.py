"""Process-sharded array simulation: partitioning, merging, determinism,
and the 100+ SSD scale path (raw array and full SAFS)."""
import numpy as np
import pytest

from repro.core.gc_sim import ArrayResults, SSDParams, Workload
from repro.core.safs_sim import SAFSResults, SAFSWorkload
from repro.core.sharded import ShardedArraySim, ShardedSAFSSim, \
    merge_results, merge_safs_results, pool_samples, shard_seed, shard_sizes

SMALL = SSDParams(capacity_pages=4096)


def test_shard_sizes_balanced():
    assert shard_sizes(18, 2) == [9, 9]
    assert shard_sizes(18, 4) == [5, 5, 4, 4]
    assert shard_sizes(128, 8) == [16] * 8
    assert shard_sizes(3, 8) == [1, 1, 1]      # clamped to n_ssds
    assert shard_sizes(7, 1) == [7]
    for n, k in ((100, 7), (128, 6), (19, 4)):
        sizes = shard_sizes(n, k)
        assert sum(sizes) == n
        assert max(sizes) - min(sizes) <= 1


def test_shard_seeds_decorrelated():
    seeds = [shard_seed(0, k) for k in range(16)] + \
            [shard_seed(1, k) for k in range(16)]
    assert len(set(seeds)) == len(seeds)


def test_merge_results_rates_add_and_percentiles_pool():
    mk = lambda iops, n, ev: ArrayResults(
        iops=iops, per_ssd_iops=np.full(n, iops / n), read_iops=0.0,
        write_iops=iops, util=np.full(n, 0.5), sim_time=1.0,
        gc_pause_frac=np.zeros(n), mean_latency=0.0, events=ev, wall_s=1.0)
    parts = [mk(100.0, 2, 10), mk(300.0, 3, 30)]
    pooled = pool_samples([np.array([1.0, 2.0, 3.0]), None, np.empty(0),
                           np.array([4.0, 5.0])])
    m = merge_results(parts, pooled)
    assert m.iops == 400.0
    assert m.per_ssd_iops.shape == (5,)
    assert m.events == 40
    assert m.p50_latency == 3.0               # exact over pooled samples
    assert m.mean_latency == pytest.approx(3.0)


def test_serial_equals_parallel():
    """The worker-process path must be bit-identical to running the same
    shard decomposition in-process."""
    wl = Workload(w_total=6 * 16, qd_per_ssd=16, n_streams=6)
    a = ShardedArraySim(6, SMALL, 0.6, wl, seed=5, n_shards=2,
                        parallel=True).run(6000)
    b = ShardedArraySim(6, SMALL, 0.6, wl, seed=5, n_shards=2,
                        parallel=False).run(6000)
    assert a.iops == b.iops
    assert a.p99_latency == b.p99_latency
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    np.testing.assert_array_equal(a.gc_pause_frac, b.gc_pause_frac)


def test_sharded_run_zero_ops_is_noop():
    """run(0) matches ArraySim.run(0): no ops are manufactured by the
    per-shard minimum (regression: max(1, ...) turned a zero budget into
    one op per shard)."""
    r = ShardedArraySim(4, SMALL, 0.6,
                        Workload(w_total=16, qd_per_ssd=4, n_streams=4),
                        seed=0, n_shards=2, parallel=False).run(0)
    assert r.events == 0
    assert r.iops == 0.0


def test_sharded_run_is_deterministic():
    wl = Workload(w_total=4 * 8, qd_per_ssd=8, n_streams=4)
    a = ShardedArraySim(4, SMALL, 0.6, wl, seed=9, n_shards=2).run(4000)
    b = ShardedArraySim(4, SMALL, 0.6, wl, seed=9, n_shards=2).run(4000)
    assert a.iops == b.iops and a.p95_latency == b.p95_latency


def test_window_splits_proportionally():
    sim = ShardedArraySim(10, SMALL, 0.6,
                          Workload(w_total=100, qd_per_ssd=10, n_streams=10),
                          seed=0, n_shards=3)
    args = sim._shard_args(3000, None)
    sizes = [a[0] for a in args]
    assert sizes == [4, 3, 3]
    assert [a[3].w_total for a in args] == [40, 30, 30]
    assert [a[3].n_streams for a in args] == [4, 3, 3]
    assert sum(a[5] for a in args) == pytest.approx(3000, abs=len(args))


# -- sharded SAFS ------------------------------------------------------------

@pytest.mark.parametrize("scenario", ["random", "hot_cold"])
@pytest.mark.parametrize("use_flusher", [True, False])
def test_safs_serial_equals_parallel(scenario, use_flusher):
    """Acceptance: serial == sharded bit-identity on two patterns x two
    policies (flusher on/off) — the worker-pool path must match the same
    shard decomposition run in-process, field for field."""
    wl = SAFSWorkload(read_frac=0.3, scenario=scenario, concurrency=128)
    a = ShardedSAFSSim(8, SMALL, 0.8, wl, use_flusher=use_flusher, seed=3,
                       n_shards=4, parallel=True).run(4000)
    b = ShardedSAFSSim(8, SMALL, 0.8, wl, use_flusher=use_flusher, seed=3,
                       n_shards=4, parallel=False).run(4000)
    assert a.app_iops == b.app_iops
    assert a.hit_rate == b.hit_rate
    assert a.ssd_page_writes == b.ssd_page_writes
    assert a.flush_writes == b.flush_writes
    assert a.demand_writes == b.demand_writes
    assert a.p99_latency == b.p99_latency
    assert a.cache_hits == b.cache_hits
    assert a.cache_lookups == b.cache_lookups
    np.testing.assert_array_equal(a.util, b.util)


def test_safs_sharded_is_deterministic():
    wl = SAFSWorkload(read_frac=0.3, concurrency=64)
    a = ShardedSAFSSim(4, SMALL, 0.8, wl, seed=9, n_shards=2).run(2000)
    b = ShardedSAFSSim(4, SMALL, 0.8, wl, seed=9, n_shards=2).run(2000)
    assert a.app_iops == b.app_iops and a.p95_latency == b.p95_latency
    assert a.hit_rate == b.hit_rate


def test_safs_merge_pools_hit_rate_from_raw_counters():
    """Hit rate must be recomputed from pooled hits/lookups, never an
    average of per-shard ratios (unequal lookup counts would skew it)."""
    mk = lambda iops, n, hits, lk: SAFSResults(
        app_iops=iops, hit_rate=hits / max(lk, 1), ssd_page_writes=10,
        flush_writes=5, demand_writes=1, ssd_reads=2, stale_discards=0,
        app_ops=100, mean_latency=0.0, sim_time=1.0, util=np.full(n, 0.5),
        events=10, wall_s=1.0, cache_hits=hits, cache_lookups=lk)
    parts = [mk(100.0, 2, 90, 100), mk(300.0, 3, 10, 1000)]
    pooled = pool_samples([np.array([1.0, 2.0, 3.0]), np.array([4.0, 5.0])])
    m = merge_safs_results(parts, pooled)
    assert m.app_iops == 400.0
    assert m.hit_rate == pytest.approx(100 / 1100)   # NOT (0.9 + 0.01) / 2
    assert m.util.shape == (5,)
    assert m.p50_latency == 3.0                      # exact over pooled
    assert m.ssd_page_writes == 20 and m.flush_writes == 10


def test_safs_concurrency_splits_proportionally():
    sim = ShardedSAFSSim(10, SMALL, 0.8,
                         SAFSWorkload(concurrency=320), n_shards=3)
    args = sim._shard_args(3000, None)
    assert [a[0] for a in args] == [4, 3, 3]
    assert [a[3].concurrency for a in args] == [128, 96, 96]


def test_safs_sharded_rejects_qos_and_trace():
    from repro.core.qos import QosPolicy, TenantSpec
    qos = QosPolicy(tenants=(TenantSpec(0, 1.0), TenantSpec(1, 1.0)))
    with pytest.raises(NotImplementedError):
        ShardedSAFSSim(4, SMALL, qos=qos)
    # trace replay IS sharded now (per-shard slicing) — but it still needs
    # the trace array itself
    with pytest.raises(ValueError):
        ShardedSAFSSim(4, SMALL, workload=SAFSWorkload(scenario="trace"))


@pytest.mark.slow
def test_safs_scale_sweep_128_ssds():
    """The tentpole unlock: the paper's actual system (SA-cache + flusher)
    at 128 SSDs, with skew locality surviving the scale-out."""
    wl = lambda scen: SAFSWorkload(read_frac=0.3, scenario=scen,
                                   concurrency=32 * 128)
    sk = ShardedSAFSSim(128, SSDParams(capacity_pages=8192), 0.8,
                        wl("hot_cold"), seed=0, n_shards=4).run(20000)
    un = ShardedSAFSSim(128, SSDParams(capacity_pages=8192), 0.8,
                        wl("random"), seed=0, n_shards=4).run(20000)
    assert sk.util.shape == (128,)
    assert sk.hit_rate > un.hit_rate          # skew locality preserved
    assert sk.app_ops == 20000 and un.app_ops == 20000


@pytest.mark.slow
def test_scale_sweep_128_ssds_monotone():
    """The ROADMAP scale item: a 128-SSD qd sweep completes and keeps the
    paper's monotone qd->throughput trend under active GC."""
    prev = 0.0
    for qd in (1, 4, 32):
        r = ShardedArraySim(
            128, SSDParams(capacity_pages=8192), 0.6,
            Workload(w_total=128 * qd, qd_per_ssd=qd, n_streams=128),
            seed=0).run(80000)
        assert r.per_ssd_iops.shape == (128,)
        assert r.iops > prev, f"qd={qd} did not improve throughput"
        prev = r.iops

"""End-to-end SAFS simulation: the paper's core claims, qualitatively."""
import numpy as np

from repro.core.flusher import FlushRequest
from repro.core.gc_sim import SSDParams
from repro.core.safs_sim import NumpySACache, SAFSSim, SAFSWorkload

SMALL = SSDParams(capacity_pages=8192)


def test_sa_cache_matches_policies():
    from repro.core import policies
    rng = np.random.default_rng(0)
    c = NumpySACache(num_sets=16, set_size=12)
    for _ in range(2000):
        tag = int(rng.integers(500))
        s, slot = c.lookup(tag)
        if slot < 0:
            c.insert(tag, dirty=bool(rng.random() < 0.5))
    for s in range(16):
        fs = c._flush_scores(s)
        valid = np.array([t != -1 for t in c.tags[s]])
        ref = policies.flush_scores(np.array(c.hits[s]), c.clock[s],
                                    valid=valid)
        np.testing.assert_array_equal(np.array(fs), ref)
        # dirty counter consistency
        assert c._dirty_n[s] == sum(
            d and t != -1 for d, t in zip(c.dirty[s], c.tags[s]))


def test_flusher_improves_write_only_throughput():
    """Paper Fig 3 direction: flusher ON >= flusher OFF for random writes."""
    res = {}
    for fl in (True, False):
        sim = SAFSSim(n_ssds=4, ssd=SMALL, occupancy=0.8,
                      workload=SAFSWorkload(read_frac=0.0, concurrency=128),
                      cache_frac=0.1, use_flusher=fl, seed=0)
        res[fl] = sim.run(12000).app_iops
    assert res[True] > res[False]


def test_flusher_keeps_writeback_amplification_low():
    """Paper Table 3: extra writeback vs no-flusher baseline is small."""
    writes = {}
    for fl in (True, False):
        sim = SAFSSim(n_ssds=4, ssd=SMALL, occupancy=0.6,
                      workload=SAFSWorkload(read_frac=0.2, dist="zipf",
                                            concurrency=128),
                      cache_frac=0.1, use_flusher=fl, seed=1)
        r = sim.run(10000)
        writes[fl] = r.ssd_page_writes / max(r.app_ops, 1)
    # within 25% extra page writes per app op (paper: <= 3.2% at full scale;
    # the scaled-down cache makes relative overhead larger)
    assert writes[True] <= writes[False] * 1.25 + 0.05


def test_demand_writes_nearly_eliminated():
    """Clean-first + pre-cleaning: application ops almost never block on a
    dirty victim when the flusher runs (paper §3.3)."""
    sim = SAFSSim(n_ssds=4, ssd=SMALL, occupancy=0.6,
                  workload=SAFSWorkload(read_frac=0.0, concurrency=128),
                  cache_frac=0.1, use_flusher=True, seed=2)
    r = sim.run(10000)
    sim_off = SAFSSim(n_ssds=4, ssd=SMALL, occupancy=0.6,
                      workload=SAFSWorkload(read_frac=0.0, concurrency=128),
                      cache_frac=0.1, use_flusher=False, seed=2)
    r_off = sim_off.run(10000)
    assert r.demand_writes < r_off.demand_writes


def _register_inflight(flusher, fr):
    """Book a hand-built FlushRequest as issued (what make_requests does)."""
    flusher._pending_per_dev[fr.device] = \
        flusher._pending_per_dev.get(fr.device, 0) + 1
    flusher._total_pending += 1
    flusher._inflight.add((fr.set_idx, fr.slot, fr.tag))


def test_flush_completion_does_not_drop_concurrent_write():
    """Regression for the lost-write race: a write that re-dirties a slot
    AFTER its flush was issued must survive the flush completion. The old
    code cleaned whenever the tag still matched."""
    sim = SAFSSim(n_ssds=1, ssd=SMALL, occupancy=0.5,
                  workload=SAFSWorkload(concurrency=8), cache_frac=0.1,
                  use_flusher=True, seed=0)
    c = sim.cache
    tag = 1234
    s, slot, _, _ = c.insert(tag, dirty=True)
    fr = FlushRequest(tag=tag, set_idx=s, slot=slot, device=0,
                      score_at_issue=5, dirty_epoch=c.dirty_epoch_of(s, slot))
    _register_inflight(sim.flusher, fr)
    c.mark_dirty(s, slot)              # concurrent write while flush in flight
    sim._on_flush_complete(fr)
    assert c.dirty[s][slot], "flush completion dropped the newer write"
    # a flush carrying the CURRENT epoch does clean
    fr2 = FlushRequest(tag=tag, set_idx=s, slot=slot, device=0,
                       score_at_issue=5, dirty_epoch=c.dirty_epoch_of(s, slot))
    _register_inflight(sim.flusher, fr2)
    sim._on_flush_complete(fr2)
    assert not c.dirty[s][slot]


def test_flusher_stamps_current_epoch_into_requests():
    c = NumpySACache(num_sets=8, set_size=4, n_devices=1)
    from repro.core.flusher import DirtyPageFlusher
    f = DirtyPageFlusher(c, 1, trigger=0, per_visit=4)
    s, slot, _, _ = c.insert(7, dirty=True)
    f.note_write(s)
    (fr,) = f.make_requests(budget=1)
    assert (fr.set_idx, fr.slot, fr.tag) == (s, slot, 7)
    assert fr.dirty_epoch == c.dirty_epoch_of(s, slot)


def test_safs_results_include_latency_percentiles():
    sim = SAFSSim(n_ssds=2, ssd=SMALL, occupancy=0.6,
                  workload=SAFSWorkload(read_frac=0.2, concurrency=64),
                  cache_frac=0.1, use_flusher=True, seed=4)
    r = sim.run(5000)
    assert 0 < r.p50_latency <= r.p95_latency <= r.p99_latency
    assert r.mean_latency > 0


def test_stale_discards_happen_under_churn():
    sim = SAFSSim(n_ssds=2, ssd=SMALL, occupancy=0.6,
                  workload=SAFSWorkload(read_frac=0.0, dist="zipf",
                                        concurrency=64, virtual_scale=2),
                  cache_frac=0.2, use_flusher=True, score_threshold=4, seed=3)
    r = sim.run(8000)
    assert r.stale_discards > 0

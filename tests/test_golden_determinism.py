"""Seed-for-seed determinism goldens for the event-engine fast path.

The values below were recorded from the PRE-fast-path engine (PR 1 state,
commit 7edcad4) on small configs. The slotted event records, batch-admission
kick, list-backed FTL, and payload handlers must not change event ordering,
RNG consumption, or float accumulation order — so a fixed seed must keep
producing BYTE-IDENTICAL counters, rates, and latency percentiles.

If a change legitimately alters simulation semantics (a modeling change, not
an optimization), regenerate these goldens and say so in the commit.
"""
import numpy as np
import pytest

from repro.core.gc_sim import ArraySim, SSDParams, Workload, \
    clear_prefill_cache
from repro.core.raid import JBODLayout, Raid0Layout, Raid5Layout
from repro.core.safs_sim import SAFSSim, SAFSWorkload

P = SSDParams(capacity_pages=4096)

GOLDEN_ARRAY_UNIFORM = {
    "iops": 79653.14748115413,
    "read_iops": 0.0,
    "write_iops": 79653.14748115413,
    "sim_time": 0.07532659021942097,
    "mean": 0.0008500640771864282,
    "p50": 0.0005252100840336116,
    "p95": 0.0039226453081232515,
    "p99": 0.005141150210084031,
    "writes": 8901,
    "gc_copies": 3676,
    "erases": 196,
    "per_ssd": [27400.68273351702, 26577.600209545093, 25674.864538092013],
}

GOLDEN_ARRAY_ZIPF = {
    "iops": 67940.04668324922,
    "read_iops": 19661.849510132328,
    "write_iops": 48278.1971731169,
    "sim_time": 0.07359429738562075,
    "mean": 0.0006936046646825378,
    "p50": 0.0005252100840336116,
    "p95": 0.0033306158963585302,
    "p99": 0.005019049737394944,
    "writes": 4669,
    "gc_copies": 2029,
    "erases": 106,
}

# Array-layout goldens (PR 3): recorded from the initial core/raid.py
# implementation. These pin the layout subsystem's event ordering, planner
# state machine, and WA accounting — regenerate only for deliberate modeling
# changes, and say so in the commit.
GOLDEN_RAID0 = {
    "iops": 38590.54675913594,
    "read_iops": 0.0,
    "write_iops": 38590.54675913594,
    "sim_time": 0.12956540966386537,
    "mean_latency": 0.001209042652194209,
    "p50_latency": 0.0005252100840336116,
    "p99_latency": 0.005664104808590087,
    "parity_wa": 1.0,
    "gc_wa": 1.4088096104841645,
    "stripe_stall_p99": 0.004973751167133528,
    "logical_writes": 16556,
    "child_writes": 16556,
    "child_reads": 0,
    "ftl_writes": 16482,
    "ftl_gc_copies": 6738,
}

GOLDEN_RAID5 = {
    # parity_writes == logical_writes + 1: one displaced-run catch-up parity
    # fires in this window (run-collision handling, reviewed fix)
    "iops": 58162.314823744746,
    "read_iops": 17739.50602124215,
    "write_iops": 40422.80880250259,
    "sim_time": 0.08596631711017719,
    "mean_latency": 0.0012298153637955143,
    "p50_latency": 0.000880765639589165,
    "p99_latency": 0.006076963702147525,
    "parity_wa": 2.0002875215641174,
    "gc_wa": 1.4284678938976663,
    "stripe_stall_p99": 0.004810863095238094,
    "logical_writes": 3478,
    "child_writes": 6957,
    "child_reads": 8506,
    "parity_writes": 3479,
    "rmw_ops": 3463,
    "ftl_writes": 6899,
    "ftl_gc_copies": 2956,
}

GOLDEN_SAFS_UNIFORM = {
    "app_iops": 101486.93371274845,
    "hit_rate": 0.10210737581535374,
    "ssd_page_writes": 2509,
    "flush_writes": 954,
    "demand_writes": 2840,
    "ssd_reads": 0,
    "stale_discards": 817,
    "sim_time": 0.03941394082633057,
    "mean": 0.0006391189348447718,
    "p50": 0.0004105794817926972,
    "p95": 0.0035815236928104614,
    "p99": 0.005803759337068157,
}


def _array_counters(sim, r):
    return {
        "iops": r.iops, "read_iops": r.read_iops, "write_iops": r.write_iops,
        "sim_time": r.sim_time, "mean": r.mean_latency, "p50": r.p50_latency,
        "p95": r.p95_latency, "p99": r.p99_latency,
        "writes": sum(s.ftl.writes for s in sim.ssds),
        "gc_copies": sum(s.ftl.gc_copies for s in sim.ssds),
        "erases": sum(s.ftl.erases for s in sim.ssds),
    }


def test_golden_array_uniform():
    sim = ArraySim(3, P, 0.6, Workload(w_total=96, qd_per_ssd=32, n_streams=3),
                   seed=42)
    r = sim.run(6000)
    got = _array_counters(sim, r)
    for k, want in GOLDEN_ARRAY_UNIFORM.items():
        if k == "per_ssd":
            continue
        assert got[k] == want, f"{k}: {got[k]!r} != golden {want!r}"
    assert [float(x) for x in r.per_ssd_iops] == GOLDEN_ARRAY_UNIFORM["per_ssd"]


def test_golden_array_zipf_mixed_rw():
    sim = ArraySim(2, P, 0.6,
                   Workload(dist="zipf", read_frac=0.3, w_total=64,
                            qd_per_ssd=32, n_streams=2), seed=7)
    r = sim.run(5000)
    got = _array_counters(sim, r)
    for k, want in GOLDEN_ARRAY_ZIPF.items():
        assert got[k] == want, f"{k}: {got[k]!r} != golden {want!r}"


def test_golden_array_jbod_layout_is_the_fast_path():
    """JBODLayout (the default) must reproduce the PR 2 golden byte-for-byte:
    the layout subsystem may not perturb the fast path's event ordering, RNG
    consumption, or float accumulation order."""
    for layout in (None, JBODLayout()):
        sim = ArraySim(3, P, 0.6,
                       Workload(w_total=96, qd_per_ssd=32, n_streams=3),
                       seed=42, layout=layout)
        r = sim.run(6000)
        got = _array_counters(sim, r)
        for k, want in GOLDEN_ARRAY_UNIFORM.items():
            if k == "per_ssd":
                continue
            assert got[k] == want, f"{k}: {got[k]!r} != golden {want!r}"
        assert [float(x) for x in r.per_ssd_iops] \
            == GOLDEN_ARRAY_UNIFORM["per_ssd"]
        assert r.layout == "jbod"


def test_golden_raid0():
    r = ArraySim(6, P, 0.6,
                 Workload(w_total=96, qd_per_ssd=32, n_streams=6), seed=42,
                 layout=Raid0Layout(stripe_width=4, group=6)).run(5000)
    for k, want in GOLDEN_RAID0.items():
        got = getattr(r, k)
        assert got == want, f"{k}: {got!r} != golden {want!r}"


def test_golden_raid5():
    r = ArraySim(6, P, 0.6,
                 Workload(w_total=96, qd_per_ssd=32, n_streams=6,
                          read_frac=0.3), seed=7,
                 layout=Raid5Layout(group=6)).run(5000)
    for k, want in GOLDEN_RAID5.items():
        got = getattr(r, k)
        assert got == want, f"{k}: {got!r} != golden {want!r}"


def test_golden_safs_uniform():
    sim = SAFSSim(n_ssds=2, ssd=P, occupancy=0.6,
                  workload=SAFSWorkload(concurrency=64), cache_frac=0.1,
                  seed=3)
    r = sim.run(4000)
    got = {
        "app_iops": r.app_iops, "hit_rate": r.hit_rate,
        "ssd_page_writes": r.ssd_page_writes, "flush_writes": r.flush_writes,
        "demand_writes": r.demand_writes, "ssd_reads": r.ssd_reads,
        "stale_discards": r.stale_discards, "sim_time": r.sim_time,
        "mean": r.mean_latency, "p50": r.p50_latency, "p95": r.p95_latency,
        "p99": r.p99_latency,
    }
    for k, want in GOLDEN_SAFS_UNIFORM.items():
        assert got[k] == want, f"{k}: {got[k]!r} != golden {want!r}"


def test_prefill_cache_is_bit_identical():
    """Construction through the prefill snapshot cache must not perturb any
    result — first build (cache miss), rebuild (cache hit), and an uncached
    build all match the golden."""
    clear_prefill_cache()
    wl = Workload(w_total=96, qd_per_ssd=32, n_streams=3)
    miss = ArraySim(3, P, 0.6, wl, seed=42, prefill_cache=True).run(6000)
    hit = ArraySim(3, P, 0.6, wl, seed=42, prefill_cache=True).run(6000)
    clear_prefill_cache()
    assert miss.iops == hit.iops == GOLDEN_ARRAY_UNIFORM["iops"]
    assert miss.p99_latency == hit.p99_latency == GOLDEN_ARRAY_UNIFORM["p99"]
    np.testing.assert_array_equal(miss.per_ssd_iops, hit.per_ssd_iops)


def test_rerun_same_seed_identical():
    """Two fresh sims with the same seed are byte-identical (no hidden
    global state in the fast path)."""
    kw = dict(ssd=P, occupancy=0.6,
              workload=Workload(dist="zipf", w_total=64, qd_per_ssd=16,
                                n_streams=4))
    a = ArraySim(4, seed=11, **kw).run(4000)
    b = ArraySim(4, seed=11, **kw).run(4000)
    assert a.iops == b.iops
    assert a.p99_latency == b.p99_latency
    np.testing.assert_array_equal(a.per_ssd_iops, b.per_ssd_iops)
    with pytest.raises(AssertionError):
        c = ArraySim(4, seed=12, **kw).run(4000)
        np.testing.assert_array_equal(a.per_ssd_iops, c.per_ssd_iops)

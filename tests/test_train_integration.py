"""Integration: the train driver end-to-end (loss down, ckpt/resume)."""
import numpy as np
import pytest

from repro.launch.train import main as train_main

pytestmark = pytest.mark.slow  # end-to-end train runs: nightly tier


def test_train_loss_decreases(tmp_path):
    losses = train_main([
        "--arch", "tinyllama-1.1b", "--preset", "smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_resume_continues(tmp_path):
    train_main(["--arch", "granite-moe-1b-a400m", "--preset", "smoke",
                "--steps", "8", "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    losses = train_main(["--arch", "granite-moe-1b-a400m", "--preset",
                         "smoke", "--steps", "12", "--batch", "4",
                         "--seq", "32", "--ckpt-dir", str(tmp_path),
                         "--resume"])
    assert len(losses) == 4            # resumed at step 8, ran 8..11


@pytest.mark.parametrize("arch", ["mamba2-780m", "whisper-tiny",
                                  "qwen2-vl-72b"])
def test_train_special_families(arch, tmp_path):
    losses = train_main(["--arch", arch, "--preset", "smoke", "--steps", "6",
                         "--batch", "4", "--seq", "64"])
    assert all(np.isfinite(l) for l in losses)

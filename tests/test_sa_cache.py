"""JAX SA-cache twin: dirty-epoch regressions for the flush-completion
lost-write race (no hypothesis needed — these must run in tier-1).

The race: a flush is issued for (tag, set, slot); while it is in flight a
write re-dirties the slot; the completion then cleared the dirty bit because
the tag still matched, silently dropping the newer version. ``clean_slot``
now also checks the per-slot dirty epoch captured at issue time.
"""
import jax.numpy as jnp

from repro.core import sa_cache
from repro.core.sa_cache import (CacheState, clean_slot, dirty_epoch_of,
                                 insert, lookup, make_cache, mark_dirty)


def test_clean_slot_epoch_mismatch_keeps_dirty():
    cache = make_cache(1, 4)
    _, _, slot, cache = insert(cache, jnp.int32(0), jnp.int32(5),
                               jnp.bool_(True))
    issued = int(dirty_epoch_of(cache, 0, slot))
    # a write re-dirties the slot while the flush is in flight
    cache = mark_dirty(cache, 0, slot, True)
    cache = clean_slot(cache, 0, slot, expect_tag=5, expect_epoch=issued)
    assert bool(cache.dirty[0, slot]), "newer write must not be dropped"
    # a flush completing with the *current* epoch does clean
    cache = clean_slot(cache, 0, slot, expect_tag=5,
                       expect_epoch=int(dirty_epoch_of(cache, 0, slot)))
    assert not bool(cache.dirty[0, slot])


def test_clean_slot_same_tag_reinserted_stays_dirty():
    """Evict + re-insert the SAME tag into the same slot: a flush issued for
    the first incarnation must not clean the second (tag check alone cannot
    see this; insert bumps the epoch)."""
    cache = make_cache(1, 1)                      # one slot: reuse guaranteed
    _, _, slot, cache = insert(cache, jnp.int32(0), jnp.int32(5),
                               jnp.bool_(True))
    issued = int(dirty_epoch_of(cache, 0, slot))
    _, _, _, cache = insert(cache, jnp.int32(0), jnp.int32(9),
                            jnp.bool_(True))      # evicts tag 5
    _, _, _, cache = insert(cache, jnp.int32(0), jnp.int32(5),
                            jnp.bool_(True))      # tag 5 back, new content
    cache = clean_slot(cache, 0, slot, expect_tag=5, expect_epoch=issued)
    assert bool(cache.dirty[0, slot])


def test_clean_slot_without_epoch_matches_legacy_rule():
    cache = make_cache(1, 4)
    _, _, slot, cache = insert(cache, jnp.int32(0), jnp.int32(5),
                               jnp.bool_(True))
    cache = clean_slot(cache, 0, slot, expect_tag=5)   # no epoch given
    assert not bool(cache.dirty[0, slot])


def test_legacy_state_without_epoch_field_still_works():
    """States built before the epoch field (epoch=None) keep functioning:
    lookup/insert/mark_dirty/clean_slot never touch the missing array."""
    ss = 4
    cache = CacheState(
        tags=jnp.full((1, ss), sa_cache.EMPTY, dtype=jnp.int32),
        hits=jnp.zeros((1, ss), dtype=jnp.int32),
        dirty=jnp.zeros((1, ss), dtype=jnp.bool_),
        clock=jnp.zeros((1,), dtype=jnp.int32))
    assert cache.epoch is None
    _, _, slot, cache = insert(cache, jnp.int32(0), jnp.int32(7),
                               jnp.bool_(True))
    assert cache.epoch is None
    hit, s2, cache = lookup(cache, jnp.int32(0), jnp.int32(7))
    assert bool(hit) and int(s2) == int(slot)
    cache = mark_dirty(cache, 0, slot, True)
    cache = clean_slot(cache, 0, slot, expect_tag=7, expect_epoch=3)
    assert not bool(cache.dirty[0, slot])   # epoch check disabled: tag rules


def test_epoch_bumps_on_insert_and_mark_dirty():
    cache = make_cache(2, 4)
    _, _, slot, cache = insert(cache, jnp.int32(1), jnp.int32(3),
                               jnp.bool_(False))
    e0 = int(cache.epoch[1, slot])
    cache = mark_dirty(cache, 1, slot, True)
    cache = mark_dirty(cache, 1, slot, True)    # every write is a new version
    assert int(cache.epoch[1, slot]) == e0 + 2
    cache = mark_dirty(cache, 1, slot, False)   # cleaning is not a version
    assert int(cache.epoch[1, slot]) == e0 + 2

"""Paper §4.2 reproductions: Figures 3-5 and Table 3 — the dirty-page
flusher's effect on SAFS throughput, writeback amplification and hit rate."""
from __future__ import annotations

import numpy as np

from repro.core.gc_sim import ArraySim, Workload
from repro.core.safs_sim import SAFSSim, SAFSWorkload

from .common import PAPER, SSD, save

N_SSDS = 4
OCC = 0.8


def _run(read_frac, dist, use_flusher, *, unaligned=False, concurrency=128,
         measure_ops=12000, occupancy=OCC, seed=0):
    sim = SAFSSim(n_ssds=N_SSDS, ssd=SSD, occupancy=occupancy,
                  workload=SAFSWorkload(read_frac=read_frac, dist=dist,
                                        unaligned=unaligned,
                                        concurrency=concurrency),
                  cache_frac=0.1, use_flusher=use_flusher, seed=seed)
    return sim.run(measure_ops)


def independent_max(measure_ops=20000) -> float:
    """Throughput when every SSD is driven independently (paper's upper
    line in Fig 3): per-SSD submit streams, deep queues."""
    r = ArraySim(N_SSDS, SSD, OCC,
                 Workload(w_total=128 * N_SSDS, qd_per_ssd=128,
                          n_streams=N_SSDS), seed=3).run(measure_ops)
    return float(r.iops)


def fig3(measure_ops=12000) -> dict:
    """Aligned 4K random writes, flusher on/off, uniform + zipf."""
    out = {"independent_max": independent_max()}
    for dist in ("uniform", "zipf"):
        on = _run(0.0, dist, True, measure_ops=measure_ops)
        off = _run(0.0, dist, False, measure_ops=measure_ops)
        out[dist] = {
            "flusher_on": float(on.app_iops), "flusher_off": float(off.app_iops),
            "gain_pct": 100.0 * (on.app_iops / off.app_iops - 1.0),
            "frac_of_independent": float(on.app_iops) / out["independent_max"],
        }
    out["paper_gain_pct"] = PAPER["fig3_gain_pct"]
    save("paper_fig3", out)
    return out


def fig4(measure_ops=60000) -> dict:
    """Unaligned (128 B) writes: every miss is read-update-write.

    Calibrated against the DES at the current service granularity: the
    window must cover several cache fills (cache is ~2.6k pages here and
    every unaligned op dirties its page), because inside the fill transient
    the flusher's eager writes read as pure overhead and the measured "gain"
    is negative — the old 8000-op window sat squarely in that transient.
    At steady state the mechanism matches the paper's: the flusher converts
    application-blocking demand writebacks into background flushes (compare
    ``demand_writes`` on/off), which is where the unaligned gain comes from.
    ``tests/test_paper_figs.py`` pins this qualitative ordering at a scaled-
    down config so it cannot silently drift again."""
    out = {}
    for dist in ("uniform", "zipf"):
        on = _run(0.0, dist, True, unaligned=True, measure_ops=measure_ops)
        off = _run(0.0, dist, False, unaligned=True, measure_ops=measure_ops)
        out[dist] = {
            "flusher_on": float(on.app_iops), "flusher_off": float(off.app_iops),
            "gain_pct": 100.0 * (on.app_iops / off.app_iops - 1.0),
            "demand_writes_on": int(on.demand_writes),
            "demand_writes_off": int(off.demand_writes),
        }
    out["paper_gain_pct"] = PAPER["fig4_gain_pct"]
    save("paper_fig4", out)
    return out


def fig5(measure_ops=12000) -> dict:
    """Mixed read/write (uniform), read fraction sweep."""
    out = {"read_pct": [], "flusher_on": [], "flusher_off": [],
           "gain_pct": []}
    for rf in (0.8, 0.6, 0.4, 0.2, 0.0):
        on = _run(rf, "uniform", True, measure_ops=measure_ops)
        off = _run(rf, "uniform", False, measure_ops=measure_ops)
        out["read_pct"].append(int(rf * 100))
        out["flusher_on"].append(float(on.app_iops))
        out["flusher_off"].append(float(off.app_iops))
        out["gain_pct"].append(100.0 * (on.app_iops / off.app_iops - 1.0))
    out["best_gain_pct"] = max(out["gain_pct"])
    out["paper_best_gain_pct"] = PAPER["fig5_best_gain_pct"]
    save("paper_fig5", out)
    return out


def table3(measure_ops=30000) -> dict:
    """Zipf mixed workloads: extra writeback and cache-hit-rate delta.

    Needs steady state (ops >> cache pages / write_frac): in a short window
    the flusher's eager writes read as 'extra' even though the baseline
    would write the same pages right after the window closes."""
    out = {"read_pct": [], "extra_writeback_pct": [], "hit_increase_pct": []}
    for rf in (0.8, 0.6, 0.4, 0.2, 0.0):
        on = _run(rf, "zipf", True, measure_ops=measure_ops, occupancy=0.6)
        off = _run(rf, "zipf", False, measure_ops=measure_ops, occupancy=0.6)
        extra = 100.0 * (on.ssd_page_writes - off.ssd_page_writes) / \
            max(off.ssd_page_writes, 1)
        out["read_pct"].append(int(rf * 100))
        out["extra_writeback_pct"].append(extra)
        out["hit_increase_pct"].append(
            100.0 * (on.hit_rate - off.hit_rate))
    out["paper_extra_max_pct"] = PAPER["table3_extra_writeback_max_pct"]
    out["paper_hit_increase_pct"] = PAPER["table3_hit_increase_pct"]
    save("paper_table3", out)
    return out


def main():
    f3 = fig3()
    for d in ("uniform", "zipf"):
        print(f"fig3 {d}: +{f3[d]['gain_pct']:.0f}% "
              f"({f3[d]['frac_of_independent'] * 100:.0f}% of independent max;"
              f" paper: +{f3['paper_gain_pct']:.0f}%)")
    f4 = fig4()
    for d in ("uniform", "zipf"):
        print(f"fig4 {d} (unaligned): +{f4[d]['gain_pct']:.0f}% "
              f"(paper: +{f4['paper_gain_pct']:.0f}%)")
    f5 = fig5()
    print(f"fig5 best mixed gain: +{f5['best_gain_pct']:.0f}% at "
          f"{f5['read_pct'][int(np.argmax(f5['gain_pct']))]}% reads "
          f"(paper: +{f5['paper_best_gain_pct']:.0f}% at 40%)")
    t3 = table3()
    print(f"table3 extra writeback: "
          f"{[f'{x:.1f}%' for x in t3['extra_writeback_pct']]} "
          f"(paper max {t3['paper_extra_max_pct']}%), hit delta "
          f"{[f'{x:+.1f}%' for x in t3['hit_increase_pct']]}")


if __name__ == "__main__":
    main()

"""Serving-trace replay: the KV offload tier becomes a measured workload.

The serving fleet (``repro.serving.fleet``) drives a REAL ``PagedKVPool``
through the recording shim (``repro.serving.trace_shim``), emitting a
page-granular ``(time, lba, op, tenant)`` trace of every offload, resume
fetch, and blocking dirty-eviction spill that reached a device. That trace
then replays through the sharded array simulator — 100+ SSDs on the
committed tier — under per-tenant QoS accounting and each GC-coordination
policy. Figure of merit: **effective tokens/s served** (spill write
completions/s x tokens per KV page) vs **p99 spill latency**.

Self-checking acceptance gates (exit nonzero on violation):

* ``emit_digest_identical`` — two same-seed fleet runs emit byte-identical
  trace arrays (``trace_digest``), and the ``.npz`` container round-trips
  the array bit-for-bit.
* ``serial_equals_sharded`` — replaying the trace with ``parallel=False``
  vs ``parallel=True`` on the same shard decomposition is bit-identical
  (iops, p99, per-tenant p99s).
* ``gc_policy_separates`` — the best coordinated policy (staggered or
  idle) beats the reactive per-device trigger on BOTH axes of the figure
  of merit: more tokens/s AND lower p99 spill latency.
* ``coordinated_meets_interactive_slo`` / ``reactive_violates_slo`` — the
  interactive tenant's p99 lands under its SLO only under coordination:
  the QoS story the per-tenant accounting exists to tell.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.serving_replay           # 120 SSDs
    PYTHONPATH=src python -m benchmarks.serving_replay --smoke   # 24 SSDs

Writes ``BENCH_serving_replay.json`` (repo root) and ``experiments/bench/``.
No jax imports anywhere on this path — the perf-smoke CI tier runs it on a
numpy-only environment.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.gc_coord import IdleGc, ReactiveGc, StaggeredGc
from repro.core.gc_sim import SSDParams, Workload
from repro.core.qos import QosPolicy, TenantSpec
from repro.core.sharded import ShardedArraySim
from repro.serving.fleet import FleetConfig, run_fleet
from repro.serving.trace_shim import load_trace, save_trace, trace_digest

from .common import save

ROOT = Path(__file__).resolve().parent.parent

# Replay knobs. The fleet emits ~1 logical second of traffic; the offered
# rate of a few hundred sessions/s is tiny next to a 100+ SSD array, so the
# replay compresses time 100x (trace_time_scale) to put the spill stream
# into the regime where queueing and GC episodes shape the tail. Interactive
# SLO 4 ms: between the coordinated tail (~2 ms) and the reactive tail
# (~6 ms) so the per-tenant accounting shows the policy choice deciding SLO
# compliance, not just shifting a percentile.
TIME_SCALE = 0.01
OCCUPANCY = 0.8
SLO_INTERACTIVE_S = 4e-3
SLO_BATCH_S = 20e-3
SSD = SSDParams(capacity_pages=4096)


def _fleet_config(n_targets: int) -> FleetConfig:
    """Fleet sized to the array: arrivals scale with the device count, the
    HBM pool scales sub-linearly so set pressure (evictions, stale
    discards) survives the scale-out."""
    return FleetConfig(n_targets=n_targets, duration_s=1.0,
                       arrival_rate=33.0 * n_targets,
                       pool_sets=max(n_targets // 2, 8), set_size=8,
                       flush_trigger=1)


def emit_scenario(n_targets: int, seed: int) -> tuple[dict, np.ndarray]:
    """Run the fleet twice at the same seed (gate a), round-trip the .npz
    container, and report the trace mix."""
    cfg = _fleet_config(n_targets)
    t0 = time.perf_counter()
    r1 = run_fleet(cfg, seed=seed)
    r2 = run_fleet(cfg, seed=seed)
    emit_s = time.perf_counter() - t0
    d1, d2 = trace_digest(r1.trace), trace_digest(r2.trace)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "kv.npz")
        save_trace(path, r1.trace, meta=r1.meta)
        loaded, meta = load_trace(path, with_meta=True)
        d_rt = trace_digest(loaded)
    tr = r1.trace
    devices_hit = int(np.unique(tr[:, 1].astype(np.int64) % n_targets).size) \
        if len(tr) else 0
    out = {
        "config": {"n_targets": n_targets, "seed": seed,
                   "arrival_rate": cfg.arrival_rate,
                   "pool_slots": cfg.pool_sets * cfg.set_size,
                   "duration_s": cfg.duration_s},
        "rows": int(len(tr)),
        "reads": int((tr[:, 2] == 0).sum()) if len(tr) else 0,
        "writes": int((tr[:, 2] == 1).sum()) if len(tr) else 0,
        "tokens_total": int(r1.tokens_total),
        "sessions": int(r1.sessions_started),
        "offloads": int(r1.offloads),
        "fetches": int(r1.fetches),
        "stale_discards": int(r1.stale_discards),
        "dirty_evictions": int(r1.dirty_evictions),
        "alloc_failures": int(r1.alloc_failures),
        "devices_hit": devices_hit,
        "digest": d1,
        "digest_identical": d1 == d2,
        "npz_roundtrip_identical": d_rt == d1 and meta == r1.meta,
        "emit_wall_s": emit_s,
    }
    print(f"  emitted {out['rows']} rows ({out['writes']} spills, "
          f"{out['reads']} fetches) from {out['sessions']} sessions, "
          f"{out['stale_discards']} stale discards; "
          f"digest match={out['digest_identical']}")
    return out, tr


def _tenant_rows(res) -> dict:
    return {
        str(t): {"ops": int(s.ops), "p99_ms": 1e3 * s.p99_latency,
                 "mean_ms": 1e3 * s.mean_latency,
                 "slo_p99_ms": None if s.slo_p99 is None else 1e3 * s.slo_p99,
                 "slo_met": (s.slo_p99 is None
                             or s.p99_latency <= s.slo_p99)}
        for t, s in sorted(res.tenant_stats.items())
    }


def replay_scenario(trace: np.ndarray, n_ssds: int, n_shards: int,
                    ops_per_ssd: int, page_tokens: int, seed: int) -> dict:
    """Replay under QoS accounting x three GC policies, plus the serial ==
    sharded bit-identity run on the reactive baseline (gate b)."""
    qos = QosPolicy(tenants=(TenantSpec(0, 2.0, slo_p99=SLO_INTERACTIVE_S),
                             TenantSpec(1, 1.0, slo_p99=SLO_BATCH_S)))
    wl = Workload(scenario="trace", w_total=8 * n_ssds, qd_per_ssd=8,
                  n_streams=n_ssds, trace_time_scale=TIME_SCALE)
    ops = ops_per_ssd * n_ssds
    mk = lambda gc, par: ShardedArraySim(
        n_ssds, SSD, OCCUPANCY, wl, seed=seed, n_shards=n_shards,
        trace=trace, qos=qos, gc=gc, parallel=par)
    policies = {
        "reactive": ReactiveGc(),
        "staggered": StaggeredGc(max_concurrent=1, scope="group",
                                 early_blocks=4),
        "idle": IdleGc(watermark=24),
    }
    out = {"config": {"n_ssds": n_ssds, "n_shards": n_shards,
                      "ops_per_ssd": ops_per_ssd, "seed": seed,
                      "time_scale": TIME_SCALE, "occupancy": OCCUPANCY,
                      "page_tokens": page_tokens}}
    serial = mk(policies["reactive"], False).run(ops)
    for name, gc in policies.items():
        r = mk(gc, True).run(ops)
        row = {
            "iops": float(r.iops),
            "tokens_per_s": float(r.write_iops * page_tokens),
            "p99_spill_ms": 1e3 * r.p99_latency,
            "p95_spill_ms": 1e3 * r.p95_latency,
            "mean_ms": 1e3 * r.mean_latency,
            "gc_starts": int(r.gc_starts),
            "gc_pause_frac": float(np.mean(r.gc_pause_frac)),
            "events": int(r.events),
            "tenants": _tenant_rows(r),
        }
        out[name] = row
        if name == "reactive":
            out["serial_equals_sharded"] = bool(
                serial.iops == r.iops
                and serial.p99_latency == r.p99_latency
                and serial.tenant_stats.keys() == r.tenant_stats.keys()
                and all(serial.tenant_stats[t].p99_latency
                        == r.tenant_stats[t].p99_latency
                        and serial.tenant_stats[t].ops
                        == r.tenant_stats[t].ops
                        for t in r.tenant_stats))
        print(f"  {name:9s} tokens/s {row['tokens_per_s']:13,.0f}  "
              f"p99 spill {row['p99_spill_ms']:6.2f} ms  "
              f"t0 p99 {row['tenants']['0']['p99_ms']:6.2f} ms  "
              f"gc_pause {row['gc_pause_frac']:.3f}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="24-SSD tier (< 1 min), for CI / tests")
    ap.add_argument("--n-ssds", type=int, default=None)
    ap.add_argument("--n-shards", type=int, default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(ROOT / "BENCH_serving_replay.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        n_ssds = args.n_ssds or 24
        n_shards = args.n_shards or 2
    else:
        n_ssds = args.n_ssds or 120          # the 100+ SSD committed tier
        n_shards = args.n_shards or 4
    ops_per_ssd = args.ops_per_ssd or 600

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "n_shards": n_shards,
        "ops_per_ssd": ops_per_ssd,
        "seed": args.seed,
    }
    print(f"fleet emit ({n_ssds} spill targets, same seed twice):")
    result["emit"], trace = emit_scenario(n_ssds, args.seed)
    page_tokens = _fleet_config(n_ssds).page_tokens
    print(f"replay ({n_ssds} SSDs, {n_shards} shards, QoS + GC policies):")
    result["replay"] = replay_scenario(trace, n_ssds, n_shards, ops_per_ssd,
                                       page_tokens, seed=args.seed + 3)
    result["wall_s"] = time.perf_counter() - t0

    em, rp = result["emit"], result["replay"]
    best = max(("staggered", "idle"),
               key=lambda k: rp[k]["tokens_per_s"])
    result["best_coordinated"] = best
    checks = {
        # gate (a): same seed => byte-identical emitted trace, and the
        # container stores exactly those bytes
        "emit_digest_identical": em["digest_identical"],
        "npz_roundtrip_identical": em["npz_roundtrip_identical"],
        # the trace is a real workload, not a degenerate one: background
        # spills AND resume fetches AND queue-head stale discards, spread
        # over every device
        "trace_nontrivial": (em["offloads"] > 0 and em["fetches"] > 0
                             and em["stale_discards"] > 0
                             and em["devices_hit"] == n_ssds),
        # gate (b): serial == sharded bit-identity on the replay
        "serial_equals_sharded": rp["serial_equals_sharded"],
        # gate (c): a coordinated policy beats reactive on BOTH axes of
        # the figure of merit
        "gc_policy_separates": (
            rp[best]["tokens_per_s"] > rp["reactive"]["tokens_per_s"]
            and rp[best]["p99_spill_ms"] < rp["reactive"]["p99_spill_ms"]),
        # the QoS story: coordination is what keeps the interactive tenant
        # inside its SLO
        "coordinated_meets_interactive_slo":
            rp[best]["tenants"]["0"]["slo_met"],
        "reactive_violates_slo":
            not rp["reactive"]["tenants"]["0"]["slo_met"],
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_serving_replay", result)
    print(f"serving replay done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Hillclimb tooling: per-op_name breakdown of collective bytes and FLOPs
from a cell's compiled HLO (loop-aware). The 'profile' of the dry-run world.

  PYTHONPATH=src python -m benchmarks.collective_breakdown --arch olmoe-1b-7b \
      --shape train_4k [--top 15]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict


def breakdown(arch: str, shape: str, mesh_kind: str = "single",
              top: int = 15, remat: bool = True):
    from repro.launch import hlo_cost as H
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with mesh:
        fn, args, cfg, shp = build_cell(arch, shape, mesh, remat=remat)
        text = fn.lower(*args).compile().as_text()
    comps, entry = H.parse_hlo(text)
    mult = defaultdict(float)
    fusion_called = set()

    def visit(name, m):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for ins in comp.instrs:
            if ins.opcode == "while":
                refs = dict(H._ATTR_CALL_RE.findall(ins.attrs))
                trip = H._trip_count(comps, refs.get("condition", ""))
                visit(refs.get("body", ""), m * trip)
                visit(refs.get("condition", ""), m * trip)
            else:
                for kind, ref in H._ATTR_CALL_RE.findall(ins.attrs):
                    if kind in ("calls", "to_apply", "branch_computations"):
                        fusion_called.add(ref)
                        visit(ref, m)

    visit(entry, 1.0)
    coll = defaultdict(float)
    flops = defaultdict(float)
    mem = defaultdict(float)
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if not m:
            continue
        for ins in comp.instrs:
            mm = re.search(r'op_name="([^"]+)"', ins.raw)
            key = mm.group(1) if mm else f"<{ins.name}>"
            key = re.sub(r"\[\d+\]", "", key)[:120]
            base = ins.opcode.replace("-start", "")
            if base in H._COLLECTIVES and not ins.opcode.endswith("-done"):
                coll[(base, key)] += m * ins.out_bytes
            if ins.opcode == "dot":
                flops[key] += m * H._dot_flops(ins, comp)
            if name not in fusion_called and ins.opcode not in H._SKIP_BYTES_OPS:
                mem[key] += m * ins.out_bytes
    print(f"== {arch} x {shape} x {mesh_kind} ==")
    print("-- collectives (per-device bytes) --")
    for (op, key), b in sorted(coll.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{b / 1e9:9.2f} GB  {op:20s} {key}")
    print("-- flops --")
    tot = sum(flops.values())
    for key, f in sorted(flops.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{f:10.3e} ({f / tot * 100:4.1f}%)  {key}")
    print("-- memory-proxy bytes --")
    mtot = sum(mem.values())
    for key, b in sorted(mem.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{b / 1e9:9.2f} GB ({b / mtot * 100:4.1f}%)  {key}")
    return coll, flops, mem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()
    breakdown(args.arch, args.shape, args.mesh, args.top,
              remat=not args.no_remat)


if __name__ == "__main__":
    main()

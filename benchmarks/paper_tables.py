"""Paper §4.1 reproductions: Table 1, Table 2, Figure 2.

Raw-device experiments (no SAFS layer): the GC-afflicted array itself.
"""
from __future__ import annotations

import numpy as np

from repro.core.gc_sim import ArraySim, Workload, fresh_ssd_write_iops, \
    single_ssd_write_iops

from .common import PAPER, SSD, save


def table1(measure_ops: int = 25000) -> dict:
    """4KB random-write IOPS of one SSD vs occupancy, GC active."""
    out = {"fresh": fresh_ssd_write_iops(SSD, measure_ops)}
    for occ in (0.4, 0.6, 0.8):
        out[f"{occ}"] = single_ssd_write_iops(occ, params=SSD,
                                              measure_ops=measure_ops)
    out["paper"] = PAPER["table1_iops"]
    save("paper_table1", out)
    return out


def table2(measure_ops: int = 30000) -> dict:
    """Per-SSD IOPS in arrays of 1/2/4/6 SSDs at fixed qd (scaled from the
    paper's 1/6/12/18): more SSDs + one bounded submit stream -> head-of-line
    blocking on GC-paused members drags everyone down."""
    out = {}
    for n in (1, 2, 4, 6):
        r = ArraySim(n, SSD, 0.6,
                     Workload(w_total=128 * n, qd_per_ssd=128, n_streams=1),
                     seed=0).run(measure_ops)
        out[f"{n}"] = float(r.iops / n)
    out["paper_per_ssd"] = PAPER["table2_per_ssd"]
    save("paper_table2", out)
    return out


def fig2(measure_ops: int = 30000, n_ssds: int = 6) -> dict:
    """Array throughput vs number of parallel writes, uniform vs Zipf.

    Paper sweep starts at an already-provisioned array (64/SSD) and rises to
    deep parallelism; the +28% is saturation headroom, and Zipf saturates at
    lower parallelism than uniform (write-buffer coalescing on hot LBAs)."""
    out = {}
    sweep = [64 * n_ssds, 128 * n_ssds, 256 * n_ssds, 512 * n_ssds,
             1024 * n_ssds]
    for dist in ("uniform", "zipf"):
        xs, ys = [], []
        for w in sweep:
            r = ArraySim(n_ssds, SSD, 0.6,
                         Workload(dist=dist, w_total=w,
                                  qd_per_ssd=max(w // n_ssds, 16),
                                  n_streams=max(1, w // 64)),
                         seed=1, prefill_cache=True).run(measure_ops)
            xs.append(w)
            ys.append(float(r.iops))
        sat = max(ys)
        # default = deepest sweep point: with a short sweep no point may
        # clear 95% of saturation (StopIteration otherwise)
        need95 = next((x for x, y in zip(xs, ys) if y >= 0.95 * sat), xs[-1])
        out[dist] = {"parallel_writes": xs, "iops": ys,
                     "gain_pct": 100.0 * (sat / ys[0] - 1.0),
                     "writes_for_95pct": need95}
    out["paper_gain_pct"] = PAPER["fig2_gain_pct"]
    save("paper_fig2", out)
    return out


def qd_sweep(measure_ops: int = 30000, n_ssds: int = 18) -> dict:
    """Queue depth as a real experimental variable (the paper's central
    lever): per-SSD queue depth sweep on the 18-SSD array under active GC.
    With the multi-slot NCQ service model throughput rises monotonically with
    depth — shallow queues cannot overlap service on the 32 channels, and
    deep queues additionally buffer through unsynchronized GC pauses (visible
    in the p99 latency, not the median)."""
    out = {"qd": [], "iops": [], "p50_ms": [], "p95_ms": [], "p99_ms": [],
           "gc_pause_frac": [], "events": 0, "run_wall_s": 0.0}
    for qd in (1, 4, 32, 128):
        r = ArraySim(n_ssds, SSD, 0.6,
                     Workload(w_total=n_ssds * qd, qd_per_ssd=qd,
                              n_streams=n_ssds),
                     seed=0, prefill_cache=True).run(measure_ops)
        out["qd"].append(qd)
        out["iops"].append(float(r.iops))
        out["p50_ms"].append(1e3 * r.p50_latency)
        out["p95_ms"].append(1e3 * r.p95_latency)
        out["p99_ms"].append(1e3 * r.p99_latency)
        out["gc_pause_frac"].append(float(np.mean(r.gc_pause_frac)))
        out["events"] += r.events
        out["run_wall_s"] += r.wall_s
    out["monotone"] = bool(np.all(np.diff(out["iops"]) > 0))
    out["events_per_sec"] = out["events"] / max(out["run_wall_s"], 1e-9)
    save("paper_qd_sweep", out)
    return out


def main():
    t1 = table1()
    print("table1 (IOPS vs occupancy):",
          {k: round(v) for k, v in t1.items() if k != "paper"})
    t2 = table2()
    print("table2 (per-SSD IOPS vs array size):",
          {k: round(v) for k, v in t2.items() if k != "paper_per_ssd"})
    f2 = fig2()
    for d in ("uniform", "zipf"):
        print(f"fig2 {d}: gain {f2[d]['gain_pct']:.0f}% "
              f"(paper: up to {f2['paper_gain_pct']:.0f}%), 95% of peak at "
              f"{f2[d]['writes_for_95pct']} writes")
    qs = qd_sweep()
    print("qd sweep (18 SSDs, GC active): " +
          ", ".join(f"qd={q}: {i:,.0f} IOPS (p99 {p:.1f} ms)"
                    for q, i, p in zip(qs["qd"], qs["iops"], qs["p99_ms"])) +
          f"  monotone={qs['monotone']}"
          f"  ({qs['events_per_sec']:,.0f} events/s)")


if __name__ == "__main__":
    main()

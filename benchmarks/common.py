"""Shared benchmark scaffolding.

The DES reproduces the paper's experiments on SCALED-DOWN drives (8192-page
FTLs instead of 128 GB) so every table finishes in CPU-minutes; IOPS numbers
are therefore compared to the paper as RATIOS/trends, with the fresh-drive
write rate calibrated to the paper's 60 928 IOPS "maximal" cell.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.gc_sim import SSDParams

OUT = Path("experiments/bench")

# scaled-down drive used by every benchmark (calibrated: fresh ~= 60928 IOPS)
SSD = SSDParams(capacity_pages=8192)

PAPER = {
    "table1_iops": {"fresh": 60928, "0.4": 42240, "0.6": 38656, "0.8": 32512},
    "table2_per_ssd": {"1": 38656, "6": 37888, "12": 33280, "18": 31744},
    "fig2_gain_pct": 28.0,
    "fig3_gain_pct": 24.0,
    "fig4_gain_pct": 39.0,
    "fig5_best_gain_pct": 62.0,
    "table3_extra_writeback_max_pct": 3.2,
    "table3_hit_increase_pct": {"0.8": 0.7, "0.6": 0.6, "0.4": 1.0,
                                "0.2": 1.4, "0.0": 4.0},
}


def save(name: str, payload: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                 default=float))


def row(name: str, value, paper=None, note: str = "") -> str:
    p = "" if paper is None else f",{paper}"
    return f"{name},{value}{p}{',' + note if note else ''}"

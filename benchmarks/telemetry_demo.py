"""Telemetry demo + self-check: the observability layer on a GC rotation.

Four scenarios, each with self-checking acceptance booleans:

* ``rotation`` — write-heavy JBOD at GC-heavy occupancy, reactive vs
  ``StaggeredGc(max_concurrent=1)``: the per-tick ``gc_active`` series shows
  every device collecting AT ONCE under reactive (synchronized dips — the
  paper's pathology) at least once per seed, while the staggered lease never
  lets all devices collect together.
* ``budget`` — per-op spans on: the latency budget's additive components
  (park/queue/gc/service/sync) sum to the measured mean latency within
  float tolerance, for both policies; printed side by side, GC-wait shift
  included.
* ``identity`` — telemetry attached (full probes + spans) must reproduce
  the pinned PR 2 golden byte-for-byte AND match a ``telemetry=None`` run:
  sampling piggybacks on the event stream, so telemetry-on is a pure
  observer.
* ``overhead`` — normalized events/sec with full series probes on must stay
  within 10% of the untelemetered run (best-of-3 each; the spans overhead
  is also reported, unGated).

Also writes a Chrome trace (``BENCH_telemetry_trace.json``, repo root) of
one staggered-GC run — open at https://ui.perfetto.dev ("Open trace file").

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.telemetry_demo           # full
    PYTHONPATH=src python -m benchmarks.telemetry_demo --smoke   # CI

Writes ``BENCH_telemetry.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.gc_coord import ReactiveGc, StaggeredGc
from repro.core.gc_sim import ArraySim, SSDParams, Workload
from repro.core.telemetry import TelemetrySpec

from .common import save

ROOT = Path(__file__).resolve().parent.parent

# the PR 2 golden (tests/test_golden_determinism.py::GOLDEN_ARRAY_UNIFORM):
# 3 SSDs, capacity 4096, occupancy 0.6, w_total=96/qd=32/3 streams, seed 42,
# run(6000). The identity scenario reproduces it with telemetry attached.
SSD = SSDParams(capacity_pages=4096)
GOLDEN_IOPS = 79653.14748115413
GOLDEN_P99 = 0.005141150210084031

SERIES = TelemetrySpec(series_dt=5e-5)                 # fine ticks (rotation)
FULL = TelemetrySpec(series_dt=5e-5, spans=True)       # fine ticks + spans
OVERHEAD = TelemetrySpec()                             # every probe on, the
                                                       # default 1 ms tick
OVERHEAD_SPANS = TelemetrySpec(spans=True)


def _wl(n_ssds):
    return Workload(w_total=32 * n_ssds, qd_per_ssd=32, n_streams=n_ssds)


def rotation_scenario(n_ssds, occupancy, ops, seeds):
    """Reactive vs staggered on the gc_active tick series: synchronized
    all-device episodes vs a rotating single lease."""
    out = {"config": {"n_ssds": n_ssds, "occupancy": occupancy, "ops": ops,
                      "seeds": list(seeds), "series_dt": SERIES.series_dt}}
    for name, gc in (("reactive", ReactiveGc()),
                     ("staggered", StaggeredGc(max_concurrent=1))):
        rows = []
        for seed in seeds:
            sim = ArraySim(n_ssds, SSD, occupancy, _wl(n_ssds), seed=seed,
                           gc=gc, telemetry=SERIES)
            r = sim.run(ops)
            t = r.telemetry
            rows.append({
                "seed": seed,
                "ticks": int(t.ticks.size),
                "gc_any_ticks": int(t.gc_active_any().sum()),
                "gc_all_ticks": int(t.gc_active_all().sum()),
                "gc_episodes": len(t.gc_episodes),
                "util_min": float(r.util_min),
                "p99_ms": 1e3 * r.p99_latency,
            })
        out[name] = rows
        m = lambda k: float(np.mean([row[k] for row in rows]))
        print(f"  {name:10s} all-devices-GC ticks {m('gc_all_ticks'):7.1f}  "
              f"any-GC ticks {m('gc_any_ticks'):7.1f}  "
              f"episodes {m('gc_episodes'):6.1f}  "
              f"util_min {m('util_min'):.3f}")
    return out


def budget_scenario(n_ssds, occupancy, ops, seed):
    """Span tracing on: decompose mean latency into additive wait
    components under both GC policies."""
    out = {"config": {"n_ssds": n_ssds, "occupancy": occupancy, "ops": ops,
                      "seed": seed}}
    for name, gc in (("reactive", ReactiveGc()),
                     ("staggered", StaggeredGc(max_concurrent=1))):
        sim = ArraySim(n_ssds, SSD, occupancy, _wl(n_ssds), seed=seed,
                       gc=gc, telemetry=FULL)
        r = sim.run(ops)
        bud = r.telemetry.budget
        comp_sum = sum(bud["mean"].values())
        out[name] = {
            "mean_latency_us": 1e6 * r.mean_latency,
            "budget_mean_latency_us": 1e6 * bud["mean_latency"],
            "component_means_us": {k: 1e6 * v
                                   for k, v in bud["mean"].items()},
            "component_sum_us": 1e6 * comp_sum,
            "sums_to_mean": bool(
                abs(comp_sum - bud["mean_latency"])
                <= 1e-9 * max(bud["mean_latency"], 1e-30)),
            "budget_matches_measured_mean": bool(
                abs(bud["mean_latency"] - r.mean_latency)
                <= 1e-9 * max(r.mean_latency, 1e-30)),
            "p99_latency_us": 1e6 * r.p99_latency,
            "tail_gc_mean_us": 1e6 * bud["tail_p99"]["mean"]["gc"]
            if bud["tail_p99"] else 0.0,
        }
        comps = out[name]["component_means_us"]
        print(f"  {name:10s} mean {out[name]['mean_latency_us']:7.1f} us = "
              + " + ".join(f"{k} {v:6.1f}" for k, v in comps.items()))
    return out


def identity_scenario():
    """Telemetry-on must be a pure observer: byte-identical to the pinned
    golden and to the telemetry=None run."""
    wl = Workload(w_total=96, qd_per_ssd=32, n_streams=3)
    off = ArraySim(3, SSD, 0.6, wl, seed=42).run(6000)
    on = ArraySim(3, SSD, 0.6, wl, seed=42, telemetry=FULL).run(6000)
    t = on.telemetry
    out = {
        "iops_off": off.iops,
        "iops_on": on.iops,
        "golden_iops": GOLDEN_IOPS,
        "p99_on": on.p99_latency,
        "golden_p99": GOLDEN_P99,
        "events_off": off.events,
        "events_on": on.events,
        "ticks": int(t.ticks.size),
        "spans": len(t.spans),
        "matches_golden": bool(on.iops == GOLDEN_IOPS
                               and on.p99_latency == GOLDEN_P99),
        "matches_off": bool(on.iops == off.iops
                            and on.events == off.events
                            and on.p99_latency == off.p99_latency),
    }
    print(f"  telemetry-on iops {on.iops:,.2f} (golden {GOLDEN_IOPS:,.2f}) "
          f"events {on.events} (off: {off.events})  "
          f"{'OK' if out['matches_golden'] and out['matches_off'] else 'FAIL'}")
    return out


def _best_rate(telemetry, ops, repeats):
    """Best-of-N normalized events/sec for one telemetry config (best-of
    filters scheduler noise; every run is the same deterministic event
    stream, so events/sec is directly comparable)."""
    best = 0.0
    events = 0
    for _ in range(repeats):
        wl = Workload(w_total=96, qd_per_ssd=32, n_streams=3)
        r = ArraySim(3, SSD, 0.6, wl, seed=42, telemetry=telemetry).run(ops)
        best = max(best, r.events / r.wall_s)
        events = r.events
    return best, events


def overhead_scenario(ops, repeats):
    """<10% normalized events/sec overhead with the full probe set on at
    the default tick rate (gated); spans overhead reported for
    information."""
    rate_off, ev_off = _best_rate(None, ops, repeats)
    rate_series, ev_series = _best_rate(OVERHEAD, ops, repeats)
    rate_spans, _ = _best_rate(OVERHEAD_SPANS, ops, repeats)
    out = {
        "ops": ops,
        "repeats": repeats,
        "series_dt": OVERHEAD.series_dt,
        "events": ev_off,
        "events_match": bool(ev_off == ev_series),
        "events_per_s_off": rate_off,
        "events_per_s_series": rate_series,
        "events_per_s_spans": rate_spans,
        "series_overhead_frac": rate_off / rate_series - 1.0,
        "spans_overhead_frac": rate_off / rate_spans - 1.0,
    }
    print(f"  events/s: off {rate_off:,.0f}  series {rate_series:,.0f} "
          f"({100 * out['series_overhead_frac']:+.1f}%)  "
          f"spans {rate_spans:,.0f} "
          f"({100 * out['spans_overhead_frac']:+.1f}%)")
    return out


def write_trace(n_ssds, occupancy, ops, seed, path):
    """Chrome trace of one staggered-GC run (spans + GC episodes +
    counters) for Perfetto."""
    sim = ArraySim(n_ssds, SSD, occupancy, _wl(n_ssds), seed=seed,
                   gc=StaggeredGc(max_concurrent=1), telemetry=FULL)
    r = sim.run(ops)
    n_events = r.telemetry.export_trace(path)
    print(f"  wrote {n_events} trace events -> {path}")
    return {"path": str(path), "trace_events": n_events,
            "gc_episodes": len(r.telemetry.gc_episodes)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config for CI (fewer ops/seeds)")
    ap.add_argument("--ops", type=int, default=None)
    ap.add_argument("--seeds", type=int, nargs="+", default=None)
    ap.add_argument("--out", default=str(ROOT / "BENCH_telemetry.json"))
    ap.add_argument("--trace-out",
                    default=str(ROOT / "BENCH_telemetry_trace.json"))
    args = ap.parse_args(argv)

    n_ssds, occupancy = 3, 0.7
    ops = args.ops or (6000 if args.smoke else 18000)
    seeds = tuple(args.seeds) if args.seeds else \
        ((0, 1) if args.smoke else (0, 1, 2))

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_ssds": n_ssds,
        "occupancy": occupancy,
        "ops": ops,
        "seeds": list(seeds),
    }
    print(f"GC rotation visibility ({n_ssds} SSDs JBOD, occupancy "
          f"{occupancy}, write-heavy):")
    result["rotation"] = rotation_scenario(n_ssds, occupancy, ops, seeds)
    print("latency budget (spans on):")
    result["budget"] = budget_scenario(n_ssds, occupancy, ops, seeds[0])
    print("telemetry identity vs golden:")
    result["identity"] = identity_scenario()
    # fixed size even under --smoke: the 10% gate needs runs long enough
    # that best-of-3 filters scheduler noise
    print("probe overhead (best of 3):")
    result["overhead"] = overhead_scenario(12000, 3)
    print("perfetto trace:")
    result["trace"] = write_trace(n_ssds, occupancy, min(ops, 6000),
                                  seeds[0], args.trace_out)
    result["wall_s"] = time.perf_counter() - t0

    rot = result["rotation"]
    bud = result["budget"]
    checks = {
        # the observability claim: the gc_active timeline makes the paper's
        # pathology VISIBLE — every device collecting at once under the
        # reactive trigger, never under the staggered lease
        "reactive_shows_all_devices_gc":
            all(row["gc_all_ticks"] > 0 for row in rot["reactive"]),
        "staggered_never_all_devices_gc":
            all(row["gc_all_ticks"] == 0 for row in rot["staggered"]),
        # additive budget: components sum to the measured mean latency
        "budget_components_sum_to_mean":
            all(bud[k]["sums_to_mean"]
                and bud[k]["budget_matches_measured_mean"]
                for k in ("reactive", "staggered")),
        # pure-observer invariant on the pinned golden
        "telemetry_identity":
            result["identity"]["matches_golden"]
            and result["identity"]["matches_off"],
        # the probes ride the existing event stream: same event count,
        # <10% normalized events/sec cost
        "overhead_under_10pct":
            result["overhead"]["events_match"]
            and result["overhead"]["series_overhead_frac"] < 0.10,
    }
    result["checks"] = checks
    ok = all(checks.values())
    result["all_checks_pass"] = ok

    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_telemetry", result)
    print(f"telemetry demo done in {result['wall_s']:.1f}s; checks: "
          + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                      for k, v in checks.items()))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

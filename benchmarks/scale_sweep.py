"""Production-scale array sweeps via ``ShardedArraySim`` (100+ SSDs).

The ROADMAP's scale-sweep item: run the paper's queue-depth dynamic at array
sizes far beyond the paper's 18 SSDs and record how the qd lever behaves as
the array grows. Per-device state is independent, so the array shards across
worker processes; the host window W and measurement budget are split
proportionally per shard (see ``core/sharded.py`` for the modeling note).

For each array size the sweep reports per-SSD IOPS, tail latency, GC pause
fraction, and aggregate simulation events/sec, and asserts the paper's
monotone qd->throughput trend still holds at scale.

Usage (relative imports — run as a module):
    PYTHONPATH=src python -m benchmarks.scale_sweep            # 18..128 SSDs
    PYTHONPATH=src python -m benchmarks.scale_sweep --smoke    # 8/16 SSDs, CI
    PYTHONPATH=src python -m benchmarks.scale_sweep --sizes 64 256 --qds 4 32 128

Writes ``BENCH_scale.json`` (repo root) and ``experiments/bench/``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.gc_sim import Workload
from repro.core.sharded import ShardedArraySim

from .common import SSD, save

ROOT = Path(__file__).resolve().parent.parent


def sweep_size(n_ssds: int, qds, ops_per_ssd: int,
               n_shards: int | None = None) -> dict:
    """Queue-depth sweep at one array size. The measurement budget scales
    with the array (ops_per_ssd per device) so per-SSD statistics keep a
    comparable sample count at every size."""
    measure_ops = ops_per_ssd * n_ssds
    out = {"n_ssds": n_ssds, "measure_ops": measure_ops, "qd": [],
           "iops": [], "per_ssd_iops": [], "p50_ms": [], "p95_ms": [],
           "p99_ms": [], "gc_pause_frac": [], "events": [], "wall_s": []}
    for qd in qds:
        sim = ShardedArraySim(
            n_ssds, SSD, 0.6,
            Workload(w_total=n_ssds * qd, qd_per_ssd=qd, n_streams=n_ssds),
            seed=0, n_shards=n_shards)
        r = sim.run(measure_ops)
        out["qd"].append(qd)
        out["iops"].append(float(r.iops))
        out["per_ssd_iops"].append(float(r.iops / n_ssds))
        out["p50_ms"].append(1e3 * r.p50_latency)
        out["p95_ms"].append(1e3 * r.p95_latency)
        out["p99_ms"].append(1e3 * r.p99_latency)
        out["gc_pause_frac"].append(float(np.mean(r.gc_pause_frac)))
        out["events"].append(int(r.events))
        out["wall_s"].append(sim.last_wall_s)
        print(f"  n={n_ssds} qd={qd}: {r.iops:,.0f} IOPS "
              f"({r.iops / n_ssds:,.0f}/SSD), p99 {1e3 * r.p99_latency:.2f} ms, "
              f"{r.events / sim.last_wall_s:,.0f} ev/s, {sim.last_wall_s:.1f}s")
    out["monotone"] = bool(np.all(np.diff(out["iops"]) > 0))
    out["events_per_sec"] = float(sum(out["events"]) / max(sum(out["wall_s"]),
                                                           1e-9))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (< 1 min), for CI / tests")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    ap.add_argument("--qds", type=int, nargs="+", default=None)
    ap.add_argument("--ops-per-ssd", type=int, default=None)
    ap.add_argument("--shards", type=int, default=None,
                    help="worker shard count (default: pinned per tier, NOT "
                         "cpu_count — results are deterministic only for a "
                         "fixed (seed, n_shards), so the monotone gate and "
                         "BENCH_scale.json must not depend on the host)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_scale.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        sizes = args.sizes or [8, 16]
        qds = args.qds or (4, 32)
        ops = args.ops_per_ssd or 800
        n_shards = args.shards or 2
    else:
        sizes = args.sizes or [18, 36, 64, 128]
        qds = args.qds or (1, 4, 32, 128)
        ops = args.ops_per_ssd or 1200
        n_shards = args.shards or 4

    t0 = time.perf_counter()
    result = {
        "smoke": args.smoke,
        "cpu_count": os.cpu_count(),
        "n_shards": n_shards,
        "qds": list(qds),
        "ops_per_ssd": ops,
        "sizes": {},
    }
    for n in sizes:
        print(f"n_ssds={n}:")
        result["sizes"][str(n)] = sweep_size(n, qds, ops, n_shards=n_shards)
    result["wall_s"] = time.perf_counter() - t0

    all_monotone = all(s["monotone"] for s in result["sizes"].values())
    result["all_monotone"] = all_monotone
    Path(args.out).write_text(json.dumps(result, indent=1, default=float))
    save("BENCH_scale", result)
    biggest = result["sizes"][str(sizes[-1])]
    print(f"scale sweep done in {result['wall_s']:.1f}s; "
          f"qd-monotone at every size: {all_monotone}; "
          f"largest array {sizes[-1]} SSDs @ "
          f"{biggest['events_per_sec']:,.0f} ev/s")
    return 0 if all_monotone else 1


if __name__ == "__main__":
    sys.exit(main())

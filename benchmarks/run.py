"""Benchmark aggregator: one section per paper table/figure + the
beyond-paper serving benchmark + the roofline table (if dry-run artifacts
exist).

Every registered section runs even if an earlier one fails its self-check or
raises — a single broken sweep must not mask the rest (the same failure mode
the CI pipeline fixed by dropping ``-x`` from the nightly). The exit code is
nonzero iff any section failed, and a summary table names the failures.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import time
import traceback


def _run_section(results: list, title: str, fn, *fn_args) -> None:
    """Run one section, capturing its exit code (a raised exception counts
    as rc=1 and is printed, not propagated)."""
    print("=" * 72)
    print(title)
    print("=" * 72)
    t0 = time.time()
    try:
        rc = fn(*fn_args) or 0
    except Exception:
        traceback.print_exc()
        rc = 1
    results.append((title, rc, time.time() - t0))
    print()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller op counts (CI)")
    args = ap.parse_args(argv)
    tier = ["--smoke"] if args.fast else []

    # perf + scale + raid first, before anything imports jax: the sharded
    # sims' worker pool can then use the fast 'fork' start method (forking
    # after the multithreaded JAX runtime initializes risks worker deadlock,
    # and the fallback 'spawn' pool is slower to start)
    from . import gc_coord_sweep, perf_bench, qos_sweep, raid_sweep, \
        safs_scale_sweep, scale_sweep

    t0 = time.time()
    results: list[tuple[str, int, float]] = []
    _run_section(results,
                 "SSEngine perf -- events/sec (calendar-queue engine)",
                 perf_bench.main, tier)
    _run_section(results,
                 "SSArray scale -- sharded 100+ SSD qd sweep",
                 scale_sweep.main, tier)
    _run_section(results,
                 "SSSAFS scale -- sharded SAFS pattern sweep @ 18/64/128 SSDs",
                 safs_scale_sweep.main, tier)
    _run_section(results,
                 "SSArray layouts -- JBOD vs RAID-0 vs RAID-5 under active GC",
                 raid_sweep.main, tier)
    _run_section(results,
                 "SSPer-tenant QoS -- weighted shares + SLO protection under GC",
                 qos_sweep.main, tier)
    _run_section(results,
                 "SSGC coordination -- staggered/idle policies vs reactive trigger",
                 gc_coord_sweep.main, tier)

    from . import paper_figs, paper_tables, roofline, serving_bench
    _run_section(results,
                 "SSPaper -- Table 1 / Table 2 / Figure 2 (raw array under GC)",
                 paper_tables.main)
    _run_section(results,
                 "SSPaper -- Figures 3-5, Table 3 (SAFS + dirty-page flusher)",
                 paper_figs.main)
    _run_section(results,
                 "SSBeyond-paper -- flusher in the paged-KV serving engine",
                 serving_bench.main)
    _run_section(results,
                 "SSRoofline -- per (arch x shape), single-pod 16x16 (from dry-run)",
                 roofline.main)

    print("=" * 72)
    print("summary")
    print("=" * 72)
    for title, rc, dt in results:
        status = "ok" if rc == 0 else f"FAIL (rc={rc})"
        print(f"  {status:12s} {dt:6.0f}s  {title}")
    n_failed = sum(1 for _, rc, _ in results if rc)
    print(f"\n{len(results) - n_failed}/{len(results)} sections passed; "
          f"total benchmark wall time: {time.time() - t0:.0f}s")
    return 1 if n_failed else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())

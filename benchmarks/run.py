"""Benchmark aggregator: one section per paper table/figure + the
beyond-paper serving benchmark + the roofline table (if dry-run artifacts
exist).

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller op counts (CI)")
    args = ap.parse_args(argv)

    # perf + scale + raid first, before anything imports jax: ShardedArraySim's
    # worker pool can then use the fast 'fork' start method (forking after
    # the multithreaded JAX runtime initializes risks worker deadlock, and
    # the fallback 'spawn' pool is slower to start)
    from . import gc_coord_sweep, perf_bench, qos_sweep, raid_sweep, \
        scale_sweep

    t0 = time.time()
    print("=" * 72)
    print("SSEngine perf -- events/sec + sharded 100+ SSD scale sweep")
    print("=" * 72)
    rc = perf_bench.main(["--smoke"] if args.fast else [])
    rc |= scale_sweep.main(["--smoke"] if args.fast else [])
    print()
    print("=" * 72)
    print("SSArray layouts -- JBOD vs RAID-0 vs RAID-5 under active GC")
    print("=" * 72)
    rc |= raid_sweep.main(["--smoke"] if args.fast else [])
    print()
    print("=" * 72)
    print("SSPer-tenant QoS -- weighted shares + SLO protection under GC")
    print("=" * 72)
    rc |= qos_sweep.main(["--smoke"] if args.fast else [])
    print()
    print("=" * 72)
    print("SSGC coordination -- staggered/idle policies vs reactive trigger")
    print("=" * 72)
    rc |= gc_coord_sweep.main(["--smoke"] if args.fast else [])
    print()

    from . import paper_figs, paper_tables, roofline, serving_bench
    print("=" * 72)
    print("SSPaper -- Table 1 / Table 2 / Figure 2 (raw array under GC)")
    print("=" * 72)
    paper_tables.main()
    print()
    print("=" * 72)
    print("SSPaper -- Figures 3-5, Table 3 (SAFS + dirty-page flusher)")
    print("=" * 72)
    paper_figs.main()
    print()
    print("=" * 72)
    print("SSBeyond-paper -- flusher in the paged-KV serving engine")
    print("=" * 72)
    serving_bench.main()
    print()
    print("=" * 72)
    print("SSRoofline -- per (arch x shape), single-pod 16x16 (from dry-run)")
    print("=" * 72)
    roofline.main()
    print(f"\ntotal benchmark wall time: {time.time() - t0:.0f}s")
    return rc


if __name__ == "__main__":
    import sys
    sys.exit(main())

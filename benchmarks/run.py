"""Benchmark aggregator: one section per paper table/figure + the
beyond-paper serving benchmark + the roofline table (if dry-run artifacts
exist).

Every registered section runs even if an earlier one fails its self-check or
raises — a single broken sweep must not mask the rest (the same failure mode
the CI pipeline fixed by dropping ``-x`` from the nightly). The exit code is
nonzero iff any section failed, and a summary table names the failures.

  PYTHONPATH=src python -m benchmarks.run             # everything
  PYTHONPATH=src python -m benchmarks.run --list      # section keys
  PYTHONPATH=src python -m benchmarks.run --only faults --fast
  PYTHONPATH=src python -m benchmarks.run --json results.json

``--only <key>`` runs a single registered section — CI smoke steps invoke
sections through it instead of duplicating per-benchmark subprocess
incantations in ci.yml. ``--json <path>`` writes a machine-readable summary
(per-section key/status/wall time + overall exit code) alongside the human
table, so CI consumes results without log-scraping.
"""
from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback
from pathlib import Path

# (key, module, title, takes the --smoke tier args?) — in run order. The
# non-tier sections import jax; they are registered LAST so the sharded
# sims' worker pools (first sections) can still use the fast 'fork'
# start method (forking after the multithreaded JAX runtime initializes
# risks worker deadlock, and the fallback 'spawn' pool is slower to start).
_SECTIONS: list[tuple[str, str, str, bool]] = [
    ("perf", "perf_bench",
     "Engine perf -- events/sec (calendar-queue engine)", True),
    ("scale", "scale_sweep",
     "Array scale -- sharded 100+ SSD qd sweep", True),
    ("safs_scale", "safs_scale_sweep",
     "SAFS scale -- sharded SAFS pattern sweep @ 18/64/128 SSDs", True),
    ("raid", "raid_sweep",
     "Array layouts -- JBOD vs RAID-0 vs RAID-5 under active GC", True),
    ("qos", "qos_sweep",
     "Per-tenant QoS -- weighted shares + SLO protection under GC", True),
    ("gc_coord", "gc_coord_sweep",
     "GC coordination -- staggered/idle policies vs reactive trigger", True),
    ("faults", "faults_sweep",
     "Faults -- fail-slow/crash injection vs hedging + quarantine", True),
    ("telemetry", "telemetry_demo",
     "Telemetry -- GC rotation timeline, latency budget, overhead gate",
     True),
    ("monitor", "monitor_demo",
     "Monitor -- online alert rules, root causes, alert-vs-quarantine race",
     True),
    ("serving_replay", "serving_replay",
     "Serving replay -- KV-spill trace emit -> sharded replay under QoS+GC",
     True),
    ("paper_tables", "paper_tables",
     "Paper -- Table 1 / Table 2 / Figure 2 (raw array under GC)", False),
    ("paper_figs", "paper_figs",
     "Paper -- Figures 3-5, Table 3 (SAFS + dirty-page flusher)", False),
    ("serving", "serving_bench",
     "Beyond-paper -- flusher in the paged-KV serving engine", False),
    ("roofline", "roofline",
     "Roofline -- per (arch x shape), single-pod 16x16 (from dry-run)",
     False),
]


def _run_section(results: list, key: str, title: str, fn, *fn_args) -> None:
    """Run one section, capturing its exit code (a raised exception counts
    as rc=1 and is printed, not propagated)."""
    print("=" * 72)
    print(title)
    print("=" * 72)
    t0 = time.time()
    try:
        rc = fn(*fn_args) or 0
    except Exception:
        traceback.print_exc()
        rc = 1
    results.append((key, title, rc, time.time() - t0))
    print()


def main(argv=None):
    keys = [k for k, _, _, _ in _SECTIONS]
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller op counts (CI)")
    ap.add_argument("--only", choices=keys, metavar="SECTION",
                    help=f"run a single section: {', '.join(keys)}")
    ap.add_argument("--list", action="store_true",
                    help="list registered section keys and exit")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable summary (per-section "
                         "status, wall time, exit code) to PATH")
    args = ap.parse_args(argv)
    if args.list:
        for key, _, title, _ in _SECTIONS:
            print(f"{key:14s} {title}")
        return 0
    tier = ["--smoke"] if args.fast else []
    sections = [s for s in _SECTIONS if args.only is None or s[0] == args.only]

    t0 = time.time()
    results: list[tuple[str, str, int, float]] = []
    for key, mod, title, takes_tier in sections:
        # lazy per-section import: --only never pays for (or breaks on) the
        # other sections' imports, and jax-importing sections stay unimported
        # until every fork-pool section has run
        module = importlib.import_module(f".{mod}", __package__)
        if takes_tier:
            _run_section(results, key, title, module.main, tier)
        else:
            _run_section(results, key, title, module.main)

    print("=" * 72)
    print("summary")
    print("=" * 72)
    for _key, title, rc, dt in results:
        status = "ok" if rc == 0 else f"FAIL (rc={rc})"
        print(f"  {status:12s} {dt:6.0f}s  {title}")
    n_failed = sum(1 for _, _, rc, _ in results if rc)
    total_wall_s = time.time() - t0
    print(f"\n{len(results) - n_failed}/{len(results)} sections passed; "
          f"total benchmark wall time: {total_wall_s:.0f}s")
    exit_code = 1 if n_failed else 0
    if args.json:
        Path(args.json).write_text(json.dumps({
            "fast": args.fast,
            "only": args.only,
            "sections": [
                {"key": key, "title": title, "status":
                 "ok" if rc == 0 else "fail", "exit_code": rc,
                 "wall_s": dt}
                for key, title, rc, dt in results
            ],
            "n_sections": len(results),
            "n_failed": n_failed,
            "total_wall_s": total_wall_s,
            "exit_code": exit_code,
        }, indent=1))
    return exit_code


if __name__ == "__main__":
    import sys
    sys.exit(main())
